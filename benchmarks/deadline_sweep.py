"""Deadline-scheduling sweep: EDF vs fixed vs slo_adaptive under overload.

Every cell is a ``SystemSpec`` over the serving mix (per-tenant prefill +
decode streams with tiered SLOs) driven at an overload ``rho`` through
two bursty arrival processes (MMPP regime-switching and a flash crowd).
The EDF cells run the full deadline stack: earliest-deadline-first bucket
ordering, feasibility admission priced via the roofline cost model with
bounded oversubscription — the DARIS-style "admit late work only up to a
priced lateness budget" policy the fixed pending cap cannot express.

A separate preemption pair (same seed, preemption off/on) shows the
ahead-of-window force-dispatch rescuing decode deadlines that the
batching window alone would miss, bounded by the per-tenant interference
budget — and, with the flight recorder enabled, every admission /
oversubscription / preemption decision lands in the Perfetto-loadable
trace, which is where "why did this deadline miss" gets answered.

``--check`` (the CI ``deadline-gate``) asserts:

  1. EDF SLO attainment >= slo_adaptive and >= fixed on the MMPP
     overload mix (the tentpole ordering);
  2. same-seed reruns are byte-identical — metrics JSON AND the exported
     Chrome trace bytes;
  3. recorder-on metrics JSON == recorder-off metrics JSON (observability
     never perturbs the timeline);
  4. the preemption cell actually preempts, within budget, and does not
     lose attainment vs preemption-off.

The committed baseline is refreshed with the SAME arguments CI uses:

    PYTHONPATH=src python benchmarks/deadline_sweep.py --events 120000 \
        --json benchmarks/baselines/BENCH_baseline_deadline_sweep.json

    PYTHONPATH=src python benchmarks/deadline_sweep.py --events 1000000
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

from repro.api import SchedulerSpec, SystemSpec, WorkloadSpec
from repro.sim import SimMetrics, to_bench_json

PROCESSES = ("mmpp", "flash")
POLICIES = ("fixed", "slo_adaptive", "edf")

# the EDF stack every edf cell runs (feasibility admission + bounded
# oversubscription); fixed/slo_adaptive keep the blind cap default
EDF_OVERRIDES = {
    "scheduler.batching_policy": "edf",
    "scheduler.admission_policy": "feasibility",
    "scheduler.oversubscription": 1.25,
}

# preemption pair: a batching window wide enough that a decode cohort
# waiting it out misses its 20ms SLO, so only the ahead-of-window
# force-dispatch can save it (lead 0 => items ripen a full window after
# arrival, the worst case for tight deadlines)
PREEMPT_OVERRIDES = {
    "scheduler.batching_policy": "edf",
    "scheduler.batching_window_s": 0.017,
    "scheduler.deadline_lead_fraction": 0.0,
    "scheduler.preemption_budget_s": 0.050,
}


def _spec(events: int, tenants: int, seed: int, rho: float) -> SystemSpec:
    return SystemSpec(
        workload=WorkloadSpec(mix="serving", tenants=tenants, process="mmpp",
                              events=events, seed=seed, rho=rho),
        scheduler=SchedulerSpec(batching_window_s=0.002,
                                max_superkernel_size=64),
    )


def run(events: int = 1_000_000, tenants: int = 6, seed: int = 0,
        rho: float = 1.15, check: bool = False,
        json_path: Optional[str] = None) -> Dict[str, SimMetrics]:
    t_wall = time.perf_counter()
    base = _spec(events, tenants, seed, rho)
    sections: Dict[str, SimMetrics] = {}
    failures: List[str] = []

    print(f"\n=== deadline_sweep: {events} events/cell, serving mix, "
          f"tenants={tenants}, rho={rho}, seed={seed} ===")
    attain: Dict[str, Dict[str, float]] = {}
    for process in PROCESSES:
        print(f"\n--- {process} overload: policy comparison ---")
        print(f"{'policy':13s} {'attain':>7s} {'p95 ms':>10s} {'goodput':>11s} "
              f"{'rejected':>9s} {'dl_rej':>7s} {'oversub':>8s}")
        attain[process] = {}
        for policy in POLICIES:
            overrides = {"workload.process": process}
            if policy == "edf":
                overrides.update(EDF_OVERRIDES)
            else:
                overrides["scheduler.batching_policy"] = policy
            m = base.replace(**overrides).build().run_metrics()
            s = m.summary()
            attain[process][policy] = s["slo_attainment"]
            sections[f"{process}_{policy}"] = m
            print(f"{policy:13s} {s['slo_attainment']:7.4f} "
                  f"{s['p95_s']*1e3:10.3f} {s['goodput_cost_per_s']:11.4g} "
                  f"{s['rejected']:9.0f} {m.deadline_rejected:7d} "
                  f"{m.oversubscribed:8d}")
        a = attain[process]
        print(f"edf >= slo_adaptive: {a['edf'] >= a['slo_adaptive']}   "
              f"edf >= fixed: {a['edf'] >= a['fixed']}")

    # the tentpole ordering is gated on the MMPP mix; flash is tracked in
    # the baseline rows (10% gate) but not hard-ordered — a flash crowd
    # can overwhelm every policy equally at high enough rho
    a = attain["mmpp"]
    if a["edf"] < a["slo_adaptive"] or a["edf"] < a["fixed"]:
        failures.append(
            f"EDF attainment ordering violated on mmpp: edf={a['edf']:.4f} "
            f"slo_adaptive={a['slo_adaptive']:.4f} fixed={a['fixed']:.4f}")

    # ------------------------------------------------------ preemption pair
    pre_events = max(events // 4, 1000)
    pre_base = base.replace(**{"workload.events": pre_events,
                               "workload.seed": seed + 1,
                               "workload.rho": 0.9})
    print(f"\n--- preemption (17ms window vs 20ms decode SLO, "
          f"{pre_events} events) ---")
    pre: Dict[bool, SimMetrics] = {}
    for on in (False, True):
        overrides = dict(PREEMPT_OVERRIDES)
        overrides["scheduler.preemption"] = on
        m = pre_base.replace(**overrides).build().run_metrics()
        pre[on] = m
        sections[f"preempt_{'on' if on else 'off'}"] = m
        s = m.summary()
        print(f"preemption={'on ' if on else 'off'}: "
              f"attainment={s['slo_attainment']:.4f} "
              f"p95={s['p95_s']*1e3:.3f}ms preemptions={m.preemptions}")
    if pre[True].preemptions <= 0:
        failures.append("preemption cell recorded zero preemptions")
    if pre[True].slo_attainment < pre[False].slo_attainment:
        failures.append(
            f"preemption lost attainment: on={pre[True].slo_attainment:.4f} "
            f"< off={pre[False].slo_attainment:.4f}")

    # ------------------------------------------- determinism + recorder-off
    # headline EDF cell: same-seed rerun must be byte-identical, recorder-on
    # must not perturb the metrics, and two recorder-on runs must export
    # byte-identical Chrome trace JSON (admission/preemption events and all)
    headline = base.replace(**{"workload.process": "mmpp", **EDF_OVERRIDES})
    rerun = headline.build().run_metrics()
    if rerun.to_json() != sections["mmpp_edf"].to_json():
        failures.append("same-seed rerun of mmpp_edf not byte-identical")
    from repro.obs.trace_export import export_chrome_trace

    traced = headline.replace(**{"observability.enabled": True})
    runs = []
    for _ in range(2):
        r = traced.build()
        m = r.run_metrics()
        runs.append((m, export_chrome_trace(r.last_recorder)))
    if runs[0][0].to_json() != sections["mmpp_edf"].to_json():
        failures.append("recorder-on metrics differ from recorder-off")
    if runs[0][1] != runs[1][1]:
        failures.append("same-seed recorder trace bytes not identical")
    n_pre_events = runs[0][0].preemptions
    print(f"\ndeterminism: rerun byte-identical, trace "
          f"{len(runs[0][1])} bytes stable, recorder-off == recorder-on "
          f"(headline preemptions={n_pre_events})")

    # ---------------------------------------------------------------- output
    if json_path:
        doc = json.loads(to_bench_json(
            "deadline_sweep", sections,
            extra={"events": events, "tenants": tenants, "seed": seed,
                   "rho": rho}))
        # the gated trajectory rows: raw attainment fraction per cell under
        # the /slo_attainment suffix (HIGHER_BETTER in check_regression)
        for name in sorted(sections):
            doc["rows"].append({
                "name": f"deadline_sweep/{name}/slo_attainment",
                "us_per_call": sections[name].slo_attainment,
                "derived": "fraction SLO met (gated, higher is better)",
            })
        with open(json_path, "w") as fh:
            fh.write(json.dumps(doc, indent=2, sort_keys=True))
        print(f"wrote {json_path}")

    print(f"\ntotal wall time: {time.perf_counter() - t_wall:.1f}s")
    if failures:
        for f in failures:
            print(f"CHECK FAILED: {f}", file=sys.stderr)
        if check:
            sys.exit(1)
    elif check:
        print("checks passed: EDF >= slo_adaptive/fixed attainment on mmpp; "
              "preemption fires and does not regress; reruns byte-identical "
              "including recorder trace bytes")
    return sections


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--events", type=int, default=1_000_000,
                    help="arrivals per policy cell (preemption pair runs 1/4)")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rho", type=float, default=1.15,
                    help="offered load / estimated capacity (overload > 1)")
    ap.add_argument("--json", default=None, help="write BENCH-style JSON here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the deadline orderings hold")
    args = ap.parse_args()
    run(events=args.events, tenants=args.tenants, seed=args.seed,
        rho=args.rho, check=args.check, json_path=args.json)


if __name__ == "__main__":
    main()
