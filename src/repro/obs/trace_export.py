"""Chrome ``trace_event`` JSON export of a flight recording.

Produces the JSON Object Format (``{"traceEvents": [...]}``) that
Perfetto (ui.perfetto.dev) and ``chrome://tracing`` load directly:

    pid 1  "replicas"   one thread per replica — dispatch spans ("X"),
                        named by bucket, with batch size R, cold/warm,
                        and strategy in args: the space-time packing
                        picture, cold starts visibly longer
    pid 2  "tenants"    one thread per tenant — request spans ("X") from
                        arrival to completion (queueing + service) with
                        SLO-met in args, plus admission-rejection
                        instants ("i"): interference as it happens
    pid 3  "control"    router decisions (with the price vector that
                        justified them), autoscale events, and partition
                        assign/replan events as instants

Timestamps are microseconds (the format's unit); simulated seconds map
as ``t_s * 1e6``. Export is a pure function of recorder contents built
in deterministic order (shards by replica id, rows in record order), so
same-seed runs export byte-identical JSON — the contract the trace
tests and the CI ``trace-smoke`` job pin.
"""

from __future__ import annotations

import json
from typing import Dict, List

from repro.obs.recorder import FlightRecorder

PID_REPLICAS = 1
PID_TENANTS = 2
PID_CONTROL = 3
_TID_ROUTER = 0
_TID_AUTOSCALER = 1
_TID_PARTITION = 2


def _meta(pid: int, tid: int, name: str, value: str) -> Dict:
    return {"ph": "M", "pid": pid, "tid": tid, "name": name,
            "args": {"name": value}}


def chrome_trace_events(rec: FlightRecorder) -> List[Dict]:
    """The ``traceEvents`` list (metadata first, then spans/instants)."""
    events: List[Dict] = []
    add = events.append
    rids = sorted(rec.shards)

    # ------------------------------------------------------------ metadata
    add(_meta(PID_REPLICAS, 0, "process_name", "replicas"))
    add(_meta(PID_TENANTS, 0, "process_name", "tenants"))
    tenants: set = set()
    for rid in rids:
        s = rec.shards[rid]
        tenants.update(s._arr_tenant)
        tenants.update(s._req_tenant)
        label = f"replica {rid}"
        if s.spec_name:
            label += f" ({s.spec_name})"
        add(_meta(PID_REPLICAS, rid, "thread_name", label))
    tenants.update(rec._rt_tenant)
    for t in sorted(tenants):
        add(_meta(PID_TENANTS, t, "thread_name", f"tenant {t}"))
    if rec.n_routes or rec.scale_events or rec.partition_events:
        add(_meta(PID_CONTROL, 0, "process_name", "control"))
        if rec.n_routes:
            name = "router"
            if rec.router_name:
                name += f" ({rec.router_name})"
            add(_meta(PID_CONTROL, _TID_ROUTER, "thread_name", name))
        if rec.scale_events:
            add(_meta(PID_CONTROL, _TID_AUTOSCALER, "thread_name",
                      "autoscaler"))
        if rec.partition_events:
            add(_meta(PID_CONTROL, _TID_PARTITION, "thread_name",
                      "partitioner"))

    # ------------------------------------------------- per-replica shards
    for rid in rids:
        s = rec.shards[rid]
        labels = s._bucket_labels
        strategy = s.strategy
        for t0, dur, bi, size, cold in zip(s._dsp_t0, s._dsp_dur,
                                           s._dsp_bucket, s._dsp_size,
                                           s._dsp_cold):
            args = {"batch": size, "cold": bool(cold)}
            if strategy:
                args["strategy"] = strategy
            add({"ph": "X", "pid": PID_REPLICAS, "tid": rid,
                 "ts": t0 * 1e6, "dur": dur * 1e6, "cat": "dispatch",
                 "name": labels[bi], "args": args})
        for t0, t1, tenant, slo, bi in zip(s._req_t0, s._req_t1,
                                           s._req_tenant, s._req_slo,
                                           s._req_bucket):
            lat = t1 - t0
            add({"ph": "X", "pid": PID_TENANTS, "tid": tenant,
                 "ts": t0 * 1e6, "dur": lat * 1e6, "cat": "request",
                 "name": labels[bi],
                 "args": {"replica": rid, "slo_ms": slo * 1e3,
                          "met": lat <= slo}})
        for t, tenant, bi, admitted, reason in zip(
                s._arr_t, s._arr_tenant, s._arr_bucket, s._arr_admitted,
                s._arr_reason):
            if not admitted:
                add({"ph": "i", "pid": PID_TENANTS, "tid": tenant,
                     "ts": t * 1e6, "s": "t", "cat": "admission",
                     "name": ("rejected_infeasible" if reason == 3
                              else "rejected"),
                     "args": {"bucket": labels[bi], "replica": rid}})
            elif reason == 1:
                add({"ph": "i", "pid": PID_TENANTS, "tid": tenant,
                     "ts": t * 1e6, "s": "t", "cat": "admission",
                     "name": "oversubscribed",
                     "args": {"bucket": labels[bi], "replica": rid}})
        for t, tenant, bi, est, victims in zip(
                s._pre_t, s._pre_tenant, s._pre_bucket, s._pre_est,
                s._pre_victims):
            add({"ph": "i", "pid": PID_REPLICAS, "tid": rid,
                 "ts": t * 1e6, "s": "t", "cat": "preemption",
                 "name": "preempt",
                 "args": {"bucket": labels[bi], "tenant": tenant,
                          "est_ms": est * 1e3, "victims": victims}})

    # --------------------------------------------------------- fleet level
    off = 0
    for i in range(rec.n_routes):
        n = rec._rt_n[i]
        args: Dict = {"tenant": rec._rt_tenant[i]}
        if n:
            args["prices"] = {
                f"r{rec._rt_price_rid[off + j]}": rec._rt_price[off + j]
                for j in range(n)}
            off += n
        add({"ph": "i", "pid": PID_CONTROL, "tid": _TID_ROUTER,
             "ts": rec._rt_t[i] * 1e6, "s": "t", "cat": "router",
             "name": f"route->r{rec._rt_chosen[i]}", "args": args})
    for ev in rec.scale_events:
        add({"ph": "i", "pid": PID_CONTROL, "tid": _TID_AUTOSCALER,
             "ts": ev["t_s"] * 1e6, "s": "p", "cat": "autoscale",
             "name": f"scale_{ev['action']}", "args": dict(ev)})
    for ev in rec.partition_events:
        add({"ph": "i", "pid": PID_CONTROL, "tid": _TID_PARTITION,
             "ts": ev["t_s"] * 1e6, "s": "p", "cat": "partition",
             "name": f"partition_{ev['action']}", "args": dict(ev)})
    return events


def export_chrome_trace(rec: FlightRecorder) -> str:
    """Canonical (sorted-keys, compact) JSON document — byte-identical
    per seed, Perfetto-loadable."""
    doc = {"displayTimeUnit": "ms", "traceEvents": chrome_trace_events(rec)}
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))
