"""Windowed time-series telemetry over a flight recording.

Folds the recorder's event columns into fixed-width windows (the
``ObservabilitySpec.window_s`` tick): arrivals / rejections / completions
per window, rolling p50/p95 latency, SLO attainment, end-of-window
backlog, busy seconds and utilization — fleet-wide plus per-tenant and
per-replica breakdowns. This is the rolling view the end-of-run
aggregates (``SimMetrics``) cannot express: you can see the flash crowd
arrive, the backlog build, the autoscaler catch up, and attainment
recover, window by window.

Everything is vectorized numpy over the columnar shards and merged in
replica-id order, so the series is a pure deterministic function of the
recording — identical for ``workers=1`` and ``workers=K`` fleet runs of
one seed (the shards are). Output is a plain JSON-able dict; it rides
inside ``RunReport.metrics["telemetry"]`` and the ``report --timeline``
CLI renders it.
"""

from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

from repro.obs.recorder import FlightRecorder

TELEMETRY_SCHEMA = "telemetry/v1"

# per-window percentile grid kept deliberately small: telemetry rides
# inside every RunReport, and windows * series is the budget
_PCTS = (50.0, 95.0)


def _empty(window_s: float) -> Dict:
    return {"schema": TELEMETRY_SCHEMA, "window_s": window_s,
            "windows": 0, "t0_s": 0.0, "arrivals": [], "rejected": [],
            "completed": [], "p50_ms": [], "p95_ms": [],
            "slo_attainment": [], "backlog": [], "busy_s": [],
            "utilization": [], "per_tenant": {}, "per_replica": {}}


def _cat(shards, attr, dtype) -> np.ndarray:
    parts = [np.asarray(getattr(s, attr), dtype) for s in shards]
    return np.concatenate(parts) if parts else np.zeros(0, dtype)


def _busy_per_window(t0: np.ndarray, dur: np.ndarray, lo: float,
                     w: float, n: int) -> np.ndarray:
    """Exact busy seconds per window from dispatch spans. Spans fully
    inside one window (the vast majority at realistic ticks) are binned
    vectorized; the rare window-straddlers are split exactly."""
    busy = np.zeros(n)
    if t0.size == 0:
        return busy
    t1 = t0 + dur
    w0 = np.clip(((t0 - lo) / w).astype(np.int64), 0, n - 1)
    w1 = np.clip(((t1 - lo) / w).astype(np.int64), 0, n - 1)
    inside = w0 == w1
    if inside.any():
        busy += np.bincount(w0[inside], weights=dur[inside], minlength=n)
    for s, e, a, b in zip(t0[~inside], t1[~inside], w0[~inside],
                          w1[~inside]):
        for k in range(a, b + 1):
            lo_k = lo + k * w
            busy[k] += min(e, lo_k + w) - max(s, lo_k)
    return busy


def windowed_series(rec: FlightRecorder, window_s: float) -> Dict:
    """Fold ``rec`` into fixed windows of ``window_s`` simulated seconds
    (wall seconds for live recordings); see module docstring for the
    series produced."""
    if window_s <= 0.0:
        raise ValueError(f"window_s must be > 0, got {window_s}")
    w = float(window_s)
    shards = [rec.shards[k] for k in sorted(rec.shards)]

    arr_t = _cat(shards, "_arr_t", np.float64)
    arr_adm = _cat(shards, "_arr_admitted", np.int64)
    req_t0 = _cat(shards, "_req_t0", np.float64)
    req_t1 = _cat(shards, "_req_t1", np.float64)
    req_slo = _cat(shards, "_req_slo", np.float64)
    req_tenant = _cat(shards, "_req_tenant", np.int64)
    dsp_t0 = _cat(shards, "_dsp_t0", np.float64)
    dsp_dur = _cat(shards, "_dsp_dur", np.float64)
    dsp_rid = np.concatenate(
        [np.full(s.n_dispatches, s.replica_id, np.int64) for s in shards]
    ) if shards else np.zeros(0, np.int64)

    bounds = [a for a in (arr_t, req_t1, dsp_t0) if a.size]
    if not bounds:
        return _empty(w)
    lo = min(float(a.min()) for a in bounds)
    hi = max(float(arr_t.max()) if arr_t.size else lo,
             float(req_t1.max()) if req_t1.size else lo,
             float((dsp_t0 + dsp_dur).max()) if dsp_t0.size else lo)
    n = max(1, int(math.ceil((hi - lo) / w))) if hi > lo else 1

    def widx(t: np.ndarray) -> np.ndarray:
        return np.clip(((t - lo) / w).astype(np.int64), 0, n - 1)

    def counts(t: np.ndarray) -> np.ndarray:
        if t.size == 0:
            return np.zeros(n, np.int64)
        return np.bincount(widx(t), minlength=n)

    arrivals = counts(arr_t)
    admitted = counts(arr_t[arr_adm == 1])
    rejected = arrivals - admitted
    completed = counts(req_t1)

    lat = req_t1 - req_t0
    met = (lat <= req_slo).astype(np.float64)
    cw = widx(req_t1) if req_t1.size else np.zeros(0, np.int64)

    p50 = np.zeros(n)
    p95 = np.zeros(n)
    attain = np.ones(n)
    if req_t1.size:
        order = np.argsort(cw, kind="stable")
        starts = np.searchsorted(cw[order], np.arange(n + 1))
        lat_sorted = lat[order]
        met_sums = np.bincount(cw, weights=met, minlength=n)
        for k in range(n):
            a, b = starts[k], starts[k + 1]
            if a < b:
                p50[k], p95[k] = np.percentile(lat_sorted[a:b], _PCTS)
                attain[k] = met_sums[k] / (b - a)

    backlog = np.cumsum(admitted) - np.cumsum(completed)
    busy = _busy_per_window(dsp_t0, dsp_dur, lo, w, n)
    n_replicas = max(1, len(shards))
    util = busy / (w * n_replicas)

    out = {
        "schema": TELEMETRY_SCHEMA,
        "window_s": w,
        "windows": n,
        "t0_s": lo,
        "arrivals": arrivals.tolist(),
        "rejected": rejected.tolist(),
        "completed": completed.tolist(),
        "p50_ms": (p50 * 1e3).tolist(),
        "p95_ms": (p95 * 1e3).tolist(),
        "slo_attainment": attain.tolist(),
        "backlog": backlog.tolist(),
        "busy_s": busy.tolist(),
        "utilization": util.tolist(),
        "per_tenant": {},
        "per_replica": {},
    }

    if req_t1.size:
        per_tenant: Dict[str, Dict[str, List]] = {}
        for t in np.unique(req_tenant):
            mask = req_tenant == t
            cw_t = cw[mask]
            done = np.bincount(cw_t, minlength=n).astype(np.float64)
            met_t = np.bincount(cw_t, weights=met[mask], minlength=n)
            at = np.divide(met_t, done, out=np.ones(n), where=done > 0)
            per_tenant[str(int(t))] = {
                "completed": done.astype(np.int64).tolist(),
                "slo_attainment": at.tolist(),
            }
        out["per_tenant"] = per_tenant

    per_replica: Dict[str, Dict[str, List]] = {}
    for s in shards:
        rid = s.replica_id
        mask = dsp_rid == rid
        per_replica[str(rid)] = {
            "busy_s": _busy_per_window(dsp_t0[mask], dsp_dur[mask],
                                       lo, w, n).tolist(),
            "dispatches": counts(dsp_t0[mask]).tolist(),
        }
    out["per_replica"] = per_replica
    return out
