"""Fleet sweep: replicas x router x strategy on the multi-replica simulator.

DEPRECATION SHIM: this script is now a thin caller of the declarative
``repro.api`` layer — one base ``SystemSpec`` per grid, ``replace()``d
per cell and pinned to the ``FleetRun`` executor (the grid's r1 cells
must report fleet metrics too). Prefer the unified CLI for new work:

    PYTHONPATH=src python -m repro sweep --spec examples/specs/hetero_fleet.json \
        --axis router.policy=round_robin,jsq,least_cost,affinity

The argparse surface below is kept for the committed baselines and CI
gates, which it reproduces byte-identically.

Every cell drives the SAME seeded arrival trace through ``repro.sim.fleet``
— N replicas of the real scheduler, each on its own virtual clock with its
own compile-cache cold-start state, behind one routing policy. Bursty MMPP
arrivals by default: load-aware routing only separates from round-robin
when load actually fluctuates.

What the grid shows (and ``--check`` gates for CI):

  * routing — join-shortest-queue and least-estimated-cost must not have a
    worse p95 than round-robin on the same trace (load-/cost-aware routing
    beats load-oblivious routing under bursts); least-estimated-cost
    additionally exploits merge economies and warm-cache affinity, which
    is typically a large win.
  * scaling — fleet goodput is non-decreasing in the replica count at
    fixed offered load (the paper's Fig-5 replica story, now with queueing
    and cold starts in the loop).
  * determinism — the headline cell run twice from the same seed produces
    byte-identical metrics JSON (the contract the CI determinism job
    diffs).

    PYTHONPATH=src python benchmarks/fleet_sweep.py --events 5000 \
        --replicas 4 --check --json BENCH_fleet_sweep.json

With ``--specs`` (and optionally ``--autoscale``) the sweep switches to
the HETEROGENEOUS grid instead: mixed chip generations behind every
router, an equal-aggregate-FLOP/s homogeneous twin for comparison, and an
elastic fleet grown from one replica by the backlog autoscaler (spin-up
pays a full cold compile cache). Its ``--check`` gates: speed-aware
routing (least_cost p95 <= round_robin p95 on the mixed fleet), hetero
goodput not below the homogeneous twin's, the elastic fleet actually
scaling, and same-seed byte-identical JSON INCLUDING scale events.

    PYTHONPATH=src python benchmarks/fleet_sweep.py --events 5000 \
        --replicas 4 --specs v5e,v5e_half --autoscale --check \
        --json BENCH_fleet_hetero.json
"""

from __future__ import annotations

import argparse
import math
import sys
import time
from typing import Dict, List, Optional

from repro.api import (
    AutoscaleSpec,
    FleetRun,
    FleetSpec,
    RouterSpec,
    SchedulerSpec,
    SystemSpec,
    WorkloadSpec,
    build_mix,
    resolve_rate_hz,
)
from repro.launch.roofline import TPU_V5E, resolve_spec
from repro.sim import ROUTERS, FleetMetrics, to_bench_json

STRATEGIES = ("time_only", "space_only", "space_time")


def replica_grid(n_max: int) -> List[int]:
    """1, 2, ..., doubling up to the requested fleet size."""
    grid = [1]
    while grid[-1] * 2 < n_max:
        grid.append(grid[-1] * 2)
    if grid[-1] != n_max:
        grid.append(n_max)
    return grid


def run(events: int = 20_000, replicas: int = 4, tenants: int = 12,
        seed: int = 0, process: str = "mmpp", mix_name: str = "fleet",
        rho: float = 0.85, compile_us: float = 200.0,
        check: bool = False, json_path: Optional[str] = None,
        csv_rows=None) -> Dict[str, FleetMetrics]:
    t_wall = time.perf_counter()
    sections: Dict[str, FleetMetrics] = {}
    failures: List[str] = []

    # offered load anchored to the FULL fleet's space_time capacity, so the
    # smaller replica counts in the grid run overloaded — that is where the
    # goodput-vs-N scaling curve is visible
    base = SystemSpec(
        workload=WorkloadSpec(mix=mix_name, tenants=tenants, process=process,
                              events=events, seed=seed, rho=rho),
        fleet=FleetSpec(replicas=replicas),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32),
    )
    base = base.replace(**{"cost_model.compile_us": compile_us})
    mix = build_mix(base.workload)
    offered_hz = resolve_rate_hz(base, mix)
    capacity_hz = resolve_rate_hz(
        base.replace(**{"workload.rho": 1.0, "fleet.replicas": 1}), mix)
    base = base.replace(**{"workload.rate_hz": offered_hz})
    grid = replica_grid(replicas)

    print(f"\n=== fleet_sweep: {events} events/cell, mix={mix_name}, "
          f"process={process}, seed={seed} ===")
    print(f"single-replica space_time capacity ~{capacity_hz:,.0f} arrivals/s; "
          f"offered load {rho:.2f} x {replicas} replicas "
          f"(~{offered_hz:,.0f}/s); compile cold-start {compile_us:g}us")

    def run_cell(n: int, router: str, strategy: str) -> FleetMetrics:
        # pinned to FleetRun: the r1 cells of the grid must report fleet
        # metrics (routing imbalance, cold fractions) like every other cell
        return FleetRun(base.replace(**{
            "fleet.replicas": n,
            "router.policy": router,
            "cost_model.strategy": strategy,
        })).run_metrics()

    print(f"\n{'cell':>28s} {'p95 ms':>9s} {'attain':>7s} {'goodput':>10s} "
          f"{'imbal':>6s} {'util':>6s} {'cold%':>6s}")
    for strategy in STRATEGIES:
        for n in grid:
            for router in ROUTERS:
                m = run_cell(n, router, strategy)
                name = f"r{n}_{router}_{strategy}"
                sections[name] = m
                s = m.summary()
                print(f"{name:>28s} {s['p95_s']*1e3:9.3f} "
                      f"{s['slo_attainment']:7.3f} "
                      f"{s['goodput_cost_per_s']:10.4g} "
                      f"{s['routing_imbalance']:6.3f} {s['utilization']:6.3f} "
                      f"{s['cold_start_fraction']*100:6.2f}")

    # ------------------------------------------------------------ 1. routing
    rr = sections[f"r{replicas}_round_robin_space_time"].summary()["p95_s"]
    for router in ("jsq", "least_cost"):
        p95 = sections[f"r{replicas}_{router}_space_time"].summary()["p95_s"]
        ok = p95 <= rr
        print(f"\n{router} p95 <= round_robin p95 ({replicas} replicas): "
              f"{p95*1e3:.3f}ms vs {rr*1e3:.3f}ms -> {ok}")
        if not ok:
            failures.append(
                f"{router} p95 {p95*1e3:.3f}ms > round_robin {rr*1e3:.3f}ms")

    # ------------------------------------------------------------ 2. scaling
    goodputs = [sections[f"r{n}_jsq_space_time"]
                .summary()["goodput_cost_per_s"] for n in grid]
    print("fleet goodput over replicas "
          + " -> ".join(f"{n}:{g:.4g}" for n, g in zip(grid, goodputs)))
    for (n_lo, g_lo), (n_hi, g_hi) in zip(zip(grid, goodputs),
                                          zip(grid[1:], goodputs[1:])):
        # tiny relative slack: once the fleet fully keeps up, goodput
        # plateaus at the offered rate and only makespan float-dust moves
        if g_hi < g_lo * (1.0 - 1e-6):
            failures.append(
                f"goodput not monotone in replicas: {n_hi} replicas "
                f"{g_hi:.6g} < {n_lo} replicas {g_lo:.6g}")

    # -------------------------------------------------------- 3. determinism
    headline = f"r{replicas}_jsq_space_time"
    rerun = run_cell(replicas, "jsq", "space_time")
    identical = rerun.to_json() == sections[headline].to_json()
    print(f"same-seed rerun of {headline} byte-identical: {identical}")
    if not identical:
        failures.append(f"{headline} rerun JSON differs (nondeterminism)")

    # ------------------------------------------------------------ 4. cold fx
    jsq = sections[f"r{replicas}_jsq_space_time"]
    first, second = jsq.cold_fraction_halves()
    print(f"cold-start fraction decays: first half {first:.3f} "
          f"-> second half {second:.3f}")

    # ---------------------------------------------------------------- outputs
    if csv_rows is not None:
        for name, m in sections.items():
            csv_rows.extend(m.bench_rows(f"fleet_sweep/{name}"))
    if json_path:
        with open(json_path, "w") as fh:
            fh.write(to_bench_json(
                "fleet_sweep", sections,
                extra={"events": events, "seed": seed, "process": process,
                       "mix": mix_name, "rho": rho, "replicas": replicas,
                       "replica_grid": grid, "compile_us": compile_us,
                       "capacity_hz": capacity_hz}))
        print(f"\nwrote {json_path}")

    print(f"\ntotal wall time: {time.perf_counter() - t_wall:.1f}s")
    if failures:
        for f in failures:
            print(f"CHECK FAILED: {f}", file=sys.stderr)
        if check:
            sys.exit(1)
    elif check:
        print("checks passed: jsq & least_cost p95 <= round_robin; goodput "
              "non-decreasing in replicas; same-seed JSON byte-identical")
    return sections


def run_hetero(events: int = 20_000, replicas: int = 4,
               specs_arg: str = "v5e,v5e_half", tenants: int = 12,
               seed: int = 0, process: str = "mmpp", mix_name: str = "fleet",
               rho: float = 0.85, compile_us: float = 200.0,
               spinup_us: float = 100.0, autoscale: bool = False,
               check: bool = False, json_path: Optional[str] = None,
               csv_rows=None) -> Dict[str, FleetMetrics]:
    """Heterogeneous + elastic fleet grid (see module docstring)."""
    t_wall = time.perf_counter()
    sections: Dict[str, FleetMetrics] = {}
    failures: List[str] = []

    names = [s.strip() for s in specs_arg.split(",") if s.strip()]
    replica_specs = [names[i % len(names)] for i in range(replicas)]
    # the equal-aggregate-FLOP/s homogeneous twin: the SAME total roofline
    # throughput delivered by round(sum of speed factors) full-speed
    # replicas — the fleet you would buy if you scrapped the old chips.
    # The mixed fleet should win: more replicas = more parallel dispatch
    # slots and shorter queues for the same silicon, PROVIDED the router
    # prices the speed difference (that is the tentpole claim).
    factors = [resolve_spec(s).peak_flops / TPU_V5E.peak_flops
               for s in replica_specs]
    # round half UP (banker's rounding would under-provision the twin on
    # half-integer aggregates and make the goodput gate trivially true)
    eq_replicas = max(1, math.floor(sum(factors) + 0.5))

    # offered load anchored to the MIXED fleet's aggregate space_time
    # capacity; the twin sees the same trace, so the comparison is pure
    base = SystemSpec(
        workload=WorkloadSpec(mix=mix_name, tenants=tenants, process=process,
                              events=events, seed=seed, rho=rho),
        fleet=FleetSpec(replicas=replicas, specs=tuple(replica_specs)),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32),
    )
    base = base.replace(**{"cost_model.compile_us": compile_us})
    mix = build_mix(base.workload)
    offered_hz = resolve_rate_hz(base, mix)
    capacity_hz = resolve_rate_hz(base.replace(**{"workload.rho": 1.0}), mix)
    base = base.replace(**{"workload.rate_hz": offered_hz})

    # autoscaler thresholds are SLO-denominated: scale up when the mean
    # replica is half a mid-tier SLO behind, down below a tenth of it
    slos = sorted(s.slo_s for s in mix)
    slo_mid = slos[len(slos) // 2]
    tick_s = 50.0 / offered_hz  # a control decision every ~50 arrivals
    scaler_spec = AutoscaleSpec(
        min_replicas=1, max_replicas=replicas,
        up_backlog_s=slo_mid / 2.0, down_backlog_s=slo_mid / 10.0,
        interval_s=tick_s, cooldown_ticks=2, spinup_s=spinup_us * 1e-6)

    print(f"\n=== fleet_hetero: {events} events/cell, mix={mix_name}, "
          f"process={process}, seed={seed} ===")
    print(f"replica specs {replica_specs} (aggregate {sum(factors):g}x v5e; "
          f"homogeneous twin: {eq_replicas} x v5e); aggregate space_time "
          f"capacity ~{capacity_hz:,.0f}/s, offered {rho:.2f}x "
          f"(~{offered_hz:,.0f}/s); compile {compile_us:g}us, spin-up "
          f"{spinup_us:g}us"
          + (f"; autoscale 1..{replicas} replicas, tick {tick_s*1e6:.0f}us"
             if autoscale else ""))

    def run_cell(router: str, specs=None, n: int = replicas,
                 elastic: bool = False) -> FleetMetrics:
        fleet = FleetSpec(
            replicas=n,
            specs=tuple(specs) if specs else None,
            autoscale=scaler_spec if elastic else None)
        spec = SystemSpec(mode=base.mode, workload=base.workload, fleet=fleet,
                          router=RouterSpec(policy=router),
                          scheduler=base.scheduler, cost_model=base.cost_model)
        return FleetRun(spec).run_metrics()

    print(f"\n{'cell':>24s} {'p95 ms':>9s} {'attain':>7s} {'goodput':>10s} "
          f"{'imbal':>6s} {'util':>6s} {'cold%':>6s} {'repl':>9s}")

    def show(name: str, m: FleetMetrics) -> None:
        sections[name] = m
        s = m.summary()
        repl = f"{m.initial_replicas}->{m.final_active}" if m.scale_events \
            else f"{m.final_active}"
        print(f"{name:>24s} {s['p95_s']*1e3:9.3f} {s['slo_attainment']:7.3f} "
              f"{s['goodput_cost_per_s']:10.4g} {s['routing_imbalance']:6.3f} "
              f"{s['utilization']:6.3f} {s['cold_start_fraction']*100:6.2f} "
              f"{repl:>9s}")

    for router in ROUTERS:
        show(f"hetero_{router}", run_cell(router, specs=replica_specs))
    for router in ("round_robin", "least_cost"):
        show(f"homo_eq_{router}", run_cell(router, n=eq_replicas))
    if autoscale:
        for router in ("jsq", "least_cost"):
            show(f"elastic_{router}",
                 run_cell(router, specs=replica_specs, n=1, elastic=True))

    # -------------------------------------------- 1. speed-aware routing
    rr = sections["hetero_round_robin"].summary()["p95_s"]
    lc = sections["hetero_least_cost"].summary()["p95_s"]
    ok = lc <= rr
    print(f"\nmixed fleet: least_cost p95 <= round_robin p95: "
          f"{lc*1e3:.3f}ms vs {rr*1e3:.3f}ms -> {ok}")
    if not ok:
        failures.append(
            f"hetero least_cost p95 {lc*1e3:.3f}ms > round_robin "
            f"{rr*1e3:.3f}ms")

    # ------------------------------- 2. hetero vs equal-aggregate twin
    g_het = sections["hetero_least_cost"].summary()["goodput_cost_per_s"]
    g_eq = sections["homo_eq_least_cost"].summary()["goodput_cost_per_s"]
    ok = g_het >= g_eq * (1.0 - 1e-6)
    print(f"hetero goodput >= equal-aggregate homogeneous twin "
          f"({eq_replicas} x v5e, least_cost): {g_het:.4g} vs {g_eq:.4g} "
          f"-> {ok}")
    if not ok:
        failures.append(
            f"hetero least_cost goodput {g_het:.6g} < homogeneous twin "
            f"{g_eq:.6g}")

    # ------------------------------------------------ 3. elasticity
    if autoscale:
        m = sections["elastic_least_cost"]
        print(f"elastic fleet scaled 1 -> {m.final_active} active "
              f"({m.scale_ups} up / {m.scale_downs} down events)")
        if m.scale_ups < 1:
            failures.append("elastic fleet never scaled up under rho="
                            f"{rho} load")

    # ---------------------------------------------- 4. determinism
    headline = "elastic_least_cost" if autoscale else "hetero_least_cost"
    rerun = run_cell("least_cost", specs=replica_specs,
                     n=1 if autoscale else replicas, elastic=autoscale)
    identical = rerun.to_json() == sections[headline].to_json()
    print(f"same-seed rerun of {headline} byte-identical "
          f"(scale events included): {identical}")
    if not identical:
        failures.append(f"{headline} rerun JSON differs (nondeterminism)")

    # -------------------------------------------------------- outputs
    if csv_rows is not None:
        for name, m in sections.items():
            csv_rows.extend(m.bench_rows(f"fleet_hetero/{name}"))
    if json_path:
        with open(json_path, "w") as fh:
            fh.write(to_bench_json(
                "fleet_hetero", sections,
                extra={"events": events, "seed": seed, "process": process,
                       "mix": mix_name, "rho": rho, "replicas": replicas,
                       "specs": replica_specs, "eq_replicas": eq_replicas,
                       "compile_us": compile_us, "spinup_us": spinup_us,
                       "autoscale": autoscale, "capacity_hz": capacity_hz}))
        print(f"\nwrote {json_path}")

    print(f"\ntotal wall time: {time.perf_counter() - t_wall:.1f}s")
    if failures:
        for f in failures:
            print(f"CHECK FAILED: {f}", file=sys.stderr)
        if check:
            sys.exit(1)
    elif check:
        print("checks passed: least_cost p95 <= round_robin on the mixed "
              "fleet; hetero goodput >= equal-aggregate homogeneous twin; "
              + ("elastic fleet scaled up; " if autoscale else "")
              + "same-seed JSON byte-identical incl. scale events")
    return sections


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--events", type=int, default=20_000,
                    help="arrivals per grid cell")
    ap.add_argument("--replicas", type=int, default=4,
                    help="max fleet size (grid doubles up to it)")
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--process", default="mmpp",
                    choices=("poisson", "mmpp", "diurnal", "flash"))
    ap.add_argument("--mix", default="fleet",
                    choices=("fleet", "sgemm", "serving"))
    ap.add_argument("--rho", type=float, default=0.85,
                    help="offered load as a fraction of the FULL fleet's "
                         "space_time capacity")
    ap.add_argument("--compile-us", type=float, default=200.0,
                    help="per-(bucket,pow2-R) compile cold-start cost "
                         "(microseconds; 0 disables)")
    ap.add_argument("--specs", default=None,
                    help="comma-separated per-replica hardware (cycled), "
                         "e.g. v5e,v5e_half — switches to the heterogeneous "
                         "grid")
    ap.add_argument("--autoscale", action="store_true",
                    help="add elastic cells grown from 1 replica by the "
                         "backlog autoscaler (implies the hetero grid)")
    ap.add_argument("--spinup-us", type=float, default=100.0,
                    help="replica spin-up latency before a scaled-up "
                         "replica takes work (microseconds)")
    ap.add_argument("--json", default=None, help="write BENCH-style JSON here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless routing/scaling/determinism "
                         "contracts hold")
    args = ap.parse_args()
    print("note: fleet_sweep.py is a shim over the unified CLI; prefer "
          "`python -m repro sweep` (see README)", file=sys.stderr)
    if args.specs or args.autoscale:
        run_hetero(events=args.events, replicas=args.replicas,
                   specs_arg=args.specs or "v5e,v5e_half",
                   tenants=args.tenants, seed=args.seed,
                   process=args.process, mix_name=args.mix, rho=args.rho,
                   compile_us=args.compile_us, spinup_us=args.spinup_us,
                   autoscale=args.autoscale, check=args.check,
                   json_path=args.json)
    else:
        run(events=args.events, replicas=args.replicas, tenants=args.tenants,
            seed=args.seed, process=args.process, mix_name=args.mix,
            rho=args.rho, compile_us=args.compile_us, check=args.check,
            json_path=args.json)


if __name__ == "__main__":
    main()
