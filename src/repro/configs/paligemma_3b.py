"""paligemma-3b [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216. SigLIP vision
encoder + gemma decoder; per assignment rules the SigLIP frontend is a STUB:
``input_specs()`` supplies 256 precomputed patch embeddings (siglip-so400m
14x14 patches on 224px -> 16x16=256 tokens, 1152-dim) which the framework
projects to d_model.
"""

from repro.config import Modality, ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="paligemma-3b",
        source="arXiv:2407.07726",
        family="vlm",
        num_layers=18,
        d_model=2048,
        vocab_size=257216,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        modality=Modality.VISION_TEXT,
        num_prefix_embeddings=256,
        frontend_embed_dim=1152,
        tie_embeddings=True,
        scale_embed=True,
        rope_theta=10_000.0,
    )
)
