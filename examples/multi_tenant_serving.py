"""End-to-end multi-tenant serving: R tenants of one architecture served
by the space-time engine with batched requests.

This is the model-level form of the paper's mechanism: tenant weights are
STACKED, the decode step is ONE vmapped program, so every projection/FFN
GEMM executes as an inter-model batched super-kernel.

    PYTHONPATH=src python examples/multi_tenant_serving.py --arch stablelm-1.6b -R 4
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("-R", "--tenants", type=int, default=4)
    ap.add_argument("--requests-per-tenant", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--mode", default="space_time", choices=["space_time", "time_only"])
    args = ap.parse_args()

    cfg = dataclasses.replace(smoke_variant(get_config(args.arch)), dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) "
          f"R={args.tenants} mode={args.mode}")

    tenant_params = [model.init(jax.random.fold_in(key, t)) for t in range(args.tenants)]
    engine = MultiTenantEngine(
        model, tenant_params,
        EngineConfig(num_tenants=args.tenants, slots_per_tenant=2,
                     cache_len=96, mode=args.mode),
    )

    rng = np.random.RandomState(0)
    t0 = time.perf_counter()
    for t in range(args.tenants):
        for _ in range(args.requests_per_tenant):
            engine.submit(InferenceRequest(
                tenant_id=t,
                prompt=list(rng.randint(1, cfg.vocab_size, size=8)),
                max_new_tokens=args.max_new_tokens,
            ))
    engine.run_until_drained()
    dt = time.perf_counter() - t0

    rep = engine.report()
    print(f"\nserved {rep['finished']:.0f} requests / "
          f"{rep['decode_tokens']:.0f} tokens in {dt:.1f}s "
          f"({rep['decode_tokens']/dt:.1f} tok/s)")
    print(f"p50 step latency {rep['p50_s']*1e3:.1f} ms   "
          f"p95 {rep['p95_s']*1e3:.1f} ms   "
          f"inter-tenant spread {rep['spread']:.1%}")
    for r in engine.finished[:3]:
        print(f"  tenant {r.tenant_id} req {r.request_id}: "
              f"prompt {r.prompt[:4]}... -> {r.generated}")


if __name__ == "__main__":
    main()
