"""Figure 2: batch size vs throughput and latency under an SLO.

Paper: ResNet-50 on V100 — throughput grows with batch, but the largest
batch within a 30 ms SLO only reaches ~28% of peak. Here: prefill of a
smoke model across batch sizes; reports tokens/s, latency, and the largest
batch meeting the SLO.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import get_config, smoke_variant
from repro.models import build_model

SLO_S = 0.200


def run(batches=(1, 2, 4, 8, 16, 32), seq: int = 32, csv_rows=None):
    print("\n=== Fig 2: batch vs throughput under SLO "
          f"({int(SLO_S*1e3)} ms, smoke model) ===")
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")), dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    fn = jax.jit(lambda p, t: m.forward_prefill(p, t, cache_len=seq)[0])
    print(f"{'batch':>6s} {'latency ms':>11s} {'tokens/s':>10s} {'in SLO':>7s}")
    best_in_slo = 0
    rates = []
    for b in batches:
        toks = jax.random.randint(key, (b, seq), 0, cfg.vocab_size)
        jax.block_until_ready(fn(params, toks))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(params, toks))
        dt = (time.perf_counter() - t0) / 3
        rate = b * seq / dt
        rates.append(rate)
        ok = dt <= SLO_S
        if ok:
            best_in_slo = b
        print(f"{b:6d} {dt*1e3:11.2f} {rate:10.0f} {'yes' if ok else 'NO':>7s}")
        if csv_rows is not None:
            csv_rows.append((f"fig2/batch{b}", dt * 1e6, f"tokens_per_s={rate:.0f}"))
    util = rates[[i for i, b in enumerate(batches) if b == best_in_slo][0]] / rates[-1] \
        if best_in_slo else 0.0
    print(f"largest batch in SLO: {best_in_slo}; utilization at that point vs "
          f"max-batch throughput: {util:.0%} (paper: 28% of peak)")


if __name__ == "__main__":
    run()
