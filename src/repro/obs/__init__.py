"""Flight recorder: structured event tracing for scheduler, routers, fleet.

The observability substrate (see ``repro.obs.recorder``): a
zero-overhead-when-off columnar event store capturing every decision
point — arrivals/admissions, dispatch spans, router price vectors,
autoscale events — with three read paths: Chrome ``trace_event`` JSON
(``repro.obs.trace_export``, Perfetto-viewable timelines), windowed
time-series telemetry (``repro.obs.telemetry``), and the
``python -m repro trace`` / ``report --timeline`` CLI surface.
"""

from repro.obs.recorder import (
    FlightRecorder,
    ReplicaShard,
    dispatch_tap,
    route_price_vector,
)
from repro.obs.telemetry import windowed_series
from repro.obs.trace_export import chrome_trace_events, export_chrome_trace

__all__ = [
    "FlightRecorder",
    "ReplicaShard",
    "chrome_trace_events",
    "dispatch_tap",
    "export_chrome_trace",
    "route_price_vector",
    "windowed_series",
]
