"""The space-time super-kernel: R same-shape GEMMs in ONE pallas_call.

This is the paper's core mechanism adapted to TPU. The GPU prototype used
``cublasSgemmBatched``; on TPU we put the problem index R on the leading
grid axis so one kernel invocation streams R independent (M,K)x(K,N)
problems through the MXU with no per-problem dispatch cost. Each problem's
weights come from a *different tenant model* — this is inter-model batching,
not data batching.

Grid: (R, M/bm, N/bn, K/bk), K innermost so a float32 VMEM accumulator can
live across the K steps of one (r, i, j) output tile. Block shapes default
to MXU-aligned (128, 128) output tiles with a 512-deep K panel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    """One (r, i, j, k) grid step: acc += X[r, i-block, k-block] @ W[r, k-block, j-block]."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(3) - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def batched_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    out_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """out[r] = x[r] @ w[r].

    Args:
        x: (R, M, K) activations, one sub-problem per tenant.
        w: (R, K, N) weights, one sub-problem per tenant.
        bm/bn/bk: VMEM block shape. Output tile (bm, bn) should be MXU
            aligned (multiples of 128 on TPU); K panel bk bounds the
            accumulator working set: bm*bk + bk*bn + bm*bn floats in VMEM.
    Returns:
        (R, M, N) in ``out_dtype`` (defaults to x.dtype).
    """
    if x.ndim != 3 or w.ndim != 3:
        raise ValueError(f"expected (R,M,K),(R,K,N); got {x.shape}, {w.shape}")
    R, M, K = x.shape
    Rw, Kw, N = w.shape
    if Rw != R or Kw != K:
        raise ValueError(f"shape mismatch: x {x.shape} vs w {w.shape}")
    out_dtype = out_dtype or x.dtype

    # Pad every dim up to its block multiple; pallas grids must tile exactly.
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    # keep hardware alignment when the problem is large enough, otherwise
    # round the block down to the (padded) problem size.
    Mp, Np, Kp = (pl.cdiv(M, bm_) * bm_, pl.cdiv(N, bn_) * bn_, pl.cdiv(K, bk_) * bk_)
    if (Mp, Np, Kp) != (M, N, K):
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, Kp - K)))
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))

    grid = (R, Mp // bm_, Np // bn_, Kp // bk_)

    out = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda r, i, j, k: (r, i, k)),
            pl.BlockSpec((1, bk_, bn_), lambda r, i, j, k: (r, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda r, i, j, k: (r, i, j)),
        out_shape=jax.ShapeDtypeStruct((R, Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :M, :N]
