"""Declarative system description — the repo's one front door.

A ``SystemSpec`` is a nested, JSON-round-trippable description of a
complete experiment: what arrives (``WorkloadSpec``), on how many
replicas of which hardware (``FleetSpec`` + ``AutoscaleSpec``), routed
how (``RouterSpec``), scheduled how (``SchedulerSpec``), and priced how
(``CostModelSpec``). ``build()`` assembles the right executor for the
spec's shape — the solo ``Simulator`` for one replica, the
``FleetSimulator`` for many, the engine-backed ``LiveFleet`` for
``mode="live"`` — and every executor returns the same ``RunReport``
(metrics + spec echo + schema_version).

Field-to-subsystem map:

    workload    -> repro.sim.traces   (mix builders + arrival processes)
    fleet       -> repro.sim.fleet    (replicas, per-replica HardwareSpec
                                       names, repro.sim.autoscale)
    router      -> repro.sim.router   (ROUTERS registry)
    scheduler   -> repro.config.ScheduleConfig (the real scheduling core)
    cost_model  -> repro.sim.costmodel (roofline / calibrated priors,
                                        cold-start compile accounting,
                                        launch.roofline.HARDWARE_SPECS)
    mode="live" -> repro.serving.fleet.LiveFleet (N real engines behind
                                       the same routers, wall clock)

Every spec constructor validates eagerly with actionable errors (unknown
hardware names list the registered ``HARDWARE_SPECS`` keys, unknown
routers list ``ROUTERS``, ...), so a typo in a JSON spec fails at
``load`` time, not three layers into a sweep.

Round-trip contract (property-tested): ``SystemSpec.from_dict(s.to_dict())
== s``, and ``build()`` on the round-tripped spec reproduces
byte-identical metrics JSON for a fixed seed.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Tuple

from repro.config import ScheduleConfig
from repro.launch.roofline import resolve_spec
from repro.sim.costmodel import STRATEGIES
from repro.sim.metrics import SCHEMA_VERSION
from repro.sim.router import ROUTERS

MIXES = ("sgemm", "fleet", "serving", "single")
PROCESSES = ("poisson", "mmpp", "diurnal", "flash", "replay")
MODES = ("sim", "live")
COST_KINDS = ("roofline", "calibrated")
AUTOSCALERS = ("backlog",)
PARTITION_POLICIES = ("knee", "explicit")


def _from_dict(cls, data, where: str):
    """Construct a spec dataclass from a plain dict, rejecting unknown
    keys with the list of known fields (the actionable-error contract)."""
    if not isinstance(data, dict):
        raise ValueError(f"{where} must be a JSON object, got {type(data).__name__}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - known)
    if unknown:
        raise ValueError(
            f"unknown {where} field(s) {unknown} (known: {sorted(known)})")
    return cls(**data)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """What arrives: a named tenant mix driven by an arrival process.

    Offered load is either absolute (``rate_hz``) or capacity-anchored
    (``rho``: the fraction of the configured fleet's estimated space_time
    capacity — one number that means the same pressure for any mix or
    fleet shape). Exactly one of the two applies; ``rho`` wins when both
    are unset via its default.

    The live-mode fields (``arch``, ``prompt_tokens``, ``max_new_tokens``)
    only matter under ``SystemSpec(mode="live")``, where ``events`` is the
    total request count spread round-robin over ``tenants``.
    """

    mix: str = "sgemm"             # sgemm | fleet (Zipf) | serving | single
    tenants: int = 8
    process: str = "poisson"       # poisson | mmpp | diurnal | flash | replay
    events: int = 20_000
    seed: int = 0
    rho: Optional[float] = 0.7     # offered load / estimated capacity
    rate_hz: Optional[float] = None  # absolute arrivals/s (overrides rho)
    zipf_a: float = 1.1            # mix="fleet": Zipf skew of tenant weights
    slo_s: float = 0.010           # mix="single": the one SLO tier
    csv_path: Optional[str] = None  # process="replay": recorded t_s,tenant rows
    arch: str = "stablelm-1.6b"    # mode="live": model architecture
    prompt_tokens: int = 8         # mode="live": prompt length per request
    max_new_tokens: int = 8        # mode="live": decode budget per request

    def __post_init__(self) -> None:
        if self.mix not in MIXES:
            raise ValueError(f"unknown mix {self.mix!r} (have {MIXES})")
        if self.process not in PROCESSES:
            raise ValueError(
                f"unknown arrival process {self.process!r} (have {PROCESSES})")
        if self.tenants < 1:
            raise ValueError(f"tenants must be >= 1, got {self.tenants}")
        if self.events < 0:
            raise ValueError(f"events must be >= 0, got {self.events}")
        if self.process == "replay" and not self.csv_path:
            raise ValueError('process="replay" needs csv_path (rows of "t_s,tenant")')
        if self.process != "replay":
            if self.rate_hz is not None:
                if self.rate_hz <= 0:
                    raise ValueError(f"rate_hz must be > 0, got {self.rate_hz}")
            elif self.rho is None:
                raise ValueError("set rho (capacity fraction) or rate_hz (absolute)")
            elif self.rho <= 0:
                raise ValueError(f"rho must be > 0, got {self.rho}")
        if self.zipf_a < 0:
            raise ValueError(f"zipf_a must be >= 0, got {self.zipf_a}")
        if self.slo_s <= 0:
            raise ValueError(f"slo_s must be > 0, got {self.slo_s}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict) -> "WorkloadSpec":
        return _from_dict(cls, data, "workload")


@dataclasses.dataclass(frozen=True)
class AutoscaleSpec:
    """Elastic-fleet policy (repro.sim.autoscale) in declarative form."""

    policy: str = "backlog"
    min_replicas: int = 1
    max_replicas: int = 8
    up_backlog_s: float = 0.010
    down_backlog_s: float = 0.002
    interval_s: float = 0.1
    cooldown_ticks: int = 2
    spinup_s: float = 0.0

    def __post_init__(self) -> None:
        if self.policy not in AUTOSCALERS:
            raise ValueError(
                f"unknown autoscaler {self.policy!r} (have {AUTOSCALERS})")
        # range/ordering constraints are owned by the controller itself —
        # construct one so spec validation and runtime agree exactly
        self.build()

    def build(self):
        from repro.sim.autoscale import make_autoscaler

        kwargs = dataclasses.asdict(self)
        kwargs.pop("policy")
        return make_autoscaler(self.policy, **kwargs)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict) -> "AutoscaleSpec":
        return _from_dict(cls, data, "fleet.autoscale")


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """How many replicas, of what hardware, grown how.

    ``replicas`` is the fleet size at trace start; ``specs`` (names from
    ``launch.roofline.HARDWARE_SPECS``, cycled over replica ids) makes
    the fleet heterogeneous; ``autoscale`` makes it elastic between the
    policy's min/max. One replica with no specs/autoscale builds the solo
    ``Simulator``; anything else builds the ``FleetSimulator``.

    ``workers > 1`` shards the replica pumps across that many forked
    processes (``repro.sim.shard``) — byte-identical metrics, restricted
    to independent-replica configurations (round-robin router, no
    autoscale, fixed batching window); ``SystemSpec`` validates the
    combination eagerly.
    """

    replicas: int = 1
    specs: Optional[Tuple[str, ...]] = None
    autoscale: Optional[AutoscaleSpec] = None
    workers: int = 1

    def __post_init__(self) -> None:
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.workers < 1:
            raise ValueError(f"fleet.workers must be >= 1, got {self.workers}")
        if self.specs is not None:
            if not self.specs:
                raise ValueError("fleet.specs must be non-empty when given")
            object.__setattr__(self, "specs", tuple(self.specs))
            for name in self.specs:
                if not isinstance(name, str):
                    raise ValueError(
                        "fleet.specs entries must be HARDWARE_SPECS names "
                        f"(JSON-portable), got {name!r}")
                resolve_spec(name)  # raises the names-listing ValueError

    @property
    def is_fleet(self) -> bool:
        return (self.replicas > 1 or self.specs is not None
                or self.autoscale is not None or self.workers > 1)

    @property
    def max_replicas(self) -> int:
        """Largest replica count this spec can reach (capacity anchor)."""
        if self.autoscale is not None:
            return max(self.replicas, self.autoscale.max_replicas)
        return self.replicas

    def to_dict(self) -> Dict:
        return {
            "replicas": self.replicas,
            "specs": list(self.specs) if self.specs is not None else None,
            "autoscale": self.autoscale.to_dict() if self.autoscale else None,
            "workers": self.workers,
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "FleetSpec":
        data = dict(data) if isinstance(data, dict) else data
        if isinstance(data, dict) and isinstance(data.get("autoscale"), dict):
            data["autoscale"] = AutoscaleSpec.from_dict(data["autoscale"])
        if isinstance(data, dict) and data.get("specs") is not None:
            data["specs"] = tuple(data["specs"])
        return _from_dict(cls, data, "fleet")


@dataclasses.dataclass(frozen=True)
class RouterSpec:
    """Which replica each arrival goes to (repro.sim.router registry)."""

    policy: str = "jsq"

    def __post_init__(self) -> None:
        if self.policy not in ROUTERS:
            raise ValueError(f"unknown router {self.policy!r} (have {ROUTERS})")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict) -> "RouterSpec":
        return _from_dict(cls, data, "router")


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """The real scheduling core's knobs — mirrors ``ScheduleConfig``
    field-for-field, so a spec file documents exactly what the scheduler
    will run with and validation is ScheduleConfig's own."""

    batching_window_s: float = 0.002
    batching_policy: str = "fixed"
    min_batching_window_s: float = 0.0
    slo_slack_fraction: float = 0.25
    max_pending_per_tenant: Optional[int] = None
    admission_policy: str = "cap"
    oversubscription: float = 1.0
    deadline_lead_fraction: float = 0.5
    preemption: bool = False
    preemption_budget_s: float = 0.010
    max_superkernel_size: int = 128
    r_bucketing: str = "pow2"
    straggler_eviction_ratio: float = 1.5
    latency_ewma_alpha: float = 0.2
    default_slo_s: float = 0.100
    allow_ragged_merge: bool = False

    def __post_init__(self) -> None:
        self.to_schedule_config()  # ScheduleConfig owns the validation

    def to_schedule_config(self) -> ScheduleConfig:
        return ScheduleConfig(**dataclasses.asdict(self))

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict) -> "SchedulerSpec":
        return _from_dict(cls, data, "scheduler")


@dataclasses.dataclass(frozen=True)
class CostModelSpec:
    """How a super-dispatch is priced (repro.sim.costmodel).

    ``kind="roofline"`` is the analytical prior over the named hardware;
    ``kind="calibrated"`` loads a fitted ``CalibratedCostModel`` table
    (``calibration_path``, produced by ``python -m repro calibrate`` or a
    live ``dynamic_trace --calibrate`` run) over that prior.
    ``compile_us > 0`` wraps the model in per-replica compile-cache
    cold-start accounting (``ColdStartCostModel``). On heterogeneous
    fleets (``fleet.specs``) each replica prices through its OWN
    hardware's roofline; ``hardware`` then only anchors capacity.
    """

    kind: str = "roofline"
    hardware: str = "v5e"
    strategy: str = "space_time"
    small_kernel_efficiency: float = 0.45
    compile_us: float = 0.0
    calibration_path: Optional[str] = None
    ewma_alpha: float = 0.2
    # Bayesian shrinkage toward the roofline prior for sparse calibrated
    # keys: a fitted (bucket, R) cost observed n times prices as
    # (n*fitted + k*prior)/(n + k) with k = prior_strength. 0 = off
    # (fitted values win outright, the pre-shrinkage behavior).
    prior_strength: float = 0.0
    # per-replica measured-cost tables (FleetCalibrator): fleet and live
    # runs LOAD this file when it exists (fresh replicas start from
    # persisted tables instead of cold EWMAs) and live runs SAVE the
    # fitted tables back on completion. Sim runs never write it — the
    # byte-identical rerun contract must not depend on run count.
    fleet_calibration_path: Optional[str] = None

    def __post_init__(self) -> None:
        if self.kind not in COST_KINDS:
            raise ValueError(f"unknown cost model kind {self.kind!r} "
                             f"(have {COST_KINDS})")
        resolve_spec(self.hardware)  # raises the names-listing ValueError
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r} (have {STRATEGIES})")
        if not (0.0 < self.small_kernel_efficiency <= 1.0):
            raise ValueError("small_kernel_efficiency must be in (0, 1], got "
                             f"{self.small_kernel_efficiency}")
        if self.compile_us < 0.0:
            raise ValueError(f"compile_us must be >= 0, got {self.compile_us}")
        if self.prior_strength < 0.0:
            raise ValueError(
                f"prior_strength must be >= 0, got {self.prior_strength} "
                "(pseudo-observations of the roofline prior)")
        if self.kind == "calibrated" and not self.calibration_path:
            raise ValueError(
                'kind="calibrated" needs calibration_path (a table saved by '
                "CalibratedCostModel.save / `python -m repro calibrate`)")
        if self.fleet_calibration_path is not None:
            if not isinstance(self.fleet_calibration_path, str) \
                    or not self.fleet_calibration_path:
                raise ValueError(
                    "fleet_calibration_path must be a non-empty path "
                    f"(got {self.fleet_calibration_path!r}); it names the "
                    "JSON file FleetCalibrator.save writes")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict) -> "CostModelSpec":
        return _from_dict(cls, data, "cost_model")


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Fractional spatial shares (``repro.partition``), declaratively.

    ``policy="knee"`` runs the deterministic planner at build time: one
    slice per workload bucket, sized at its throughput knee and grown
    only as far as deadline feasibility demands, batch windows
    co-optimized (``repro.partition.planner``). ``policy="explicit"``
    takes ``shares`` verbatim — one fraction per slice, tenants assigned
    round-robin (``tenant_id % len(shares)``).

    Partitioning is simulator-only (real chips expose no share knob
    here) and single-process; ``SystemSpec`` validates those pairings
    eagerly. ``replan_interval_s > 0`` re-runs the planner at fixed
    simulated intervals from each slice's OBSERVED mean merged batch
    size, swapping slice sizes mid-run — every re-plan lands in the
    metrics JSON and the flight-recorder timeline.
    """

    policy: str = "knee"
    shares: Optional[Tuple[float, ...]] = None   # explicit: per-slice
    share_grid: Optional[Tuple[float, ...]] = None  # knee: candidates
    knee_fraction: float = 0.9
    min_share: float = 0.0625
    slack_fraction: float = 0.5
    replan_interval_s: float = 0.0

    def __post_init__(self) -> None:
        if self.policy not in PARTITION_POLICIES:
            raise ValueError(
                f"unknown partition policy {self.policy!r} "
                f"(have {PARTITION_POLICIES})")
        if self.policy == "explicit" and not self.shares:
            raise ValueError(
                'partition.policy="explicit" needs shares (per-slice '
                "fractions of one chip, e.g. [0.5, 0.25, 0.25])")
        if self.policy == "knee" and self.shares is not None:
            raise ValueError(
                "partition.shares only applies to policy='explicit' "
                "(the knee planner derives shares); drop shares or set "
                "policy='explicit'")
        if self.shares is not None:
            shares = tuple(float(s) for s in self.shares)
            object.__setattr__(self, "shares", shares)
            for s in shares:
                if not (0.0 < s <= 1.0):
                    raise ValueError(
                        f"partition shares must be in (0, 1], got {s}")
            total = sum(shares)
            if total > 1.0 + 1e-9:
                raise ValueError(
                    f"partition shares sum to {total:g} > 1.0; shares "
                    f"are fractions of ONE chip — scale them down")
        if self.share_grid is not None:
            grid = tuple(float(s) for s in self.share_grid)
            object.__setattr__(self, "share_grid", grid)
            if not grid or any(not (0.0 < s <= 1.0) for s in grid) \
                    or list(grid) != sorted(set(grid)):
                raise ValueError(
                    "partition.share_grid must be strictly ascending "
                    f"fractions in (0, 1], got {list(grid)}")
        if not (0.0 < self.knee_fraction <= 1.0):
            raise ValueError(
                f"partition.knee_fraction must be in (0, 1], got "
                f"{self.knee_fraction}")
        if not (0.0 < self.min_share <= 1.0):
            raise ValueError(
                f"partition.min_share must be in (0, 1], got "
                f"{self.min_share}")
        if not (0.0 <= self.slack_fraction <= 1.0):
            raise ValueError(
                f"partition.slack_fraction must be in [0, 1], got "
                f"{self.slack_fraction}")
        if self.replan_interval_s < 0.0:
            raise ValueError(
                f"partition.replan_interval_s must be >= 0, got "
                f"{self.replan_interval_s}")

    def to_dict(self) -> Dict:
        return {
            "policy": self.policy,
            "shares": list(self.shares) if self.shares is not None else None,
            "share_grid": (list(self.share_grid)
                           if self.share_grid is not None else None),
            "knee_fraction": self.knee_fraction,
            "min_share": self.min_share,
            "slack_fraction": self.slack_fraction,
            "replan_interval_s": self.replan_interval_s,
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "PartitionSpec":
        data = dict(data) if isinstance(data, dict) else data
        if isinstance(data, dict):
            for key in ("shares", "share_grid"):
                if data.get(key) is not None:
                    data[key] = tuple(data[key])
        return _from_dict(cls, data, "partition")


@dataclasses.dataclass(frozen=True)
class ObservabilitySpec:
    """The flight recorder (repro.obs), declaratively. Off by default —
    recorder-off runs are byte-identical to pre-recorder builds.

    ``window_s`` is the telemetry tick (rolling p50/p95, backlog,
    utilization, SLO attainment per window) — 1 simulated millisecond by
    default, sized to the microsecond-scale dispatches the sims model;
    ``per_request`` adds one request span per completion to the trace
    (turn off to shrink traces to dispatch granularity on huge runs);
    ``trace_path``, when set, writes the Chrome ``trace_event`` JSON
    there after ``run()`` — loadable in Perfetto (ui.perfetto.dev) or
    ``chrome://tracing``.
    """

    enabled: bool = False
    window_s: float = 0.001
    per_request: bool = True
    trace_path: Optional[str] = None

    def __post_init__(self) -> None:
        if self.window_s <= 0:
            raise ValueError(
                f"observability.window_s must be > 0, got {self.window_s}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict) -> "ObservabilitySpec":
        return _from_dict(cls, data, "observability")


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """The complete declarative experiment (see module docstring)."""

    mode: str = "sim"
    workload: WorkloadSpec = dataclasses.field(default_factory=WorkloadSpec)
    fleet: FleetSpec = dataclasses.field(default_factory=FleetSpec)
    router: RouterSpec = dataclasses.field(default_factory=RouterSpec)
    # None = each executor's own defaults (ScheduleConfig() for sims, the
    # engine-derived greedy schedule for live runs)
    scheduler: Optional[SchedulerSpec] = None
    cost_model: CostModelSpec = dataclasses.field(default_factory=CostModelSpec)
    observability: ObservabilitySpec = dataclasses.field(
        default_factory=ObservabilitySpec)
    # None = whole-chip execution; a PartitionSpec carves every replica
    # into fractional spatial slices (repro.partition)
    partition: Optional[PartitionSpec] = None

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r} (have {MODES})")
        if self.partition is not None:
            if self.mode == "live":
                raise ValueError(
                    "mode='live' cannot combine with partition: "
                    "fractional spatial shares are a simulator-only "
                    "resource model (no live slice API); use mode='sim'")
            if self.fleet.workers > 1:
                raise ValueError(
                    "fleet.workers > 1 cannot combine with partition: "
                    "co-located partition pumps share per-chip state the "
                    "shard merge does not replay; set fleet.workers=1")
            if self.fleet.autoscale is not None:
                raise ValueError(
                    "partition cannot combine with fleet.autoscale: the "
                    "plan carves a fixed replica set and scale events "
                    "would need mid-run re-planning (see ROADMAP); drop "
                    "one")
            if self.fleet.specs is not None:
                raise ValueError(
                    "partition cannot combine with fleet.specs: slices "
                    "are carved from ONE base hardware "
                    "(cost_model.hardware); drop fleet.specs")
        if self.mode == "live":
            # the live fleet runs the same PumpCore/router stack as the
            # simulator — replicas, hetero specs, feasibility admission
            # and preemption are all valid. Only process-level features
            # stay sim-only:
            if self.fleet.workers > 1:
                raise ValueError(
                    "mode='live' cannot combine with fleet.workers > 1: "
                    "sharded forked execution is a simulator-only "
                    "optimization (replicas already execute real work)")
            if self.fleet.autoscale is not None:
                raise ValueError(
                    "mode='live' does not support fleet.autoscale yet: "
                    "live elasticity means provisioning real engines "
                    "mid-run (a deployment concern — see ROADMAP); fix "
                    "the replica count")
        if self.fleet.specs is not None and self.cost_model.kind == "calibrated":
            raise ValueError(
                "cost_model.kind='calibrated' cannot combine with "
                "fleet.specs: heterogeneous replicas price through their "
                "own per-hardware rooflines, and per-replica calibrated "
                "tables (FleetCalibrator) are not spec-addressable yet "
                "(see ROADMAP); drop fleet.specs or use kind='roofline'")
        if self.fleet.workers > 1:
            # sharded execution needs provably independent replicas —
            # same conditions repro.sim.shard enforces at run time, but
            # surfaced at spec-load time per the front-door contract
            if self.router.policy != "round_robin":
                raise ValueError(
                    "fleet.workers > 1 requires router.policy="
                    "'round_robin' (state-oblivious routing keeps "
                    f"replicas independent); got {self.router.policy!r}")
            if self.fleet.autoscale is not None:
                raise ValueError(
                    "fleet.workers > 1 cannot combine with fleet.autoscale:"
                    " scale decisions read fleet-wide state; drop one")
            if (self.scheduler is not None
                    and self.scheduler.batching_policy != "fixed"):
                raise ValueError(
                    "fleet.workers > 1 requires the fixed batching window "
                    "(scheduler.batching_policy='fixed'); got "
                    f"{self.scheduler.batching_policy!r}")
            if (self.scheduler is not None
                    and self.scheduler.admission_policy != "cap"):
                raise ValueError(
                    "fleet.workers > 1 requires admission_policy='cap' "
                    "(feasibility admission reads per-replica committed "
                    "horizons the shard merge does not replay); got "
                    f"{self.scheduler.admission_policy!r}")
            if self.cost_model.fleet_calibration_path is not None:
                raise ValueError(
                    "fleet.workers > 1 cannot combine with cost_model."
                    "fleet_calibration_path: calibration reads fleet-wide "
                    "dispatch state the shard merge does not replay")
        if (self.cost_model.fleet_calibration_path is not None
                and self.mode == "sim" and not self.fleet.is_fleet):
            raise ValueError(
                "cost_model.fleet_calibration_path needs a fleet (replicas "
                "> 1, specs, or autoscale) or mode='live': the solo "
                "simulator has no per-replica tables to calibrate")

    # ----------------------------------------------------------- round trip
    def to_dict(self) -> Dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "mode": self.mode,
            "workload": self.workload.to_dict(),
            "fleet": self.fleet.to_dict(),
            "router": self.router.to_dict(),
            "scheduler": self.scheduler.to_dict() if self.scheduler else None,
            "cost_model": self.cost_model.to_dict(),
            "observability": self.observability.to_dict(),
            "partition": self.partition.to_dict() if self.partition else None,
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "SystemSpec":
        if not isinstance(data, dict):
            raise ValueError(f"spec must be a JSON object, got {type(data).__name__}")
        data = dict(data)
        version = data.pop("schema_version", SCHEMA_VERSION)
        if not isinstance(version, int):
            raise ValueError(
                f"schema_version must be an integer, got {version!r}")
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"spec schema_version {version} is newer than this build "
                f"supports ({SCHEMA_VERSION}); update the repo or re-save "
                f"the spec")
        converters = {
            "workload": WorkloadSpec.from_dict,
            "fleet": FleetSpec.from_dict,
            "router": RouterSpec.from_dict,
            "scheduler": SchedulerSpec.from_dict,
            "cost_model": CostModelSpec.from_dict,
            "observability": ObservabilitySpec.from_dict,
            "partition": PartitionSpec.from_dict,
        }
        for key, conv in converters.items():
            if isinstance(data.get(key), dict):
                data[key] = conv(data[key])
        if data.get("scheduler") is None:
            data.pop("scheduler", None)
        if data.get("partition") is None:
            data.pop("partition", None)
        return _from_dict(cls, data, "spec")

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(self.to_json() + "\n")
        os.replace(tmp, path)

    @classmethod
    def from_json(cls, text: str) -> "SystemSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "SystemSpec":
        try:
            with open(path) as fh:
                return cls.from_json(fh.read())
        except FileNotFoundError:
            raise ValueError(
                f"spec file not found: {path!r} (committed examples live "
                f"under examples/specs/)") from None

    # -------------------------------------------------------------- override
    def replace(self, **dotted) -> "SystemSpec":
        """Functional override by dotted path — the CLI's ``--set``/axis
        surface: ``spec.replace(**{"workload.events": 2000,
        "router.policy": "jsq"})`` re-validates through from_dict."""
        doc = self.to_dict()
        for path, value in dotted.items():
            node = doc
            *parents, leaf = path.split(".")
            for part in parents:
                child = node.get(part) if isinstance(node, dict) else None
                if not isinstance(child, dict):
                    # materialize defaults for absent optional sub-specs
                    # (e.g. scheduler: null) so leaves under them resolve
                    defaults = {
                        "scheduler": SchedulerSpec,
                        "autoscale": AutoscaleSpec,
                        "partition": PartitionSpec,
                    }.get(part)
                    if not isinstance(node, dict) or defaults is None:
                        raise ValueError(
                            f"cannot set {path!r}: {part!r} is not a spec "
                            f"section")
                    child = defaults().to_dict()
                    node[part] = child
                node = child
            if leaf not in node:
                raise ValueError(
                    f"cannot set {path!r}: unknown field {leaf!r} "
                    f"(known: {sorted(node)})")
            node[leaf] = value
        return SystemSpec.from_dict(doc)

    # ----------------------------------------------------------------- build
    def build(self):
        """Assemble the executor this spec's shape calls for: solo
        ``Simulator`` / ``FleetSimulator`` / engine-backed ``LiveFleet``
        behind a uniform ``run() -> RunReport`` surface."""
        from repro.api.build import FleetRun, LiveRun, SimRun

        if self.mode == "live":
            return LiveRun(self)
        if self.partition is not None or self.fleet.is_fleet:
            # a partitioned solo replica is still a fleet of co-located
            # slice pumps sharing one chip's timeline
            return FleetRun(self)
        return SimRun(self)

    def run(self):
        """One-shot convenience: ``build()`` then ``run()``."""
        return self.build().run()


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """The HTTP front door (``python -m repro serve``), declaratively.

    ``system`` describes the fleet behind the endpoints and must be a
    live spec — the server routes every ``POST /v1/predict`` through the
    same ``LiveFleet`` a ``simulate`` run of that spec would build, so
    capacity planning done in sim transfers to the deployed shape.
    ``workload`` fields still matter: tenants define the request classes
    (bucket/SLO per tenant id), ``arch`` picks the engine.

    ``report_path``, when set, receives the schema-versioned ``RunReport``
    JSON on graceful shutdown — the serve-smoke CI contract.
    """

    system: SystemSpec = dataclasses.field(
        default_factory=lambda: SystemSpec(mode="live"))
    host: str = "127.0.0.1"
    port: int = 8077
    report_path: Optional[str] = None
    request_timeout_s: float = 30.0   # per-request wait on the done event
    poll_interval_s: float = 0.050    # pump-thread heartbeat upper bound

    def __post_init__(self) -> None:
        if self.system.mode != "live":
            raise ValueError(
                "serve.system must have mode='live' (a server cannot fan "
                "out over simulated replicas); got "
                f"mode={self.system.mode!r}")
        if not (0 <= self.port < 65536):
            # port 0 binds an OS-assigned free port (tests / CI smoke)
            raise ValueError(f"port must be in [0, 65536), got {self.port}")
        if self.request_timeout_s <= 0:
            raise ValueError(
                f"request_timeout_s must be > 0, got {self.request_timeout_s}")
        if self.poll_interval_s <= 0:
            raise ValueError(
                f"poll_interval_s must be > 0, got {self.poll_interval_s}")

    def to_dict(self) -> Dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "system": self.system.to_dict(),
            "host": self.host,
            "port": self.port,
            "report_path": self.report_path,
            "request_timeout_s": self.request_timeout_s,
            "poll_interval_s": self.poll_interval_s,
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "ServeSpec":
        if not isinstance(data, dict):
            raise ValueError(
                f"serve spec must be a JSON object, got {type(data).__name__}")
        data = dict(data)
        data.pop("schema_version", None)
        if isinstance(data.get("system"), dict):
            data["system"] = SystemSpec.from_dict(data["system"])
        return _from_dict(cls, data, "serve")

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ServeSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "ServeSpec":
        try:
            with open(path) as fh:
                return cls.from_json(fh.read())
        except FileNotFoundError:
            raise ValueError(
                f"serve spec file not found: {path!r} (committed examples "
                f"live under examples/specs/)") from None

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(self.to_json() + "\n")
        os.replace(tmp, path)
