"""The paper's primary contribution: dynamic space-time kernel scheduling.

Components (paper section 4):
    queue        -- shape-bucketed kernel arrival queue
    superkernel  -- inter-model batched super-kernel builder + compile cache
    strategies   -- the four multiplexing strategies under comparison
                    (exclusive / time-only / space-only / space-time)
    scheduler    -- DynamicSpaceTimeScheduler: batching window, SLO-aware
                    dispatch, straggler eviction
    tenancy      -- multi-tenant model/weight store (stacked pytrees)
    slo          -- per-tenant latency EWMA + predictability metrics
"""

from repro.core.queue import GemmProblem, KernelQueue, ShapeBucket  # noqa: F401
from repro.core.scheduler import DynamicSpaceTimeScheduler  # noqa: F401
from repro.core.superkernel import SuperKernelCache  # noqa: F401
from repro.core.tenancy import TenantManager, stack_params, unstack_params  # noqa: F401
