"""Deterministic replica autoscaling from the simulated load signal.

The elasticity half of the fleet story: the simulator already shows WHERE
to route work on a fixed fleet; this module decides HOW MANY replicas the
fleet should be running, from the same replica state routers read — so
the policy that looks good here transfers to a production control loop
reading the same signals (queue depths, backlog seconds).

``BacklogAutoscaler`` is a textbook hysteresis controller evaluated at
fixed control-loop ticks of simulated time:

    signal  = mean per-active-replica backlog seconds (``backlog_s``) —
              residual busy time plus the estimated cost of everything
              queued, i.e. "how many seconds behind is the average
              replica right now"
    up      : signal > up_backlog_s   and active < max_replicas
    down    : signal < down_backlog_s and active > min_replicas
    step    : one replica per decision, then ``cooldown_ticks`` quiet
              ticks — rate limiting is what keeps bursty arrivals (MMPP)
              from flapping the fleet

Spin-up is NOT free: the fleet simulator answers a scale-up with a FRESH
replica — new ``replica_id``, empty compile cache (every super-kernel
variant recompiles on it: the full cold-start bill), and a clock that
only starts accepting work ``spinup_s`` after the decision (container /
weights-load latency). Scale-down retires the replica whose drain cost
(backlog seconds priced via its own table — ``pick_scale_down``) is
lowest, the newest on ties: it stops receiving arrivals and drains what
it already owns. Both directions are
pure functions of seeded simulator state, so autoscaled fleets keep the
byte-identical-JSON determinism contract, scale-event timeline included.

The thresholds are in seconds of backlog — SLO-denominated, not
throughput-denominated — because the paper's (and Zhao et al.'s) framing
is latency predictability: scale when predicted queueing delay threatens
the SLO, not when utilization looks big.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One autoscale decision that changed the fleet."""

    t_s: float          # simulated time of the control tick
    action: str         # "up" | "down"
    replica_id: int     # the replica spawned or retired
    active: int         # active replica count AFTER the event
    signal: float       # backlog signal (seconds) that triggered it

    def to_dict(self) -> Dict:
        return {"t_s": self.t_s, "action": self.action,
                "replica_id": self.replica_id, "active": self.active,
                "signal_backlog_s": self.signal}


class Autoscaler:
    """Decides the desired active-replica count at each control tick."""

    name: str = "base"
    interval_s: float = 0.1     # control-loop period (simulated seconds)
    spinup_s: float = 0.0       # delay before a new replica takes work

    def decide(self, replicas: Sequence, now: float) -> int:
        """Return the desired ACTIVE count given the live replica state.

        Must be a deterministic pure function of (replica state, own
        state); the fleet applies at most the returned delta and records
        a ``ScaleEvent`` per replica changed."""
        raise NotImplementedError


class BacklogAutoscaler(Autoscaler):
    """Hysteresis controller on mean per-replica backlog seconds."""

    name = "backlog"

    def __init__(
        self,
        min_replicas: int = 1,
        max_replicas: int = 8,
        up_backlog_s: float = 0.010,
        down_backlog_s: float = 0.002,
        interval_s: float = 0.1,
        cooldown_ticks: int = 2,
        spinup_s: float = 0.0,
    ):
        if not (1 <= min_replicas <= max_replicas):
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"[{min_replicas}, {max_replicas}]")
        if not (0.0 <= down_backlog_s < up_backlog_s):
            raise ValueError(
                "need 0 <= down_backlog_s < up_backlog_s (the hysteresis "
                f"band), got [{down_backlog_s}, {up_backlog_s}]")
        if interval_s <= 0.0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        if spinup_s < 0.0:
            raise ValueError(f"spinup_s must be >= 0, got {spinup_s}")
        self.min_replicas = int(min_replicas)
        self.max_replicas = int(max_replicas)
        self.up_backlog_s = float(up_backlog_s)
        self.down_backlog_s = float(down_backlog_s)
        self.interval_s = float(interval_s)
        self.cooldown_ticks = int(cooldown_ticks)
        self.spinup_s = float(spinup_s)
        self._cooldown = 0
        self.last_signal = 0.0

    def decide(self, replicas: Sequence, now: float) -> int:
        n = len(replicas)
        self.last_signal = sum(r.backlog_s(now) for r in replicas) / n
        if self._cooldown > 0:
            self._cooldown -= 1
            return n
        if self.last_signal > self.up_backlog_s and n < self.max_replicas:
            self._cooldown = self.cooldown_ticks
            return n + 1
        if self.last_signal < self.down_backlog_s and n > self.min_replicas:
            self._cooldown = self.cooldown_ticks
            return n - 1
        return n


def pick_scale_down(replicas: Sequence, now: float) -> int:
    """Index of the replica to retire: the one whose DRAIN COST is lowest.

    Drain cost is the replica's ``backlog_s(now)`` — residual busy time
    plus the estimated seconds of everything it still owns, priced
    through its own (possibly calibrated) table via the same
    ``pending_est_s`` accounting the routers read. Retiring the cheapest
    drainer keeps the most-loaded (and typically longest-warmed) caches
    serving.

    Tie-break preserves the historical policy: iterate newest→oldest with
    a strict ``<``, so equal-cost replicas still retire the NEWEST — the
    longest-warmed caches stay alive and up/down sequences on idle fleets
    are unchanged from the retire-the-newest era.
    """
    best_i = len(replicas) - 1
    best_cost = replicas[best_i].backlog_s(now)
    for i in range(len(replicas) - 2, -1, -1):
        c = replicas[i].backlog_s(now)
        if c < best_cost:
            best_i, best_cost = i, c
    return best_i


def make_autoscaler(name: str, **kwargs) -> Autoscaler:
    """Name-keyed factory (the CLI surface of this module)."""
    if name == "backlog":
        return BacklogAutoscaler(**kwargs)
    raise ValueError(f"unknown autoscaler: {name!r} (have ('backlog',))")
