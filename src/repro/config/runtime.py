"""Runtime / mesh / scheduler configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Production mesh description (pod, data, model)."""

    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axis_names

    @staticmethod
    def single_pod() -> "MeshConfig":
        return MeshConfig((16, 16), ("data", "model"))

    @staticmethod
    def two_pod() -> "MeshConfig":
        return MeshConfig((2, 16, 16), ("pod", "data", "model"))

    @staticmethod
    def host_debug() -> "MeshConfig":
        return MeshConfig((1, 1), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Dynamic space-time scheduler knobs (paper section 4)."""

    # batching window: how long the scheduler waits to accumulate matching
    # workloads before dispatching a super-kernel (seconds, injected clock).
    batching_window_s: float = 0.002
    # window policy: "fixed" holds every bucket the full window; the
    # "slo_adaptive" policy shrinks a bucket's window as any pending
    # item's slack to its SLO deadline shrinks (D-STACK-style); "edf"
    # fixes each item's ripeness at arrival from its own deadline and
    # dispatches ripe buckets earliest-deadline-first.
    batching_policy: str = "fixed"  # "fixed" | "slo_adaptive" | "edf"
    # slo_adaptive knobs: floor of the shrunken window, and the fraction
    # of remaining slack a bucket may keep waiting.
    min_batching_window_s: float = 0.0
    slo_slack_fraction: float = 0.25
    # admission control: reject submits once a tenant has this many
    # pending workloads queued (None = unbounded).
    max_pending_per_tenant: Optional[int] = None
    # admission policy: "cap" is the blind per-tenant pending cap above;
    # "feasibility" prices a candidate's completion via the cost model
    # and rejects work whose deadline cannot be met even after
    # oversubscription (DARIS-style). Requires a cost model.
    admission_policy: str = "cap"  # "cap" | "feasibility"
    # feasibility admission admits past the deadline up to
    # (oversubscription - 1) extra deadlines of predicted lateness;
    # 1.0 = admit only feasible work, 1.5 = tolerate 50% lateness.
    oversubscription: float = 1.0
    # edf knob: fraction of an item's SLO reserved as dispatch+service
    # lead; the item ripens after min(base_window, slo * (1 - lead)).
    deadline_lead_fraction: float = 0.5
    # preemption: when an unripe bucket's deadline would be missed by
    # waiting out its window, force-dispatch it ahead of ripe buckets
    # (requires batching_policy="edf"), charging the preempting tenant's
    # interference debt up to preemption_budget_s per tenant.
    preemption: bool = False
    preemption_budget_s: float = 0.010
    # maximum problems merged into one super-kernel invocation.
    max_superkernel_size: int = 128
    # R is padded up to the next bucket to bound the number of compiled
    # super-kernel variants (paper: "cache super-kernels as workloads
    # stabilize"). Power-of-two bucketing.
    r_bucketing: str = "pow2"  # "pow2" | "exact"
    # straggler eviction: tenants whose EWMA latency exceeds this multiple of
    # the cohort median get evicted to a fresh queue slot.
    straggler_eviction_ratio: float = 1.5
    latency_ewma_alpha: float = 0.2
    # SLO default (seconds) used when requests don't carry one.
    default_slo_s: float = 0.100
    # when True the scheduler may merge GEMMs of *different* shapes through
    # the grouped (ragged) kernel — beyond-paper extension (MAGMA vbatched
    # analogue).
    allow_ragged_merge: bool = False

    def __post_init__(self) -> None:
        # Fail at construction, not deep inside the pump where a negative
        # window reads as "every bucket is instantly ripe" and a size cap
        # of 0 as an infinite pop loop.
        if self.batching_window_s < 0.0:
            raise ValueError(
                f"batching_window_s must be >= 0, got {self.batching_window_s}"
            )
        if self.min_batching_window_s < 0.0:
            raise ValueError(
                "min_batching_window_s must be >= 0, "
                f"got {self.min_batching_window_s}"
            )
        if self.max_superkernel_size < 1:
            raise ValueError(
                f"max_superkernel_size must be >= 1, got {self.max_superkernel_size}"
            )
        if self.max_pending_per_tenant is not None and self.max_pending_per_tenant < 1:
            raise ValueError(
                "max_pending_per_tenant must be >= 1 or None, "
                f"got {self.max_pending_per_tenant}"
            )
        if self.admission_policy not in ("cap", "feasibility"):
            raise ValueError(
                "admission_policy must be 'cap' or 'feasibility', "
                f"got {self.admission_policy!r}"
            )
        if self.oversubscription < 1.0:
            raise ValueError(
                f"oversubscription must be >= 1.0, got {self.oversubscription}"
            )
        if not 0.0 <= self.deadline_lead_fraction <= 1.0:
            raise ValueError(
                "deadline_lead_fraction must be in [0, 1], "
                f"got {self.deadline_lead_fraction}"
            )
        if self.preemption_budget_s < 0.0:
            raise ValueError(
                f"preemption_budget_s must be >= 0, got {self.preemption_budget_s}"
            )
        if self.preemption and self.batching_policy != "edf":
            raise ValueError(
                "preemption requires batching_policy='edf', "
                f"got {self.batching_policy!r}"
            )
        if self.batching_policy == "edf" and self.allow_ragged_merge:
            raise ValueError(
                "allow_ragged_merge is incompatible with batching_policy='edf' "
                "(the ragged merge scans buckets in family order, not "
                "deadline order)"
            )


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Top-level runtime bundle consumed by launchers."""

    arch: str = "granite-3-8b"
    shape: str = "train_4k"
    mesh: MeshConfig = dataclasses.field(default_factory=MeshConfig.single_pod)
    schedule: ScheduleConfig = dataclasses.field(default_factory=ScheduleConfig)
    num_tenants: int = 1
    seed: int = 0
    # training knobs
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 300
    # remat policy: "none" | "block" | "full"
    remat: str = "block"
    checkpoint_dir: Optional[str] = None
