"""Fractional-share sweep: knee-planned partitions vs whole-chip
space-only and time-only on the paper SGEMM mix.

Every cell is a ``SystemSpec`` over the paper's three-shape SGEMM mix at
the same capacity-anchored offered load (``rho`` prices against the
whole chip's space_time capacity regardless of the cell's strategy, so
all cells face identical arrival streams). The partition cells run the
deterministic knee planner (``repro.partition``): one slice per shape
bucket, sized at its throughput knee and floored by deadline
feasibility, with batch windows co-optimized — co-located slices then
execute CONCURRENTLY on the chip's timeline, which is the fractional
generalization of the paper's space-only strategy. The baselines run
the whole chip under the classic ``space_only`` / ``time_only`` cost
strategies.

A re-planning cell (``replan_interval_s > 0``) re-runs the planner from
each slice's observed merged batch size mid-run; its assign/replan
timeline lands in the metrics JSON and the Perfetto trace. An explicit
equal-shares cell covers ``policy="explicit"``.

``--check`` (the CI ``partition-gate``) asserts:

  1. knee-planned goodput STRICTLY beats whole-chip space_only AND
     time_only (the tentpole ordering);
  2. the plan is sane: shares sum to <= 1.0 and the partition section is
     echoed in the metrics JSON;
  3. same-seed reruns are byte-identical — metrics JSON AND the exported
     Chrome trace bytes (partition events included);
  4. recorder-on metrics JSON == recorder-off metrics JSON.

The committed baseline is refreshed with the SAME arguments CI uses:

    PYTHONPATH=src python benchmarks/partition_sweep.py --events 30000 \
        --json benchmarks/baselines/BENCH_baseline_partition_sweep.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

from repro.api import PartitionSpec, SystemSpec, WorkloadSpec
from repro.sim import to_bench_json

BASELINES = ("space_only", "time_only")


def _spec(events: int, tenants: int, seed: int, rho: float,
          partition: Optional[PartitionSpec] = None,
          strategy: str = "space_time") -> SystemSpec:
    return SystemSpec(
        workload=WorkloadSpec(mix="sgemm", tenants=tenants, events=events,
                              seed=seed, rho=rho),
        partition=partition,
    ).replace(**{"cost_model.strategy": strategy})


def run(events: int = 200_000, tenants: int = 6, seed: int = 0,
        rho: float = 1.1, check: bool = False,
        json_path: Optional[str] = None) -> Dict:
    t_wall = time.perf_counter()
    sections: Dict = {}
    failures: List[str] = []

    print(f"\n=== partition_sweep: {events} events/cell, sgemm mix, "
          f"tenants={tenants}, rho={rho}, seed={seed} ===")

    cells = {
        "knee": _spec(events, tenants, seed, rho,
                      partition=PartitionSpec(policy="knee")),
        "knee_replan": _spec(events, tenants, seed, rho,
                             partition=PartitionSpec(
                                 policy="knee", replan_interval_s=0.01)),
        "explicit_thirds": _spec(
            events, tenants, seed, rho,
            partition=PartitionSpec(
                policy="explicit",
                shares=(1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0))),
        "space_only": _spec(events, tenants, seed, rho,
                            strategy="space_only"),
        "time_only": _spec(events, tenants, seed, rho,
                           strategy="time_only"),
    }

    print(f"{'cell':16s} {'goodput':>12s} {'attain':>7s} {'p95 ms':>9s} "
          f"{'util':>6s} {'slices':>7s}")
    goodput: Dict[str, float] = {}
    for name, spec in cells.items():
        m = spec.build().run_metrics()
        sections[name] = m
        s = m.summary()
        goodput[name] = s["goodput_cost_per_s"]
        part = getattr(m, "partition", None)
        slices = (len(part["plan"]["groups"]) if part else 1)
        print(f"{name:16s} {s['goodput_cost_per_s']:12.4g} "
              f"{s['slo_attainment']:7.4f} {s['p95_s']*1e3:9.3f} "
              f"{s['utilization']:6.3f} {slices:7d}")

    # ------------------------------------------------------- plan sanity
    knee_m = sections["knee"]
    plan = knee_m.partition["plan"]
    total = sum(g["share"] for g in plan["groups"])
    print(f"\nknee plan: " + ", ".join(
        f"{g['name']}={g['share']:.3f}" for g in plan["groups"])
        + f" (sum {total:.3f})")
    if total > 1.0 + 1e-9:
        failures.append(f"knee plan shares sum to {total:.6f} > 1.0")
    if "partition" not in json.loads(knee_m.to_json()):
        failures.append("partition section missing from metrics JSON")
    replans = [e for e in sections["knee_replan"].partition["events"]
               if e["action"] == "replan"]
    print(f"replan cell: {len(replans)} mid-run share change(s)")

    # --------------------------------------------------- tentpole ordering
    for baseline in BASELINES:
        ok = goodput["knee"] > goodput[baseline]
        print(f"knee > {baseline}: {ok} "
              f"({goodput['knee']:.4g} vs {goodput[baseline]:.4g})")
        if not ok:
            failures.append(
                f"knee goodput {goodput['knee']:.6g} does not beat "
                f"{baseline} {goodput[baseline]:.6g}")

    # ------------------------------------------- determinism + recorder-off
    # headline knee cell: same-seed rerun byte-identical, recorder-on must
    # not perturb the metrics, and two recorder-on runs must export
    # byte-identical Chrome trace JSON (partition events and all)
    rerun = cells["knee"].build().run_metrics()
    if rerun.to_json() != knee_m.to_json():
        failures.append("same-seed rerun of knee cell not byte-identical")
    from repro.obs.trace_export import export_chrome_trace

    traced = cells["knee"].replace(**{"observability.enabled": True})
    runs = []
    for _ in range(2):
        r = traced.build()
        m = r.run_metrics()
        runs.append((m, export_chrome_trace(r.last_recorder)))
    if runs[0][0].to_json() != knee_m.to_json():
        failures.append("recorder-on metrics differ from recorder-off")
    if runs[0][1] != runs[1][1]:
        failures.append("same-seed recorder trace bytes not identical")
    n_part_events = runs[0][1].count('"cat":"partition"')
    print(f"\ndeterminism: rerun byte-identical, trace "
          f"{len(runs[0][1])} bytes stable ({n_part_events} partition "
          f"events), recorder-off == recorder-on")
    if n_part_events < len(plan["groups"]):
        failures.append(
            f"trace carries {n_part_events} partition events, expected at "
            f"least one assign per slice ({len(plan['groups'])})")

    # ---------------------------------------------------------------- output
    if json_path:
        doc = json.loads(to_bench_json(
            "partition_sweep", sections,
            extra={"events": events, "tenants": tenants, "seed": seed,
                   "rho": rho, "knee_plan": plan,
                   "replan_events": len(replans)}))
        with open(json_path, "w") as fh:
            fh.write(json.dumps(doc, indent=2, sort_keys=True))
        print(f"wrote {json_path}")

    print(f"\ntotal wall time: {time.perf_counter() - t_wall:.1f}s")
    if failures:
        for f in failures:
            print(f"CHECK FAILED: {f}", file=sys.stderr)
        if check:
            sys.exit(1)
    elif check:
        print("checks passed: knee-planned fractional shares beat "
              "whole-chip space_only and time_only goodput; plan sums to "
              "<= 1.0; reruns byte-identical including recorder trace bytes")
    return sections


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--events", type=int, default=200_000,
                    help="arrivals per cell")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rho", type=float, default=1.1,
                    help="offered load / whole-chip space_time capacity")
    ap.add_argument("--json", default=None, help="write BENCH-style JSON here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the partition orderings hold")
    args = ap.parse_args()
    run(events=args.events, tenants=args.tenants, seed=args.seed,
        rho=args.rho, check=args.check, json_path=args.json)


if __name__ == "__main__":
    main()
