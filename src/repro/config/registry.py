"""Architecture config registry + smoke-variant derivation.

``repro.configs`` modules register themselves on import; ``get_config``
imports the package lazily so any entry point (tests, benchmarks, launchers)
sees all assigned architectures with no side-effectful global imports.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.config.model import BlockKind, ModelConfig, MoEConfig, SSMConfig

_REGISTRY: Dict[str, ModelConfig] = {}
_LOADED = False


def register_config(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY and _REGISTRY[cfg.name] != cfg:
        raise ValueError(f"conflicting re-registration of config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    global _LOADED
    if not _LOADED:
        importlib.import_module("repro.configs")
        _LOADED = True


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(_REGISTRY)}")


def list_configs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 256) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests.

    Keeps the *structure* (block pattern family, GQA ratio, gating, MoE
    top-k, SSM chunking) while shrinking every width to laptop scale:
    <=2 layers, d_model<=512, <=4 experts.
    """
    d_model = min(d_model, 512)
    if cfg.num_heads > 0:
        num_heads = min(cfg.num_heads, 4)
        # preserve GQA grouping where possible
        q_per_kv = max(1, cfg.q_per_kv)
        num_kv = max(1, num_heads // min(q_per_kv, num_heads))
    else:
        num_heads = 0
        num_kv = 0
    head_dim = (d_model // num_heads) if num_heads else 0

    moe = None
    if cfg.moe is not None:
        n_exp = min(cfg.moe.num_experts, 4)
        moe = MoEConfig(
            num_experts=n_exp,
            experts_per_token=min(cfg.moe.experts_per_token, n_exp),
            expert_d_ff=min(cfg.moe.expert_d_ff, 2 * d_model),
            router_aux_loss_weight=cfg.moe.router_aux_loss_weight,
            capacity_factor=cfg.moe.capacity_factor,
        )

    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(
            state_dim=min(cfg.ssm.state_dim, 16),
            head_dim=min(cfg.ssm.head_dim, 32),
            expand=cfg.ssm.expand,
            conv_width=cfg.ssm.conv_width,
            chunk_size=16,
        )

    pattern = None
    if cfg.block_pattern is not None:
        # keep the first occurrence of each distinct block kind, in order, so
        # the smoke test exercises every block family of the hybrid.
        seen: List[BlockKind] = []
        for b in cfg.block_pattern:
            if b not in seen:
                seen.append(b)
        pattern = tuple((seen * num_layers)[:num_layers]) if seen else None
        num_layers = len(pattern) if pattern else num_layers

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        vocab_size=min(cfg.vocab_size, 1024),
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 4 * d_model) if cfg.d_ff else 0,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        moe=moe,
        ssm=ssm,
        block_pattern=pattern,
        num_prefix_embeddings=min(cfg.num_prefix_embeddings, 4),
        frontend_embed_dim=min(cfg.frontend_embed_dim, d_model)
        if cfg.frontend_embed_dim
        else 0,
        dtype="float32",
    )
