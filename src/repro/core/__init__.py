"""The paper's primary contribution: dynamic space-time scheduling,
unified behind one execution core.

Every layer submits generic ``Workload`` items (shape-bucket key, cost,
tenant, SLO, execute-callback) through the same scheduler — single GEMMs
at the kernel layer, prefill/decode cohorts at the serving layer.

Components (paper section 4 + the unifying refactor):
    workload     -- the generic schedulable item (the common currency)
    clock        -- injectable time sources (wall / deterministic virtual)
    policy       -- pluggable batching windows (fixed / SLO-adaptive)
    queue        -- bucketed workload arrival queue
    superkernel  -- inter-model batched super-kernel builder + compile cache
    strategies   -- the four multiplexing strategies under comparison
                    (exclusive / time-only / space-only / space-time)
    scheduler    -- DynamicSpaceTimeScheduler: admission control, batching
                    window policy, SLO tracking, straggler eviction
    tenancy      -- multi-tenant model/weight store (stacked pytrees)
    slo          -- per-tenant latency EWMA + predictability metrics
"""

from repro.core.clock import Clock, VirtualClock, WallClock  # noqa: F401
from repro.core.policy import (  # noqa: F401
    BatchingPolicy,
    FixedWindowPolicy,
    SLOAdaptiveWindowPolicy,
    make_policy,
)
from repro.core.queue import (  # noqa: F401
    GemmProblem,
    KernelQueue,
    ShapeBucket,
    WorkQueue,
)
from repro.core.scheduler import DynamicSpaceTimeScheduler  # noqa: F401
from repro.core.superkernel import SuperKernelCache  # noqa: F401
from repro.core.tenancy import TenantManager, stack_params, unstack_params  # noqa: F401
from repro.core.workload import Workload, round_pow2  # noqa: F401
