"""Per-tenant latency tracking: EWMA, SLO attainment, predictability.

"We preserve predictability and isolation during virtualization by
monitoring inference latencies per-kernel. This allows reallocating
resources between tenants on-the-fly." (paper section 4)
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from bisect import bisect_left, insort
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class TenantLatency:
    ewma_s: Optional[float] = None
    count: int = 0
    slo_violations: int = 0
    history: List[float] = dataclasses.field(default_factory=list)

    def percentile(self, q: float) -> float:
        if not self.history:
            return 0.0
        h = sorted(self.history)
        idx = min(len(h) - 1, int(q * len(h)))
        return h[idx]

    @property
    def attainment(self) -> float:
        """Fraction of recorded latencies that met their SLO."""
        if self.count == 0:
            return 1.0
        return 1.0 - self.slo_violations / self.count


class LatencyMonitor:
    """Cohort-level latency bookkeeping + straggler detection.

    With every workload flowing through the unified scheduler, one
    monitor sees heterogeneous work (steady-state decode steps,
    compile-heavy prefills, raw kernels). ``kind`` keeps a cohort-level
    history per workload class so consumers can report percentiles for
    one class (``summary_for``) without a second monitor.
    """

    # per-kind histories are bounded (recent window) so long-running
    # serving processes don't leak a float per dispatch forever
    KIND_HISTORY_MAX = 8192

    def __init__(self, ewma_alpha: float = 0.2, eviction_ratio: float = 1.5):
        self.alpha = ewma_alpha
        self.eviction_ratio = eviction_ratio
        self.tenants: Dict[int, TenantLatency] = {}
        # every non-None tenant EWMA, kept sorted incrementally (one
        # bisect-delete + insort per update) so straggler detection after
        # each dispatch is O(log T) instead of a full re-sort — the fleet
        # sim's former per-dispatch fixed cost. All EWMA updates MUST go
        # through record/record_batch to keep this in sync.
        self._ewma_sorted: List[float] = []
        self.by_kind: Dict[str, Deque[float]] = {}
        # False = keep only the signals the scheduler acts on (EWMA,
        # counts, violations) and skip the per-item history lists. The
        # simulator flips this off: its metrics come from
        # MetricsAccumulator, and an unbounded per-tenant history is a
        # float leaked per event at million-event scale. History-derived
        # views (summary / percentiles / spread) then report empty.
        self.record_history = True

    def record(
        self, tenant_id: int, latency_s: float, slo_s: float,
        kind: str = "default",
    ) -> None:
        t = self.tenants.setdefault(tenant_id, TenantLatency())
        t.count += 1
        if latency_s > slo_s:
            t.slo_violations += 1
        srt = self._ewma_sorted
        old = t.ewma_s
        if old is None:
            t.ewma_s = latency_s
        else:
            t.ewma_s = self.alpha * latency_s + (1 - self.alpha) * old
            del srt[bisect_left(srt, old)]
        insort(srt, t.ewma_s)
        if self.record_history:
            t.history.append(latency_s)
            self.by_kind.setdefault(
                kind, collections.deque(maxlen=self.KIND_HISTORY_MAX)
            ).append(latency_s)

    def record_batch(self, items, completion_s: float) -> None:
        """Record one dispatch's completions: ``completion_s -
        item.arrival_time`` against ``item.slo_s`` per item, in batch
        order. Same arithmetic as per-item ``record`` with the dict and
        attribute traffic hoisted out of the loop — the scheduler calls
        this once per dispatch instead of once per workload.
        """
        alpha = self.alpha
        one_minus = 1 - alpha
        tenants = self.tenants
        srt = self._ewma_sorted
        keep_history = self.record_history
        by_kind = self.by_kind
        # sorted-list fixups are deferred to once per distinct tenant per
        # batch: only each tenant's final EWMA survives the batch, so the
        # resulting list is identical to per-item maintenance
        before: Dict[int, Optional[float]] = {}
        for p in items:
            latency_s = completion_s - p.arrival_time
            tid = p.tenant_id
            t = tenants.get(tid)
            if t is None:
                t = TenantLatency()
                tenants[tid] = t
            t.count += 1
            if latency_s > p.slo_s:
                t.slo_violations += 1
            e = t.ewma_s
            if tid not in before:
                before[tid] = e
            if e is None:
                t.ewma_s = latency_s
            else:
                t.ewma_s = alpha * latency_s + one_minus * e
            if keep_history:
                t.history.append(latency_s)
                kind = getattr(p, "kind", "default")
                d = by_kind.get(kind)
                if d is None:
                    d = collections.deque(maxlen=self.KIND_HISTORY_MAX)
                    by_kind[kind] = d
                d.append(latency_s)
        for tid, old in before.items():
            if old is not None:
                del srt[bisect_left(srt, old)]
            insort(srt, tenants[tid].ewma_s)

    def slo_attainment(self, tenant_id: int) -> float:
        """Per-tenant SLO attainment (1.0 for unknown tenants)."""
        t = self.tenants.get(tenant_id)
        return t.attainment if t is not None else 1.0

    def cohort_median_ewma(self) -> Optional[float]:
        # read off the incrementally-maintained sorted list; the even-n
        # arithmetic matches statistics.median exactly (byte-identical
        # eviction decisions vs the old per-call re-sort)
        srt = self._ewma_sorted
        n = len(srt)
        if n == 0:
            return None
        mid = n // 2
        return srt[mid] if n % 2 else (srt[mid - 1] + srt[mid]) / 2

    def stragglers(self) -> List[int]:
        """Tenants whose EWMA latency exceeds eviction_ratio x cohort median.

        "CUDA Stream scheduling anomalies typically only create a few
        stragglers, so we can simply evict degraded workers without
        significantly impacting total system throughput."
        """
        med = self.cohort_median_ewma()
        if med is None or med == 0.0:
            return []
        cut = self.eviction_ratio * med
        if self._ewma_sorted[-1] <= cut:
            # common case — no tenant above the cut; O(1) per dispatch
            return []
        return [
            tid
            for tid, t in self.tenants.items()
            if t.ewma_s is not None and t.ewma_s > cut
        ]

    # ------------------------------------------------------------ metrics
    def predictability_spread(self) -> float:
        """Max/min inter-tenant typical-latency gap (paper Fig 4: 25% for MPS).

        Returns (max - min) / min over each tenant's MEDIAN latency; 0 =
        perfectly uniform (predictable) cohort. Median rather than mean:
        with every workload flowing through the unified scheduler, a
        tenant's history mixes steady-state decode steps with one-off
        compile-heavy prefills, and the paper's claim is about the
        steady-state step latency the device scheduler hands each tenant.
        """
        meds = [
            statistics.median(t.history) for t in self.tenants.values() if t.history
        ]
        if len(meds) < 2 or min(meds) == 0.0:
            return 0.0
        return (max(meds) - min(meds)) / min(meds)

    @staticmethod
    def _percentiles(latencies: List[float]) -> Dict[str, float]:
        h = sorted(latencies)
        return {
            "p50_s": h[len(h) // 2],
            "p95_s": h[min(len(h) - 1, int(0.95 * len(h)))],
            "p99_s": h[min(len(h) - 1, int(0.99 * len(h)))],
            "mean_s": statistics.mean(h),
        }

    def summary_for(self, kind: str) -> Dict[str, float]:
        """Percentiles over one workload class (empty dict if unseen)."""
        lat = self.by_kind.get(kind)
        return self._percentiles(lat) if lat else {}

    def summary(self) -> Dict[str, float]:
        all_lat = [x for t in self.tenants.values() for x in t.history]
        if not all_lat:
            return {}
        out = self._percentiles(all_lat)
        out.update({
            "num_tenants": float(len(self.tenants)),
            "spread": self.predictability_spread(),
            "slo_violations": float(
                sum(t.slo_violations for t in self.tenants.values())
            ),
        })
        return out
