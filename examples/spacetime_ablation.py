"""Ablation of the dynamic scheduler's knobs (paper section 4).

Sweeps the batching window and R-bucketing policy over a stochastic trace
and reports the latency/throughput/compile trade-off each knob controls:

  * window=0           -> per-arrival dispatch (degenerates toward
                          space-only: many small super-kernels)
  * window=inf(ish)    -> offline batching (max merge, worst latency)
  * r_bucketing=exact  -> one compile per distinct R (cold-start heavy)
  * r_bucketing=pow2   -> padded merge, log2 many compiles

    PYTHONPATH=src python examples/spacetime_ablation.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScheduleConfig
from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES
from repro.core import DynamicSpaceTimeScheduler, GemmProblem


def trace(sched: DynamicSpaceTimeScheduler, tenants=8, events=120, seed=0):
    g = PAPER_GEMM_SHAPES["resnet18_conv2_2"]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    ws = [jax.random.normal(jax.random.fold_in(key, t), (g.K, g.N), jnp.float32)
          for t in range(tenants)]
    xs = [jax.random.normal(jax.random.fold_in(key, 99 + i), (g.M, g.K), jnp.float32)
          for i in range(4)]
    lat = []
    for _ in range(events):
        for _ in range(1 + rng.poisson(1.5)):
            t = int(rng.integers(tenants))
            sched.submit(GemmProblem(tenant_id=t, x=xs[int(rng.integers(4))], w=ws[t]))
        for p in sched.pump():
            lat.append(p.completion_time - p.arrival_time)
        time.sleep(0.0002)
    for p in sched.flush():
        lat.append(p.completion_time - p.arrival_time)
    return np.asarray(lat)


def main() -> None:
    print(f"{'window_ms':>10s} {'bucketing':>10s} {'p50 ms':>8s} {'p95 ms':>8s} "
          f"{'dispatches':>11s} {'hit rate':>9s}")
    for window_s in (0.0, 0.002, 0.02):
        for bucketing in ("pow2", "exact"):
            sched = DynamicSpaceTimeScheduler(ScheduleConfig(
                batching_window_s=window_s, r_bucketing=bucketing,
                max_superkernel_size=64))
            lat = trace(sched)
            rep = sched.report()
            print(f"{window_s*1e3:10.1f} {bucketing:>10s} "
                  f"{np.percentile(lat,50)*1e3:8.2f} {np.percentile(lat,95)*1e3:8.2f} "
                  f"{rep['dispatches']:11.0f} {rep['cache_hit_rate']:9.2f}")


if __name__ == "__main__":
    main()
