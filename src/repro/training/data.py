"""Synthetic token pipeline.

A deterministic, seekable stream of pseudo-corpus token batches. The
"corpus" is a Zipf-distributed unigram mix with injected n-gram structure
(so losses actually go down during the example train runs — pure uniform
noise would pin CE at ln(V)). Supports sharding the batch dimension for
data parallelism.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticTokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    ngram_order: int = 3
    zipf_a: float = 1.2

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipf unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (ranks ** -self.zipf_a) / np.sum(ranks ** -self.zipf_a)
        # deterministic "grammar": each token has a preferred successor
        self._succ = rng.integers(0, v, size=v)
        self._succ_p = 0.5  # P(next = succ[cur]); else unigram draw

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic (tokens, labels) for a global step."""
        rng = np.random.default_rng((self.seed, step))
        B, S, v = self.batch_size, self.seq_len, self.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=B, p=self._unigram)
        follow = rng.random((B, S)) < self._succ_p
        draws = rng.choice(v, size=(B, S), p=self._unigram)
        for t in range(S):
            toks[:, t + 1] = np.where(follow[:, t], self._succ[toks[:, t]], draws[:, t])
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
