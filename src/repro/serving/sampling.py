"""Token sampling: greedy / temperature / top-k / top-p (nucleus).

Pure functions over logits batches; the engine threads a PRNG key per
step. All samplers are jit-compatible and vmappable over the tenant axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 => greedy
    top_k: int = 0             # 0 => disabled
    top_p: float = 1.0         # 1 => disabled

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def apply_top_k(logits: jax.Array, k: int) -> jax.Array:
    """Mask all but the k highest logits. logits: (..., V)."""
    if k <= 0:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits < kth, NEG_INF, logits)


def apply_top_p(logits: jax.Array, p: float) -> jax.Array:
    """Nucleus filtering: keep the smallest prefix of sorted probs >= p."""
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens whose cumulative mass (excluding themselves) < p
    keep_sorted = (cum - probs) < p
    # threshold = smallest kept logit
    thresholds = jnp.min(
        jnp.where(keep_sorted, sorted_logits, jnp.inf), axis=-1, keepdims=True
    )
    return jnp.where(logits < thresholds, NEG_INF, logits)


def sample(
    logits: jax.Array,
    params: SamplingParams,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Sample token ids from (..., V) logits."""
    if params.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("non-greedy sampling requires a PRNG key")
    logits = logits.astype(jnp.float32) / params.temperature
    logits = apply_top_k(logits, params.top_k)
    logits = apply_top_p(logits, params.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
