"""Multi-tenant inference serving runtime.

The model-level embodiment of space-time scheduling: R tenants of one
architecture run as ONE vmapped program over stacked weights/caches
(every layer's GEMMs become inter-model batched super-kernels), with a
slot-based continuous batcher feeding the decode loop. Prefill and
decode cohorts are submitted as generic ``Workload`` items through the
shared ``DynamicSpaceTimeScheduler`` core, which owns admission control,
per-tenant SLO/latency tracking, and straggler eviction.
"""

from repro.serving.engine import EngineConfig, MultiTenantEngine  # noqa: F401
from repro.serving.request import InferenceRequest, RequestState  # noqa: F401
