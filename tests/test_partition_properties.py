"""Hypothesis property tests for the fractional-share planner.

Three properties the knee machinery stands on:

* throughput is non-decreasing in the spatial share on ANY workload the
  roofline pricer can see (roofs scale with the share, overheads do
  not — more chip never slows a slice down);
* the knee is well-defined on monotone curves: it reaches the requested
  fraction of the best throughput, and raising ``knee_fraction`` can
  only move the knee up the curve;
* the planner is a pure function — byte-identical ``to_json`` across
  repeated calls for any (grid, knee_fraction, merge_size) knobs.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.api import WorkloadSpec, build_mix
from repro.launch.roofline import TPU_V5E
from repro.partition import (
    DEFAULT_SHARE_GRID,
    PlannerConfig,
    knee_share,
    plan_partitions,
    share_pricer,
    throughput_curve,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

MIX = build_mix(WorkloadSpec(mix="sgemm", tenants=6))
PRICE = share_pricer(TPU_V5E)


@given(
    widx=st.integers(min_value=0, max_value=len(MIX) - 1),
    r=st.integers(min_value=1, max_value=256),
)
def test_throughput_non_decreasing_in_share(widx, r):
    curve = throughput_curve(MIX[widx], r, PRICE, DEFAULT_SHARE_GRID)
    thrs = [thr for _, thr in curve]
    assert all(b >= a * (1.0 - 1e-12) for a, b in zip(thrs, thrs[1:])), \
        f"throughput fell as share grew: {curve}"
    assert all(thr > 0.0 for thr in thrs)


@given(
    widx=st.integers(min_value=0, max_value=len(MIX) - 1),
    r=st.integers(min_value=1, max_value=256),
    frac_lo=st.floats(min_value=0.05, max_value=0.95),
    frac_hi=st.floats(min_value=0.05, max_value=0.95),
)
def test_knee_well_defined_and_monotone_in_fraction(
        widx, r, frac_lo, frac_hi):
    curve = throughput_curve(MIX[widx], r, PRICE, DEFAULT_SHARE_GRID)
    lo, hi = sorted((frac_lo, frac_hi))
    k_lo, k_hi = knee_share(curve, lo), knee_share(curve, hi)
    # well-defined: the knee is a grid point whose throughput reaches
    # the requested fraction of the curve's best
    best = max(thr for _, thr in curve)
    by_share = dict(curve)
    for frac, knee in ((lo, k_lo), (hi, k_hi)):
        assert knee in by_share
        assert by_share[knee] + 1e-12 >= frac * best
    # a stricter fraction can only move the knee up the curve
    assert k_hi >= k_lo


@given(
    knee_fraction=st.floats(min_value=0.1, max_value=1.0),
    min_share=st.sampled_from(DEFAULT_SHARE_GRID[:4]),
    merge_size=st.integers(min_value=1, max_value=128),
)
def test_planner_byte_identical_and_subscribed(
        knee_fraction, min_share, merge_size):
    cfg = PlannerConfig(knee_fraction=knee_fraction, min_share=min_share,
                        merge_size=merge_size)
    a = plan_partitions(MIX, TPU_V5E, cfg)
    b = plan_partitions(MIX, TPU_V5E, cfg)
    assert a.to_json() == b.to_json()
    assert a.total_share <= 1.0 + 1e-9
    assert sorted(t for g in a.groups for t in g.tenants) == \
        sorted(s.tenant_id for s in MIX)
