"""Simulator speed trajectory: events/sec for solo and fleet runs.

The simulator's value is policy sweeps at scale — millions of simulated
arrivals in seconds on CPU — so its throughput is a gated deliverable,
not a nice-to-have. This benchmark times the REAL entry points
(``Simulator.run`` / ``FleetSimulator.run`` over a seeded trace,
trace generation included, exactly what a sweep pays) and emits an
events-per-second row per section:

    sim_speed/solo/events_per_s     1 pump, paper SGEMM mix, Poisson
    sim_speed/fleet/events_per_s    8 round-robin replicas, Zipf mix

Rows are gated HIGHER-IS-BETTER by ``check_regression.py`` (25%
tolerance in CI — wall-clock rows need more slack than deterministic
latency rows). Each section takes the best of ``--repeats`` runs: timing
noise is one-sided, so max-of-N is the stable statistic.

Refresh the committed baseline with the SAME arguments CI uses:

    PYTHONPATH=src python benchmarks/sim_speed.py --events 200000 \
        --fleet-events 100000 --repeats 3 \
        --json benchmarks/baselines/BENCH_baseline_sim_speed.json

Full tier (the PR-acceptance numbers): defaults time 1M solo events and
8x250K fleet events; ``--full`` adds a 100M-event solo smoke (streamed,
O(chunk) memory — it exists to prove scale, expect a few minutes).
``--workers K`` additionally times the sharded fleet path
(informational, never gated: on a single-core runner fork parallelism
measures the scheduler, not the simulator).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.sim import (
    FleetSimulator,
    PoissonTrace,
    RooflineCostModel,
    Simulator,
    estimate_capacity_hz,
    fleet_sgemm_mix,
    paper_sgemm_mix,
    to_bench_json,  # noqa: F401  (re-export parity with sibling sweeps)
)
from repro.sim.metrics import SCHEMA_VERSION

SOLO_TENANTS = 8
FLEET_TENANTS = 16
FLEET_REPLICAS = 8
RHO = 0.7


def _solo_once(events: int, seed: int) -> Tuple[float, Dict[str, float]]:
    mix = paper_sgemm_mix(SOLO_TENANTS)
    model = RooflineCostModel()
    rate = RHO * estimate_capacity_hz(mix, model)
    trace = PoissonTrace(mix, rate, events, seed=seed)
    sim = Simulator(cost_model=model)
    t0 = time.perf_counter()
    m = sim.run(trace)
    dt = time.perf_counter() - t0
    return events / dt, m.summary()


def _fleet_once(events: int, seed: int,
                workers: int = 1) -> Tuple[float, Dict[str, float]]:
    mix = fleet_sgemm_mix(FLEET_TENANTS)
    rate = RHO * FLEET_REPLICAS * estimate_capacity_hz(mix, RooflineCostModel())
    trace = PoissonTrace(mix, rate, events, seed=seed)
    fleet = FleetSimulator(FLEET_REPLICAS, router="round_robin",
                           workers=workers)
    t0 = time.perf_counter()
    m = fleet.run(trace)
    dt = time.perf_counter() - t0
    return events / dt, m.summary()


def _best_of(fn, repeats: int):
    best_rate, summary = 0.0, None
    for _ in range(max(1, repeats)):
        rate, s = fn()
        if rate > best_rate:
            best_rate, summary = rate, s
    return best_rate, summary


def run(events: int = 1_000_000, fleet_events: int = 2_000_000,
        repeats: int = 3, seed: int = 0, workers: int = 0,
        full: bool = False, json_path: Optional[str] = None,
        csv_rows=None) -> Dict[str, float]:
    t_wall = time.perf_counter()
    print(f"\n=== sim_speed: solo {events} events, fleet "
          f"{FLEET_REPLICAS}x{fleet_events // FLEET_REPLICAS} events, "
          f"best of {repeats} ===")

    rows: List[Tuple[str, float, str]] = []
    extra: Dict = {"events": events, "fleet_events": fleet_events,
                   "repeats": repeats, "seed": seed,
                   "fleet_replicas": FLEET_REPLICAS}

    solo_rate, solo_sum = _best_of(lambda: _solo_once(events, seed), repeats)
    rows.append(("sim_speed/solo/events_per_s", solo_rate, "events_per_s"))
    extra["solo_completed"] = solo_sum["completed"]
    print(f"solo : {solo_rate:12,.0f} events/s "
          f"(completed={solo_sum['completed']:.0f}, "
          f"p95={solo_sum['p95_s'] * 1e3:.3f}ms)")

    fleet_rate, fleet_sum = _best_of(
        lambda: _fleet_once(fleet_events, seed + 1), repeats)
    rows.append(("sim_speed/fleet/events_per_s", fleet_rate, "events_per_s"))
    extra["fleet_completed"] = fleet_sum["completed"]
    print(f"fleet: {fleet_rate:12,.0f} events/s "
          f"(completed={fleet_sum['completed']:.0f}, "
          f"p95={fleet_sum['p95_s'] * 1e3:.3f}ms)")

    if workers > 0:
        # informational only (never a gated suffix): fork parallelism on
        # shared CI cores measures the host, not the simulator
        sh_rate, _ = _best_of(
            lambda: _fleet_once(fleet_events, seed + 1, workers=workers),
            repeats)
        rows.append((f"sim_speed/fleet_workers{workers}/sharded_events_per_s",
                     sh_rate, "events_per_s (ungated)"))
        print(f"fleet (workers={workers}): {sh_rate:12,.0f} events/s")

    if full:
        print("\n--- --full: 100M-event solo smoke (streamed) ---")
        smoke_rate, smoke_sum = _solo_once(100_000_000, seed)
        rows.append(("sim_speed/solo_100m/smoke_events_per_s", smoke_rate,
                     "events_per_s (ungated)"))
        extra["smoke_completed"] = smoke_sum["completed"]
        print(f"100M solo: {smoke_rate:12,.0f} events/s "
              f"(completed={smoke_sum['completed']:.0f})")

    if csv_rows is not None:
        csv_rows.extend(rows)
    if json_path:
        doc = {
            "benchmark": "sim_speed",
            "schema_version": SCHEMA_VERSION,
            "rows": [{"name": n, "us_per_call": v, "derived": d}
                     for n, v, d in rows],
            "extra": extra,
        }
        with open(json_path, "w") as fh:
            fh.write(json.dumps(doc, indent=2, sort_keys=True))
        print(f"\nwrote {json_path}")

    print(f"total wall time: {time.perf_counter() - t_wall:.1f}s")
    return {n: v for n, v, _ in rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--events", type=int, default=1_000_000,
                    help="solo section arrivals")
    ap.add_argument("--fleet-events", type=int, default=2_000_000,
                    help=f"fleet section arrivals (over {FLEET_REPLICAS} "
                         f"replicas)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="runs per section; best (max events/s) is reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=0,
                    help="also time the sharded fleet path with this many "
                         "worker processes (0 = skip; informational)")
    ap.add_argument("--full", action="store_true",
                    help="add the 100M-event solo smoke (minutes)")
    ap.add_argument("--json", default=None, help="write BENCH-style JSON here")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="compare against a committed baseline JSON and exit "
                         "non-zero on >tolerance events/sec regressions")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative slack for --check (default 0.25: "
                         "wall-clock rows are noisier than latency rows)")
    args = ap.parse_args()

    rates = run(events=args.events, fleet_events=args.fleet_events,
                repeats=args.repeats, seed=args.seed, workers=args.workers,
                full=args.full, json_path=args.json)

    if args.check:
        try:
            from benchmarks.check_regression import compare
        except ModuleNotFoundError:
            # invoked as `python benchmarks/sim_speed.py` rather than -m:
            # resolve the sibling module from this file's directory
            sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
            from check_regression import compare

        with open(args.check) as fh:
            base_doc = json.load(fh)
        baseline = {r["name"]: float(r["us_per_call"])
                    for r in base_doc.get("rows", [])}
        problems, gated = compare(baseline, rates, args.tolerance)
        if problems:
            print(f"REGRESSION GATE [sim_speed]: {len(problems)} problem(s) "
                  f"over {gated} gated rows", file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            print("If the slowdown is intentional, refresh the baseline "
                  "(see module docstring) and commit it.", file=sys.stderr)
            sys.exit(1)
        print(f"regression gate [sim_speed]: {gated} gated rows within "
              f"{args.tolerance * 100.0:.0f}% of baseline")


if __name__ == "__main__":
    main()
