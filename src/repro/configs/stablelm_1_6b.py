"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="stablelm-1.6b",
        source="hf:stabilityai/stablelm-2-1_6b",
        family="dense",
        num_layers=24,
        d_model=2048,
        vocab_size=100352,
        num_heads=32,
        num_kv_heads=32,
        d_ff=5632,
        rope_theta=10_000.0,
    )
)
