"""repro: Dynamic Space-Time Scheduling for accelerator inference, in JAX.

Reproduction + TPU-native extension of Jain et al., "Dynamic Space-Time
Scheduling for GPU Inference" (CS.DC 2018 / NeurIPS ML-for-Systems workshop).

Public API surface:
    repro.config      -- configuration dataclasses and registry
    repro.configs     -- assigned architecture configs
    repro.models      -- pure-JAX model substrate
    repro.kernels     -- Pallas TPU super-kernels (+ jnp reference oracles)
    repro.core        -- the paper's contribution: the space-time scheduler
    repro.sim         -- trace-driven simulation + calibrated cost models
    repro.serving     -- multi-tenant inference engine
    repro.training    -- optimizer / data / checkpoint / train loop
    repro.distributed -- sharding rules and mesh helpers
    repro.launch      -- mesh construction, dry-run, roofline, drivers
"""

__version__ = "1.0.0"
