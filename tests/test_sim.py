"""The trace-driven simulation subsystem (repro.sim): arrival processes,
roofline + calibrated cost models, the discrete-event loop over the real
scheduler, and the determinism / ordering contracts CI asserts on.

The hypothesis load-monotonicity property lives at the bottom behind the
usual importorskip guard; a plain parametrized version of the same
property runs everywhere.
"""

import json

import numpy as np
import pytest

from repro.config import ScheduleConfig
from repro.core.workload import round_pow2
from repro.sim import (
    CalibratedCostModel,
    CsvReplayTrace,
    MarkovModulatedTrace,
    PoissonTrace,
    RooflineCostModel,
    SimWorkload,
    Simulator,
    TenantSpec,
    batch_key,
    estimate_capacity_hz,
    interference_matrix,
    make_trace,
    paper_sgemm_mix,
    prefill_decode_mix,
    simulate,
)


# --------------------------------------------------------------- shared pow2
class TestRoundPow2:
    def test_values(self):
        assert [round_pow2(n) for n in (0, 1, 2, 3, 4, 5, 8, 9, 1023, 1024)] \
            == [1, 1, 2, 4, 4, 8, 8, 16, 1024, 1024]

    def test_one_definition_everywhere(self):
        """The compile cache and the cost-model keys must share ONE pow2
        helper — a live-measured (bucket, R) cost has to land in exactly
        the bucket a simulated batch of that size looks up."""
        from repro.core import superkernel

        assert superkernel._round_pow2 is round_pow2
        cache = superkernel.SuperKernelCache(ScheduleConfig(r_bucketing="pow2"))
        for r in (1, 3, 5, 9):
            assert cache._r_bucket(r) == round_pow2(r)


# ------------------------------------------------------- config validation
class TestScheduleConfigValidation:
    def test_negative_window_rejected(self):
        with pytest.raises(ValueError, match="batching_window_s"):
            ScheduleConfig(batching_window_s=-0.001)

    def test_negative_min_window_rejected(self):
        with pytest.raises(ValueError, match="min_batching_window_s"):
            ScheduleConfig(min_batching_window_s=-1.0)

    def test_size_cap_below_one_rejected(self):
        with pytest.raises(ValueError, match="max_superkernel_size"):
            ScheduleConfig(max_superkernel_size=0)

    def test_bad_pending_cap_rejected(self):
        with pytest.raises(ValueError, match="max_pending_per_tenant"):
            ScheduleConfig(max_pending_per_tenant=0)

    def test_valid_boundaries_accepted(self):
        ScheduleConfig(batching_window_s=0.0, max_superkernel_size=1,
                       max_pending_per_tenant=1)


# ------------------------------------------------------------------- traces
class TestTraces:
    def test_poisson_deterministic_ordered(self):
        mix = paper_sgemm_mix(4)
        a = list(PoissonTrace(mix, 1000.0, 500, seed=7))
        b = list(PoissonTrace(mix, 1000.0, 500, seed=7))
        assert a == b
        assert len(a) == 500
        ts = [ev.t_s for ev in a]
        assert ts == sorted(ts)
        assert list(PoissonTrace(mix, 1000.0, 500, seed=8)) != a

    def test_mmpp_ordered_and_bursty(self):
        mix = paper_sgemm_mix(2)
        evs = list(MarkovModulatedTrace(mix, calm_hz=100.0, burst_hz=5000.0,
                                        events=2000, mean_calm_s=0.5,
                                        mean_burst_s=0.1, seed=0))
        ts = np.array([ev.t_s for ev in evs])
        assert (np.diff(ts) >= 0).all()
        gaps = np.diff(ts)
        # burstiness: inter-arrival dispersion far above Poisson's CV=1
        assert gaps.std() / gaps.mean() > 1.5

    @pytest.mark.parametrize("process", ["poisson", "mmpp", "diurnal", "flash"])
    def test_factory_event_counts(self, process):
        mix = paper_sgemm_mix(3)
        evs = list(make_trace(process, mix, 2000.0, 300, seed=1))
        assert len(evs) == 300
        ts = [ev.t_s for ev in evs]
        assert ts == sorted(ts)

    def test_merge_composes_in_time_order(self):
        mix_a, mix_b = paper_sgemm_mix(2), prefill_decode_mix(1)
        merged = PoissonTrace(mix_a, 500.0, 100, seed=0) \
            + PoissonTrace(mix_b, 500.0, 100, seed=1)
        evs = list(merged)
        assert len(evs) == 200
        ts = [ev.t_s for ev in evs]
        assert ts == sorted(ts)

    def test_csv_replay(self):
        mix = paper_sgemm_mix(2)
        rows = ["# t_s,spec", "0.001,0", f"0.002,{mix[1].name}", "0.004,0"]
        evs = list(CsvReplayTrace(mix, rows))
        assert [ev.t_s for ev in evs] == [0.001, 0.002, 0.004]
        assert [ev.spec.tenant_id for ev in evs] == [0, 1, 0]

    def test_csv_replay_rejects_time_travel(self):
        mix = paper_sgemm_mix(1)
        with pytest.raises(ValueError, match="non-decreasing"):
            list(CsvReplayTrace(mix, ["0.002,0", "0.001,0"]))

    def test_weights_shape_arrival_shares(self):
        mix = prefill_decode_mix(1, decode_per_prefill=64.0)
        evs = list(PoissonTrace(mix, 1000.0, 4000, seed=0))
        decodes = sum(1 for ev in evs if ev.spec.kind == "decode")
        assert decodes / len(evs) > 0.9  # 64:1 weighting dominates


# -------------------------------------------------------------- cost models
def _batch(mix, n):
    return [SimWorkload(mix[i % len(mix)], mix[i % len(mix)].cost)
            for i in range(n)]


class TestRooflineCostModel:
    def test_strategy_ordering_guaranteed_per_batch(self):
        """The prior must price every batch with the paper's ordering."""
        for mix in (paper_sgemm_mix(6), prefill_decode_mix(3)):
            for n in (1, 2, 7, 32):
                batch = _batch(mix, n)
                t = {s: RooflineCostModel(strategy=s)(batch)
                     for s in ("time_only", "space_only", "space_time")}
                assert t["time_only"] > t["space_only"] > t["space_time"] > 0

    def test_rejects_unknown_strategy(self):
        with pytest.raises(ValueError, match="strategy"):
            RooflineCostModel(strategy="warp_speed")


class TestCalibratedCostModel:
    def test_prior_fallback_then_fitted(self):
        mix = paper_sgemm_mix(2)
        batch = _batch(mix, 4)
        model = CalibratedCostModel(ewma_alpha=0.5)
        prior = model(batch)
        assert prior == pytest.approx(RooflineCostModel()(batch))
        model.observe(batch, 1e-3)
        assert model(batch) == pytest.approx(1e-3)
        model.observe(batch, 2e-3)
        assert model(batch) == pytest.approx(1.5e-3)  # EWMA, alpha=0.5
        assert model.coverage(batch)

    def test_pow2_key_shares_compiled_variant_bucket(self):
        mix = paper_sgemm_mix(1)
        assert batch_key(_batch(mix, 5)) == batch_key(_batch(mix, 8))
        assert batch_key(_batch(mix, 5)) != batch_key(_batch(mix, 16))

    def test_json_roundtrip(self, tmp_path):
        mix = paper_sgemm_mix(3)
        model = CalibratedCostModel()
        model.observe(_batch(mix, 4), 2e-4)
        model.observe(_batch(mix, 16), 9e-4)
        path = str(tmp_path / "costs.json")
        model.save(path)
        loaded = CalibratedCostModel.load(path)
        assert loaded.table == model.table
        assert loaded.counts == model.counts
        assert loaded(_batch(mix, 4)) == pytest.approx(2e-4)

    def test_roundtrip_preserves_counts_and_keeps_ewma_updating(self):
        """Regression: persisting a fitted model must carry per-key
        observation counts, so a LOADED model stays in steady-state EWMA.
        Without the counts the warm-up schedule restarts and the first
        post-load sample wipes the whole fit (alpha_eff = 1/1 = 1)."""
        mix = paper_sgemm_mix(1)
        batch = _batch(mix, 4)
        model = CalibratedCostModel(ewma_alpha=0.2)
        for _ in range(10):  # well past the 1/alpha warm-up
            model.observe(batch, 1e-3)
        fitted = model(batch)
        assert fitted == pytest.approx(1e-3)

        loaded = CalibratedCostModel.from_json(model.to_json())
        assert loaded.counts == model.counts
        loaded.observe(batch, 5e-3)  # an outlier sample after reload
        # steady-state EWMA: 0.2*5e-3 + 0.8*1e-3 — NOT the raw 5e-3 a
        # restarted warm-up would produce
        assert loaded(batch) == pytest.approx(0.2 * 5e-3 + 0.8 * fitted)
        assert loaded(batch) != pytest.approx(5e-3)

    def test_warmup_is_cumulative_mean_then_ewma(self):
        """First 1/alpha observations average (fast convergence from the
        first sample), later ones blend at steady-state alpha."""
        mix = paper_sgemm_mix(1)
        batch = _batch(mix, 2)
        model = CalibratedCostModel(ewma_alpha=0.25)
        for s in (1e-3, 2e-3, 3e-3, 6e-3):
            model.observe(batch, s)
        assert model(batch) == pytest.approx(3e-3)  # plain mean of 4
        model.observe(batch, 7e-3)  # count 5 > 1/alpha: EWMA now
        assert model(batch) == pytest.approx(0.25 * 7e-3 + 0.75 * 3e-3)

    def test_scheduler_on_dispatch_tap(self):
        """A live scheduler feeds the calibrator through on_dispatch."""
        from repro.core import DynamicSpaceTimeScheduler, VirtualClock

        model = CalibratedCostModel()
        clock = VirtualClock()
        sched = DynamicSpaceTimeScheduler(
            ScheduleConfig(batching_window_s=0.0),
            clock=clock,
            cost_model=lambda batch: 5e-4,
            on_dispatch=model.observe,
        )
        mix = paper_sgemm_mix(1)
        for w in _batch(mix, 3):
            sched.submit(w)
        sched.flush()
        key = batch_key(_batch(mix, 3))
        assert model.table[key] == pytest.approx(5e-4)


# ---------------------------------------------------------------- simulator
SCHED = ScheduleConfig(batching_window_s=0.001, max_superkernel_size=32)


def _run(events=3000, seed=0, policy="fixed", scale=1.0, rate_hz=None, mix=None):
    mix = mix or paper_sgemm_mix(6)
    base = RooflineCostModel(strategy="space_time")
    rate = rate_hz or 0.7 * estimate_capacity_hz(mix, base)
    model = base if scale == 1.0 else (lambda b: scale * base(b))
    return simulate(
        PoissonTrace(mix, rate, events, seed=seed),
        ScheduleConfig(batching_window_s=0.001, max_superkernel_size=32,
                       batching_policy=policy),
        model,
    )


class TestSimulator:
    def test_all_events_complete(self):
        m = _run(events=2000)
        assert m.completed == 2000
        assert m.summary()["dispatches"] > 0
        assert 0.0 < m.utilization <= 1.0

    @pytest.mark.parametrize("policy", ["fixed", "slo_adaptive"])
    def test_same_seed_bit_identical_metrics_json(self, policy):
        a = _run(seed=3, policy=policy).to_json()
        b = _run(seed=3, policy=policy).to_json()
        assert a == b  # byte-identical: the determinism contract
        assert json.loads(a)["summary"]["completed"] == 3000.0

    def test_different_seed_differs(self):
        assert _run(seed=1).to_json() != _run(seed=2).to_json()

    def test_window_dispatch_happens_between_arrivals(self):
        """A lone item must dispatch at oldest+window on the virtual
        timeline, not get quantized to the next (late) arrival."""
        spec = paper_sgemm_mix(1)[0]
        rows = ["0.000,0", "0.100,0"]  # second arrival long after window
        m = simulate(CsvReplayTrace([spec], rows),
                     ScheduleConfig(batching_window_s=0.002),
                     RooflineCostModel())
        first_lat = float(m.lat[0])
        assert first_lat == pytest.approx(0.002, abs=1e-4)

    def test_overload_stamps_true_arrival_times(self):
        """Under overload the virtual clock runs ahead of arrivals;
        latency must include the queueing delay (grow without bound),
        not reset at each dispatch."""
        mix = paper_sgemm_mix(2)
        cap = estimate_capacity_hz(mix, RooflineCostModel())
        m = _run(events=4000, rate_hz=5.0 * cap, mix=mix)
        assert m.completed == 4000
        third = 4000 // 3
        assert m.lat[-third:].mean() > 3.0 * m.lat[:third].mean()

    def test_attainment_monotone_in_offered_load(self):
        """Scaling every dispatch cost up scales offered load up; SLO
        attainment must not improve (plain version of the hypothesis
        property below)."""
        att = [_run(events=2500, seed=4, scale=s).slo_attainment
               for s in (0.5, 1.0, 2.0, 4.0, 8.0)]
        for lo, hi in zip(att, att[1:]):
            assert hi <= lo + 1e-12

    def test_strategy_throughput_ordering_end_to_end(self):
        mix = paper_sgemm_mix(6)
        cap = estimate_capacity_hz(mix, RooflineCostModel())
        tput = {}
        for strat in ("space_time", "space_only", "time_only"):
            m = simulate(PoissonTrace(mix, 2.0 * cap, 4000, seed=0),
                         SCHED, RooflineCostModel(strategy=strat))
            tput[strat] = m.throughput_cost_per_s
        assert tput["space_time"] > tput["space_only"] > tput["time_only"]

    def test_serving_mix_runs_with_per_kind_metrics(self):
        m = _run(events=2000, mix=prefill_decode_mix(3))
        kinds = m.per_kind()
        assert set(kinds) == {"prefill", "decode"}
        for d in kinds.values():
            assert d["mean_s"] > 0.0
            assert 0.0 <= d["slo_attainment"] <= 1.0


# ------------------------------------------------------------------ metrics
class TestMetrics:
    def test_bench_rows_schema(self):
        rows = _run(events=1000).bench_rows("sim/test")
        assert all(len(r) == 3 for r in rows)
        names = [r[0] for r in rows]
        assert "sim/test/p95" in names and "sim/test/attainment" in names

    def test_interference_matrix_shape_and_diag(self):
        specs = paper_sgemm_mix(3)

        def run_subset(sub):
            return simulate(PoissonTrace(sub, 50_000.0, 400, seed=0),
                            SCHED, RooflineCostModel())

        M = interference_matrix(run_subset, specs)
        assert M.shape == (3, 3)
        assert np.allclose(np.diag(M), 1.0)
        assert (M > 0).all()

    def test_interference_matrix_rejects_duplicate_tenants(self):
        """Serving mixes carry two streams per tenant; the matrix is
        keyed per tenant, so duplicates must be rejected not blended."""
        specs = prefill_decode_mix(2)  # 4 specs over 2 tenant_ids
        with pytest.raises(ValueError, match="unique tenant_ids"):
            interference_matrix(lambda sub: None, specs)


# --------------------------------------------------- hypothesis (optional)
def test_attainment_monotone_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings.register_profile("sim", max_examples=15, deadline=None)
    settings.load_profile("sim")

    @given(
        seed=st.integers(0, 50),
        scales=st.lists(st.floats(0.25, 16.0), min_size=2, max_size=4),
    )
    def prop(seed, scales):
        att = [_run(events=800, seed=seed, scale=s).slo_attainment
               for s in sorted(scales)]
        for lo, hi in zip(att, att[1:]):
            assert hi <= lo + 1e-12
    prop()
