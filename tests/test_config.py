"""Config registry, parameter accounting, smoke-variant bounds."""

import pytest

from repro.config import get_config, get_shape, list_configs, smoke_variant
from repro.config.model import AttentionKind, BlockKind
from repro.configs import ASSIGNED_ARCHS

# (arch, expected total params +-15%, expected active +-15%)
EXPECTED_PARAMS = {
    "granite-moe-1b-a400m": (1.3e9, 0.4e9),
    "granite-3-8b": (8.2e9, 8.2e9),
    "qwen2-7b": (7.6e9, 7.6e9),
    "stablelm-1.6b": (1.6e9, 1.6e9),
    "gemma3-27b": (27e9, 27e9),
    "rwkv6-1.6b": (1.6e9, 1.6e9),
    "llama4-maverick-400b-a17b": (400e9, 17e9),
    "musicgen-large": (2.4e9, 2.4e9),
    "paligemma-3b": (2.5e9, 2.5e9),
    "zamba2-7b": (9.2e9, 11.7e9),  # shared-attn reuse: active FLOP-params > stored
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_plausible(arch):
    cfg = get_config(arch)
    total, active = EXPECTED_PARAMS[arch]
    assert abs(cfg.param_count() - total) / total < 0.25, cfg.param_count()
    assert abs(cfg.active_param_count() - active) / active < 0.25


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_variant_bounds(arch):
    s = smoke_variant(get_config(arch))
    assert s.num_layers <= 3
    assert s.d_model <= 512
    if s.moe:
        assert s.moe.num_experts <= 4
    assert s.vocab_size <= 1024


def test_exact_assigned_geometry():
    c = get_config("qwen2-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (28, 3584, 28, 4, 18944, 152064, True)
    g = get_config("gemma3-27b")
    assert g.sliding_window == 1024 and g.global_every == 6
    assert g.attention_kind_at(0) == AttentionKind.SLIDING
    assert g.attention_kind_at(5) == AttentionKind.FULL
    z = get_config("zamba2-7b")
    assert z.layer_pattern[5] == BlockKind.HYBRID_SHARED_ATTN
    assert sum(1 for b in z.layer_pattern if b == BlockKind.MAMBA2) == 68
    r = get_config("rwkv6-1.6b")
    assert r.num_heads == 0 and r.attention_kind == AttentionKind.NONE


def test_long_500k_eligibility():
    eligible = {a for a in ASSIGNED_ARCHS if get_config(a).is_subquadratic}
    assert eligible == {"rwkv6-1.6b", "zamba2-7b", "gemma3-27b"}


def test_shapes_table():
    assert get_shape("train_4k").kind == "train"
    assert get_shape("decode_32k").is_decode
    assert get_shape("long_500k").global_batch == 1
    with pytest.raises(KeyError):
        get_shape("nope")


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("resnet-50")


def test_all_archs_have_sources():
    for a in list_configs():
        assert get_config(a).source, a
