"""``PartitionPlan``: tenants mapped to fractional per-replica shares.

A plan names the spatial slices one physical chip is carved into and
assigns every tenant to exactly one slice. Each slice executes as its
own scheduler pump over ``HardwareSpec.sliced(share)`` — roofs scaled by
the share, launch overheads at full price — and co-located slices run
CONCURRENTLY on the chip's timeline (``repro.sim.fleet``), which is the
fractional generalization of the paper's space-only strategy.

Validation is eager and total: shares in (0, 1] summing to <= 1.0,
disjoint tenant sets, unique group names — a malformed plan fails at
construction with a one-line actionable error, never three layers into
a sweep (the ``repro.api`` spec-error contract).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

from repro.launch.roofline import HardwareSpec

# float-noise allowance on the shares-sum cap: 16 slices of 1/16 must
# validate, 0.9 + 0.2 must not
SHARE_SUM_TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class PartitionShare:
    """One spatial slice: its name, chip fraction, member tenants, and
    (optionally) the batching window the planner co-optimized for it."""

    name: str
    share: float
    tenants: Tuple[int, ...] = ()
    window_s: Optional[float] = None

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("partition group name must be non-empty")
        if not (0.0 < self.share <= 1.0):
            raise ValueError(
                f"partition share must be in (0, 1], got {self.share} "
                f"(group {self.name!r})")
        object.__setattr__(self, "tenants",
                           tuple(int(t) for t in self.tenants))
        if self.window_s is not None and self.window_s < 0.0:
            raise ValueError(
                f"partition window_s must be >= 0, got {self.window_s} "
                f"(group {self.name!r})")

    def to_dict(self) -> Dict:
        return {"name": self.name, "share": self.share,
                "tenants": list(self.tenants), "window_s": self.window_s}

    @classmethod
    def from_dict(cls, data: Dict) -> "PartitionShare":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise ValueError(
                f"unknown partition group field(s) {unknown} "
                f"(known: {sorted(known)})")
        return cls(**data)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Named slices of one chip; every replica in the fleet is carved
    identically (the per-replica unit of the plan)."""

    groups: Tuple[PartitionShare, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "groups", tuple(self.groups))
        if not self.groups:
            raise ValueError("a PartitionPlan needs at least one group")
        names = [g.name for g in self.groups]
        if len(set(names)) != len(names):
            raise ValueError(
                f"partition group names must be unique, got {names}")
        total = sum(g.share for g in self.groups)
        if total > 1.0 + SHARE_SUM_TOL:
            raise ValueError(
                f"partition shares sum to {total:g} > 1.0; shares are "
                f"fractions of ONE chip — shrink them or drop a group")
        by_tenant: Dict[int, int] = {}
        for gi, g in enumerate(self.groups):
            for t in g.tenants:
                if t in by_tenant:
                    raise ValueError(
                        f"tenant {t} assigned to two partition groups "
                        f"({self.groups[by_tenant[t]].name!r} and "
                        f"{g.name!r}); tenant sets must be disjoint")
                by_tenant[t] = gi
        object.__setattr__(self, "_by_tenant", by_tenant)

    # ------------------------------------------------------------ accessors
    @property
    def total_share(self) -> float:
        return sum(g.share for g in self.groups)

    def group_of(self, tenant_id: int) -> int:
        """Index of the group serving ``tenant_id``. Tenants the plan
        never named fall back to ``tenant_id % len(groups)`` — a
        deterministic catch-all so a plan built from one mix still routes
        a replayed trace with extra tenants instead of crashing."""
        gi = self._by_tenant.get(int(tenant_id))
        if gi is None:
            return int(tenant_id) % len(self.groups)
        return gi

    def sliced_specs(self, hardware: HardwareSpec) -> Tuple[HardwareSpec, ...]:
        """One ``HardwareSpec`` slice per group, in group order — what
        each co-located partition pump prices against."""
        return tuple(
            hardware.sliced(g.share, name=f"{hardware.name}@{g.name}"
                                          f":{g.share:g}")
            for g in self.groups)

    # ------------------------------------------------------------ round trip
    def to_dict(self) -> Dict:
        return {"groups": [g.to_dict() for g in self.groups]}

    @classmethod
    def from_dict(cls, data: Dict) -> "PartitionPlan":
        if not isinstance(data, dict) or "groups" not in data:
            raise ValueError(
                'a PartitionPlan dict needs a "groups" list '
                f"(got {sorted(data) if isinstance(data, dict) else data!r})")
        return cls(groups=tuple(
            PartitionShare.from_dict(g) for g in data["groups"]))

    def to_json(self) -> str:
        """Canonical sorted-keys JSON — the planner determinism contract
        compares these strings directly."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "PartitionPlan":
        return cls.from_dict(json.loads(text))
