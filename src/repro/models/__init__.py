"""Pure-JAX model substrate.

Every assigned architecture is assembled by ``transformer.build_model``
from one ``ModelConfig``; parameters are plain pytrees (nested dicts of
arrays), layers are pure functions, and the layer stack runs as a
``lax.scan`` over the pattern's smallest repeating unit so full-scale
dry-runs lower to compact HLO.
"""

from repro.models.transformer import Model, build_model  # noqa: F401
