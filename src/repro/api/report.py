"""The one result shape every executor returns.

A ``RunReport`` is the metrics document plus the exact spec that
produced it (echoed so a result file is self-describing and replayable)
plus the schema_version stamp shared with the BENCH exports. Solo sim,
fleet sim, and live engine runs all freeze into this — "same spec shape
in, same report shape out" is the API's contract.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict

from repro.sim.metrics import SCHEMA_VERSION


@dataclasses.dataclass(frozen=True)
class RunReport:
    """Outcome of one ``SystemSpec`` execution."""

    executor: str        # "simulator" | "fleet" | "live"
    mode: str            # spec.mode echo ("sim" | "live")
    spec: Dict           # the producing SystemSpec, as a dict
    metrics: Dict        # SimMetrics/FleetMetrics to_dict, or live report
    schema_version: int = SCHEMA_VERSION

    @property
    def summary(self) -> Dict[str, float]:
        """The headline scalar block, whatever the executor."""
        return self.metrics.get("summary", self.metrics)

    def to_dict(self) -> Dict:
        return {
            "schema_version": self.schema_version,
            "executor": self.executor,
            "mode": self.mode,
            "spec": self.spec,
            "metrics": self.metrics,
        }

    def to_json(self) -> str:
        """Canonical sorted-keys JSON — byte-identical per seed for the
        simulated executors (the same determinism contract the BENCH
        exports carry)."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(self.to_json() + "\n")
        os.replace(tmp, path)

    @classmethod
    def from_dict(cls, data: Dict) -> "RunReport":
        return cls(
            executor=data["executor"],
            mode=data["mode"],
            spec=data["spec"],
            metrics=data["metrics"],
            schema_version=int(data.get("schema_version", SCHEMA_VERSION)),
        )

    @classmethod
    def load(cls, path: str) -> "RunReport":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))
