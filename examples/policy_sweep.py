"""Policy sweep on the trace-driven simulator — no device work, seconds
on CPU, deterministic per seed.

Demonstrates the `repro.sim` workflow end-to-end:

  1. build a heterogeneous tenant mix (paper SGEMM kernels or
     engine-shaped prefill/decode cohorts);
  2. generate arrival traces from different stochastic processes
     (steady Poisson, bursty MMPP, diurnal, flash crowd);
  3. replay each trace through the REAL DynamicSpaceTimeScheduler on a
     virtual clock, priced by the roofline cost model;
  4. compare batching policies on SLO attainment / tail latency / goodput.

The point the sweep makes: neither window policy dominates. On the
serving mix (tight decode SLOs against a wide window) the adaptive
window buys large attainment gains; on the kernel mix under saturating
bursts it can LOSE throughput by giving up merging exactly when merging
matters most. Latency predictability is a policy property — which is why
these sweeps run in simulation, where the whole surface costs seconds.

    PYTHONPATH=src python examples/policy_sweep.py
"""

from repro.config import ScheduleConfig
from repro.sim import (
    RooflineCostModel,
    estimate_capacity_hz,
    make_trace,
    paper_sgemm_mix,
    prefill_decode_mix,
    simulate,
)

EVENTS = 30_000
SEED = 0


def sweep(mix_name: str, mix, rho: float) -> None:
    # offered load anchored to the mix's merged-roofline capacity, so one
    # rho means the same pressure for FLOP-priced GEMMs and byte-priced
    # decode cohorts alike
    rate_hz = rho * estimate_capacity_hz(
        mix, RooflineCostModel(strategy="space_time"), merge_size=64)
    print(f"\n=== mix={mix_name} @ rho={rho:.2f} "
          f"(~{rate_hz:,.0f} arrivals/s), {EVENTS} events/cell ===")
    print(f"{'process':>9s} {'policy':>13s} {'p50 ms':>8s} {'p95 ms':>8s} "
          f"{'attain':>7s} {'goodput':>10s}")
    for process in ("poisson", "mmpp", "diurnal", "flash"):
        for policy in ("fixed", "slo_adaptive"):
            trace = make_trace(process, mix, rate_hz, EVENTS, seed=SEED)
            m = simulate(
                trace,
                ScheduleConfig(
                    batching_window_s=0.5 * min(s.slo_s for s in mix),
                    batching_policy=policy,
                    max_superkernel_size=64,
                ),
                RooflineCostModel(strategy="space_time"),
            )
            s = m.summary()
            print(f"{process:>9s} {policy:>13s} {s['p50_s']*1e3:8.3f} "
                  f"{s['p95_s']*1e3:8.3f} {s['slo_attainment']:7.3f} "
                  f"{s['goodput_cost_per_s']:10.3g}")


def main() -> None:
    # kernel-level tenants: steady load leaves slack, only bursts bite
    sweep("sgemm", paper_sgemm_mix(8), rho=0.6)
    # engine-shaped cohorts: decode steps dominate arrivals, prefills are
    # rare and heavy — the realistic serving mix
    sweep("serving", prefill_decode_mix(4), rho=0.6)


if __name__ == "__main__":
    main()
