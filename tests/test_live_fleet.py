"""The live fleet (repro.serving.fleet) and its sim↔live parity contract.

One pump/router core serves both executors, so a ``LiveFleet`` on a
virtual clock with the no-op ``NullEngine`` must be a bit-exact twin of
``FleetSimulator``: same routing decision sequence, same admission
reason codes, same frozen metrics bytes. Everything here is jax-free
(NullEngine / FakeEngine); the real-engine smoke is opt-in via
REPRO_LIVE_JAX=1.
"""

import json
import os

import pytest

from repro.config import ScheduleConfig
from repro.core.clock import VirtualClock
from repro.obs.recorder import FlightRecorder
from repro.serving.fleet import FakeEngine, LiveFleet, NullEngine
from repro.sim import (
    FleetSimulator,
    RooflineCostModel,
    estimate_capacity_hz,
    fleet_sgemm_mix,
    make_trace,
)

SCHED = ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32)
MIX = fleet_sgemm_mix(12)
BASE = RooflineCostModel(strategy="space_time")
OFFERED_HZ = 0.85 * 3 * estimate_capacity_hz(MIX, BASE)


def _trace(events=2000, seed=0, process="mmpp"):
    return make_trace(process, MIX, OFFERED_HZ, events, seed=seed)


def _sim(router="least_cost", recorder=None, schedule=SCHED, **kw):
    return FleetSimulator(replicas=3, router=router, schedule=schedule,
                          cost_model=BASE, compile_s=1e-3,
                          recorder=recorder, **kw)


def _live(router="least_cost", recorder=None, schedule=SCHED, **kw):
    # virtual clocks + the no-result engine = the simulator's exact twin
    return LiveFleet(replicas=3, engine_factory=NullEngine, router=router,
                     schedule=schedule, cost_model=BASE, compile_s=1e-3,
                     recorder=recorder, clock_factory=VirtualClock, **kw)


# -------------------------------------------------------------- sim ↔ live
class TestParity:
    @pytest.mark.parametrize("router", ["round_robin", "jsq", "least_cost",
                                        "affinity"])
    def test_metrics_bytes_match_fleet_simulator(self, router):
        m_sim = _sim(router=router).run(_trace())
        m_live = _live(router=router).run(_trace())
        assert m_live.to_json() == m_sim.to_json()

    def test_router_decision_sequence_matches(self):
        rec_sim, rec_live = FlightRecorder(), FlightRecorder()
        _sim(recorder=rec_sim).run(_trace())
        _live(recorder=rec_live).run(_trace())
        assert rec_sim.n_routes == rec_live.n_routes == 2000
        assert list(rec_live._rt_chosen) == list(rec_sim._rt_chosen)
        assert list(rec_live._rt_price) == list(rec_sim._rt_price)

    def test_admission_reason_codes_match(self):
        # feasibility admission under heavy pressure produces a mix of
        # admit / oversubscribed / infeasible codes; the live fleet must
        # reproduce the simulator's sequence exactly, per replica
        sched = ScheduleConfig(batching_window_s=0.0005,
                               max_superkernel_size=32,
                               admission_policy="feasibility",
                               oversubscription=1.25)
        rec_sim, rec_live = FlightRecorder(), FlightRecorder()
        trace = make_trace("mmpp", MIX, 3 * OFFERED_HZ, 2000, seed=1)
        _sim(recorder=rec_sim, schedule=sched).run(trace)
        _live(recorder=rec_live, schedule=sched).run(trace)
        for rid in range(3):
            s, l = rec_sim.shards[rid], rec_live.shards[rid]
            assert list(l._arr_reason) == list(s._arr_reason)
            assert list(l._arr_admitted) == list(s._arr_admitted)
        reasons = {r for rid in range(3)
                   for r in rec_sim.shards[rid]._arr_reason}
        assert len(reasons) > 1  # the sequence actually exercised codes

    def test_routed_counts_match(self):
        sim, live = _sim(), _live()
        sim.run(_trace(seed=3))
        live.run(_trace(seed=3))
        assert live.routed_counts == sim.routed_counts


# ------------------------------------------------------------- live engines
class TestFakeEngine:
    def test_tokens_deterministic_and_replica_independent(self):
        eng0, eng1 = FakeEngine(0), FakeEngine(1)

        class W:
            tenant_id, payload = 5, [7, 8, 9]

        a, b = eng0.execute([W]), eng1.execute([W])
        assert a == b  # output is a function of (tenant, payload) only
        assert len(a[0]) == 8 and all(0 <= t < 32000 for t in a[0])

    def test_results_land_on_workloads(self):
        fleet = LiveFleet(replicas=2, engine_factory=FakeEngine,
                          schedule=SCHED, cost_model=BASE,
                          clock_factory=VirtualClock)
        done = []
        spec = MIX[0]
        w, rid, admitted, reason = fleet.submit_one(spec, spec.cost,
                                                    payload=[1, 2], t_s=0.0)
        assert admitted and reason == 0
        fleet._drain_until(1.0)
        assert w.result is not None and len(w.result) == 8
        assert w.completion_time is not None

    def test_wall_clock_run_completes(self):
        # the real serving configuration: wall clock, full-speed replay
        fleet = LiveFleet(replicas=2, engine_factory=FakeEngine,
                          schedule=SCHED, cost_model=BASE)
        m = fleet.run(_trace(events=300, seed=2),
                      payload_fn=lambda s: [s.tenant_id])
        assert m.merged.completed == 300
        assert sum(fleet.routed_counts) == 300
        assert m.router == "least_cost"


# ---------------------------------------------------------------- end to end
class TestLiveSpec:
    def _spec(self, **over):
        from repro.api.spec import SystemSpec

        doc = {
            "mode": "live",
            "workload": {"mix": "sgemm", "tenants": 4, "events": 300,
                         "seed": 3, "rate_hz": 2000.0, "arch": "fake"},
            "fleet": {"replicas": 2},
            "router": {"policy": "least_cost"},
            "scheduler": {"admission_policy": "feasibility"},
        }
        doc.update(over)
        return SystemSpec.from_dict(doc)

    def test_live_fleet_spec_builds_and_runs(self):
        # the ISSUE acceptance spec: live + fleet + least_cost + feasibility
        from repro.api.build import LiveRun

        run = self._spec().build()
        assert isinstance(run, LiveRun)
        rep = run.run()
        assert rep.executor == "live" and rep.mode == "live"
        sched = rep.metrics["scheduler"]
        assert sched["completed"] + sched["rejected"] == 300
        assert sum(rep.metrics["routed_counts"]) + sched["rejected"] == 300
        assert rep.metrics["engine"] == "fake"
        assert "p95_s" in rep.metrics["summary"]
        assert rep.metrics["schema_version"] == rep.schema_version

    def test_live_check_invariants_pass(self, tmp_path):
        from repro.api.cli import main

        path = tmp_path / "live.json"
        path.write_text(self._spec().to_json())
        assert main(["simulate", "--spec", str(path), "--check"]) == 0

    def test_calibration_saved_and_reloaded(self, tmp_path):
        calib = str(tmp_path / "fleet_calib.json")
        spec = self._spec(cost_model={"fleet_calibration_path": calib})
        spec.build().run()
        doc = json.loads(open(calib).read())
        assert sorted(doc["replicas"]) == ["0", "1"]
        # second run loads the saved tables and still completes
        rep = spec.build().run()
        assert rep.metrics["scheduler"]["completed"] > 0

    def test_sim_fleet_reads_but_never_writes_tables(self, tmp_path):
        calib = str(tmp_path / "fleet_calib.json")
        live = self._spec(cost_model={"fleet_calibration_path": calib})
        live.build().run()
        stamp = os.path.getmtime(calib)
        sim = self._spec(mode="sim",
                         cost_model={"fleet_calibration_path": calib})
        rep = sim.build().run()
        assert rep.executor == "fleet"
        assert os.path.getmtime(calib) == stamp

    @pytest.mark.skipif(not os.environ.get("REPRO_LIVE_JAX"),
                        reason="set REPRO_LIVE_JAX=1 for the jax CPU smoke")
    def test_real_engine_smoke(self):
        spec = self._spec(workload={
            "mix": "sgemm", "tenants": 2, "events": 4, "seed": 0,
            "rate_hz": 50.0, "arch": "stablelm-1.6b", "prompt_tokens": 4,
            "max_new_tokens": 4})
        rep = spec.build().run()
        assert rep.metrics["engine"] == "jax"
        assert rep.metrics["scheduler"]["completed"] == 4
