"""RWKV-6 "Finch" block: time-mix (WKV6 recurrence) + channel-mix.

Data-dependent decay: per-token decay logits w_t are produced by a small
LoRA on the token-shift-mixed input (the Finch mechanism). The recurrence
itself runs through ``repro.kernels.ops.wkv6_scan`` (Pallas on TPU).
Decode state: (wkv_state (B,H,N,P), shift_tm (B,d), shift_cm (B,d)).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.constraints import constrain
from repro.kernels import ops, ref
from repro.models import layers

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]

LORA_DIM = 64


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    N = s.head_dim if s is not None else 64
    H = cfg.d_model // N
    return H, N


def rwkv6_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    H, N = _dims(cfg)
    keys = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": (jax.random.uniform(keys[0], (5, d)) * 0.5 + 0.25).astype(dtype),  # r,k,v,g,w
        "wr": layers.dense_init(keys[1], d, d, dtype),
        "wk": layers.dense_init(keys[2], d, d, dtype),
        "wv": layers.dense_init(keys[3], d, d, dtype),
        "wg": layers.dense_init(keys[4], d, d, dtype),
        "wo": layers.dense_init(keys[5], d, d, dtype),
        "w_base": (jnp.zeros((d,)) - 4.0).astype(jnp.float32),
        "w_lora_a": layers.dense_init(keys[6], d, LORA_DIM, dtype),
        "w_lora_b": (jnp.zeros((LORA_DIM, d))).astype(dtype),
        "u": (jax.random.normal(keys[7], (H, N)) * 0.1).astype(jnp.float32),
        # channel-mix
        "mu_ck": (jax.random.uniform(keys[8], (d,)) * 0.5 + 0.25).astype(dtype),
        "mu_cr": (jax.random.uniform(keys[9], (d,)) * 0.5 + 0.25).astype(dtype),
        "ck": layers.dense_init(keys[10], d, cfg.d_ff, dtype),
        "cv": layers.dense_init(keys[11], cfg.d_ff, d, dtype),
        "cr": layers.dense_init(keys[0], d, d, dtype),
        "norm_tm": layers.rmsnorm_init(d, dtype),
        "norm_cm": layers.rmsnorm_init(d, dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, dtype) -> Cache:
    d = cfg.d_model
    H, N = _dims(cfg)
    return {
        "wkv": jnp.zeros((batch, H, N, N), jnp.float32),  # state (N keys x P=N vals)
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B,S,d); prev: (B,d) last token of previous segment."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix(params: Params, x: jax.Array, shifted: jax.Array, cfg: ModelConfig):
    """Shared projection math for scan + step paths. x: (B,S,d)."""
    H, N = _dims(cfg)
    B, S, d = x.shape
    mu = params["mu"]
    xr = x + (shifted - x) * mu[0]
    xk = x + (shifted - x) * mu[1]
    xv = x + (shifted - x) * mu[2]
    xg = x + (shifted - x) * mu[3]
    xw = x + (shifted - x) * mu[4]
    r = constrain(xr @ params["wr"], "batch", None, "model")
    k = constrain(xk @ params["wk"], "batch", None, "model")
    v = constrain(xv @ params["wv"], "batch", None, "model")
    g = jax.nn.silu(xg @ params["wg"])
    w = params["w_base"] + (jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]).astype(jnp.float32)
    return r, k, v, g, w


def time_mix_forward(
    params: Params, x: jax.Array, cfg: ModelConfig, prev: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence WKV6. Returns (out (B,S,d), last_x (B,d))."""
    H, N = _dims(cfg)
    B, S, d = x.shape
    shifted = _token_shift(x, prev)
    r, k, v, g, w = _time_mix(params, x, shifted, cfg)

    def heads(t):  # (B,S,d) -> (B*H, S, N)
        return t.reshape(B, S, H, N).transpose(0, 2, 1, 3).reshape(B * H, S, N)

    u = jnp.broadcast_to(params["u"][None], (B, H, N)).reshape(B * H, N)
    o = ops.wkv6_scan(heads(r), heads(k), heads(v), heads(w.astype(r.dtype)), u)
    o = o.reshape(B, H, S, N).transpose(0, 2, 1, 3).reshape(B, S, d)
    o = layers.groupnorm_heads(o, H) * g
    return o @ params["wo"], x[:, -1, :]


def channel_mix_forward(
    params: Params, x: jax.Array, cfg: ModelConfig, prev: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    shifted = _token_shift(x, prev)
    xk = x + (shifted - x) * params["mu_ck"]
    xr = x + (shifted - x) * params["mu_cr"]
    k = constrain(jnp.square(jax.nn.relu(xk @ params["ck"])), "batch", None, "model")
    return (k @ params["cv"]) * jax.nn.sigmoid(xr @ params["cr"]), x[:, -1, :]


def rwkv6_block(
    params: Params, x: jax.Array, cfg: ModelConfig, cache: Cache, mode: str
) -> Tuple[jax.Array, Cache]:
    """Full RWKV6 block (time-mix + channel-mix), pre-norm residual.

    mode: "train" (no state tracking), "prefill" (sequence + final state for
    serving continuity) or "decode" (S == 1, O(1) step).
    """
    new_cache = dict(cache)
    h = layers.rmsnorm(params["norm_tm"], x, cfg.norm_eps)
    if mode == "train":
        tm, _ = time_mix_forward(params, h, cfg, cache["shift_tm"])
    elif mode == "prefill":
        # Prefill honors the INCOMING wkv/shift state (zero for fresh
        # sequences; non-zero for chunked-prefill continuation), so the
        # jnp scan with init_state is used rather than the zero-init
        # Pallas kernel (kernel init-state support: future work).
        H, N = _dims(cfg)
        B, S, d = h.shape
        shifted = _token_shift(h, cache["shift_tm"])
        r, k, v, g, w = _time_mix(params, h, shifted, cfg)
        heads = lambda t: t.reshape(B, S, H, N).transpose(0, 2, 1, 3).reshape(B * H, S, N)
        state = cache["wkv"].reshape(B * H, N, N)
        u = jnp.broadcast_to(params["u"][None], (B, H, N)).reshape(B * H, N)
        o = ref.wkv6_scan(
            heads(r), heads(k), heads(v), heads(w.astype(r.dtype)), u,
            init_state=state,
        )
        o = o.reshape(B, H, S, N).transpose(0, 2, 1, 3).reshape(B, S, d)
        tm = (layers.groupnorm_heads(o, H) * g) @ params["wo"]
        state = _wkv_final_state(heads(k), heads(v), heads(w), state)
        new_cache["wkv"] = state.reshape(B, H, N, N)
        new_cache["shift_tm"] = h[:, -1, :]
    else:
        tm, new_wkv, last = _time_mix_step(params, h[:, 0, :], cfg, cache)
        tm = tm[:, None, :]
        new_cache["wkv"] = new_wkv
        new_cache["shift_tm"] = last
    x = x + tm

    h = layers.rmsnorm(params["norm_cm"], x, cfg.norm_eps)
    cm, last_cm = channel_mix_forward(params, h, cfg, cache["shift_cm"])
    new_cache["shift_cm"] = last_cm
    return x + cm, new_cache


def _time_mix_step(params: Params, x: jax.Array, cfg: ModelConfig, cache: Cache):
    """Single-token time-mix. x: (B, d)."""
    H, N = _dims(cfg)
    B, d = x.shape
    x3 = x[:, None, :]
    shifted = cache["shift_tm"][:, None, :]
    r, k, v, g, w = _time_mix(params, x3, shifted, cfg)
    rh = r.reshape(B, H, N).reshape(B * H, N)
    kh = k.reshape(B, H, N).reshape(B * H, N)
    vh = v.reshape(B, H, N).reshape(B * H, N)
    wh = w.reshape(B, H, N).reshape(B * H, N)
    u = jnp.broadcast_to(params["u"][None], (B, H, N)).reshape(B * H, N)
    state = cache["wkv"].reshape(B * H, N, N)
    new_state, o = ref.wkv6_step(state, rh, kh, vh, wh, u)
    o = o.reshape(B, d)
    o = layers.groupnorm_heads(o, H) * g[:, 0, :]
    return o @ params["wo"], new_state.reshape(B, H, N, N), x


def _wkv_final_state(k: jax.Array, v: jax.Array, w: jax.Array, state: jax.Array):
    """Roll the WKV state over a sequence (no outputs). k/v/w: (BH,S,N)."""
    def step(s, inp):
        k_t, v_t, w_t = inp
        decay = jnp.exp(-jnp.exp(w_t.astype(jnp.float32)))
        kv = jnp.einsum("bn,bv->bnv", k_t.astype(jnp.float32), v_t.astype(jnp.float32))
        return decay[..., None] * s + kv, None

    s, _ = jax.lax.scan(
        step, state, (k.transpose(1, 0, 2), v.transpose(1, 0, 2), w.transpose(1, 0, 2))
    )
    return s
