"""Per-tenant latency tracking: EWMA, SLO attainment, predictability.

"We preserve predictability and isolation during virtualization by
monitoring inference latencies per-kernel. This allows reallocating
resources between tenants on-the-fly." (paper section 4)
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional


@dataclasses.dataclass
class TenantLatency:
    ewma_s: Optional[float] = None
    count: int = 0
    slo_violations: int = 0
    history: List[float] = dataclasses.field(default_factory=list)

    def record(self, latency_s: float, slo_s: float, alpha: float) -> None:
        self.count += 1
        if latency_s > slo_s:
            self.slo_violations += 1
        self.ewma_s = (
            latency_s
            if self.ewma_s is None
            else alpha * latency_s + (1 - alpha) * self.ewma_s
        )
        self.history.append(latency_s)

    def percentile(self, q: float) -> float:
        if not self.history:
            return 0.0
        h = sorted(self.history)
        idx = min(len(h) - 1, int(q * len(h)))
        return h[idx]


class LatencyMonitor:
    """Cohort-level latency bookkeeping + straggler detection."""

    def __init__(self, ewma_alpha: float = 0.2, eviction_ratio: float = 1.5):
        self.alpha = ewma_alpha
        self.eviction_ratio = eviction_ratio
        self.tenants: Dict[int, TenantLatency] = {}

    def record(self, tenant_id: int, latency_s: float, slo_s: float) -> None:
        self.tenants.setdefault(tenant_id, TenantLatency()).record(
            latency_s, slo_s, self.alpha
        )

    def cohort_median_ewma(self) -> Optional[float]:
        vals = [t.ewma_s for t in self.tenants.values() if t.ewma_s is not None]
        return statistics.median(vals) if vals else None

    def stragglers(self) -> List[int]:
        """Tenants whose EWMA latency exceeds eviction_ratio x cohort median.

        "CUDA Stream scheduling anomalies typically only create a few
        stragglers, so we can simply evict degraded workers without
        significantly impacting total system throughput."
        """
        med = self.cohort_median_ewma()
        if med is None or med == 0.0:
            return []
        return [
            tid
            for tid, t in self.tenants.items()
            if t.ewma_s is not None and t.ewma_s > self.eviction_ratio * med
        ]

    # ------------------------------------------------------------ metrics
    def predictability_spread(self) -> float:
        """Max/min inter-tenant mean-latency gap (paper Fig 4: 25% for MPS).

        Returns (max_mean - min_mean) / min_mean over tenants; 0 = perfectly
        uniform (predictable) cohort.
        """
        means = [
            statistics.mean(t.history) for t in self.tenants.values() if t.history
        ]
        if len(means) < 2 or min(means) == 0.0:
            return 0.0
        return (max(means) - min(means)) / min(means)

    def summary(self) -> Dict[str, float]:
        all_lat = [x for t in self.tenants.values() for x in t.history]
        if not all_lat:
            return {}
        h = sorted(all_lat)
        return {
            "num_tenants": float(len(self.tenants)),
            "p50_s": h[len(h) // 2],
            "p95_s": h[min(len(h) - 1, int(0.95 * len(h)))],
            "p99_s": h[min(len(h) - 1, int(0.99 * len(h)))],
            "mean_s": statistics.mean(h),
            "spread": self.predictability_spread(),
            "slo_violations": float(
                sum(t.slo_violations for t in self.tenants.values())
            ),
        }
