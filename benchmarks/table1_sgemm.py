"""Table 1 / Figure 7: inter-model SGEMM batching throughput vs R.

Two complementary readouts (DESIGN.md section 7):
  * MEASURED (this CPU): wall-clock GFLOP/s for the four strategies.
    A single CPU core cannot show spatial underutilization, so the
    measurable ordinal claim here is time_only < {space_only, space_time}.
  * DERIVED (TPU v5e MXU model): first-order per-strategy kernel-time
    model — per-kernel dispatch + systolic pipeline fill + MXU busy
    cycles — which is where the paper's >3x space-time gain lives.

Derived-model assumptions (documented, first-order):
    MXU 128x128 @ 940 MHz; one K-panel pass = 128 cycles;
    busy(M,N,K) = ceil(M/128)*ceil(N/128)*ceil(K/128)*128 cycles;
    pipeline fill = 128 cycles per kernel launch; dispatch = 2 us/kernel;
    context switch (time-only) = 5 us; HBM roof = 819 GB/s.
"""

from __future__ import annotations

import math
import statistics
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.config import ScheduleConfig
from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES, GemmShape
from repro.core.queue import GemmProblem
from repro.core.strategies import Exclusive, SpaceOnly, SpaceTime, TimeOnly
from repro.core.superkernel import SuperKernelCache

MXU_FREQ = 940e6
MXU_TILE = 128
PIPE_FILL_CYCLES = 128
DISPATCH_S = 2e-6
CTX_SWITCH_S = 5e-6
HBM_BW = 819e9


def mxu_busy_cycles(g: GemmShape) -> float:
    tiles = (
        math.ceil(g.M / MXU_TILE) * math.ceil(g.N / MXU_TILE) * math.ceil(g.K / MXU_TILE)
    )
    return tiles * MXU_TILE


def derived_tpu_time(g: GemmShape, r: int, strategy: str) -> float:
    busy = mxu_busy_cycles(g) / MXU_FREQ
    fill = PIPE_FILL_CYCLES / MXU_FREQ
    mem = r * 4 * (g.M * g.K + g.K * g.N + g.M * g.N) / HBM_BW
    if strategy == "time_only":
        t = r * (CTX_SWITCH_S + DISPATCH_S + busy + fill)
    elif strategy == "space_only":
        t = DISPATCH_S + r * (busy + fill)
    elif strategy in ("space_time", "exclusive"):
        t = DISPATCH_S + r * busy + fill
    else:
        raise ValueError(strategy)
    return max(t, mem)


def make_problems(g: GemmShape, r: int, seed: int = 0) -> List[GemmProblem]:
    key = jax.random.PRNGKey(seed)
    out = []
    for t in range(r):
        kx, kw, key = jax.random.split(key, 3)
        out.append(
            GemmProblem(
                tenant_id=t,
                x=jax.random.normal(kx, (g.M, g.K), jnp.float32),
                w=jax.random.normal(kw, (g.K, g.N), jnp.float32),
            )
        )
    return out


def measure(g: GemmShape, r: int, reps: int = 5) -> Dict[str, float]:
    problems = make_problems(g, r)
    out: Dict[str, float] = {}
    strategies = [
        TimeOnly(),
        SpaceOnly(),
        SpaceTime(SuperKernelCache(ScheduleConfig(r_bucketing="exact"))),
        Exclusive(),
    ]
    for s in strategies:
        s.prepare(problems)
        times = []
        for _ in range(reps):
            _, t = s.run()
            times.append(t)
        out[s.name] = g.flops * r / min(times)  # FLOP/s
    return out


def geomean(xs: List[float]) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def run(r_sweep=(2, 4, 8, 16, 32), reps: int = 5, csv_rows=None):
    print("\n=== Table 1 / Fig 7: SGEMM R-scaling (measured CPU + derived TPU) ===")
    header = (
        f"{'shape':18s} {'R':>4s} | measured GFLOP/s: "
        f"{'time':>8s} {'space':>8s} {'st':>8s} {'excl':>8s} | "
        f"derived TPU speedup st/space st/time"
    )
    print(header)
    paper = {"rnn_matvec": 2.48, "resnet18_conv2_2": 3.23, "square_256": 4.93}
    for name, g in PAPER_GEMM_SHAPES.items():
        st_vs_space, st_vs_time = [], []
        for r in r_sweep:
            m = measure(g, r, reps)
            d = {s: derived_tpu_time(g, r, s) for s in
                 ("time_only", "space_only", "space_time")}
            sp_space = d["space_only"] / d["space_time"]
            sp_time = d["time_only"] / d["space_time"]
            st_vs_space.append(sp_space)
            st_vs_time.append(sp_time)
            print(
                f"{name:18s} {r:4d} | "
                f"{m['time_only']/1e9:8.1f} {m['space_only']/1e9:8.1f} "
                f"{m['space_time']/1e9:8.1f} {m['exclusive']/1e9:8.1f} | "
                f"{sp_space:7.2f}x {sp_time:6.2f}x"
            )
            if csv_rows is not None:
                for strat, flops in m.items():
                    csv_rows.append(
                        (f"table1/{name}/R{r}/{strat}", 1e6 * g.flops * r / flops,
                         f"{flops/1e9:.2f}GFLOPs_measured")
                    )
        print(
            f"{name:18s} geomean derived: st/space {geomean(st_vs_space):.2f}x "
            f"st/time {geomean(st_vs_time):.2f}x  (paper geomean vs next-best: "
            f"{paper[name]:.2f}x)"
        )


if __name__ == "__main__":
    run()
