"""Shape-bucketed workload arrival queue.

Interactive inference queries arrive stochastically; each query decomposes
into schedulable workloads — kernel launches (mostly GEMMs) at the bottom
layer, prefill/decode cohorts at the serving layer. The queue groups
pending workloads by their *bucket* (any hashable mergeability key —
``ShapeBucket`` for GEMMs, tuples for engine cohorts); items in the same
bucket are mergeable into one super-dispatch. This is the front-end of the
unified space-time scheduler.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Dict, Hashable, List, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ShapeBucket:
    """Super-kernel mergeability key for GEMM-shaped workloads."""

    op: str                       # "gemm" (others pluggable)
    M: int
    K: int
    N: int
    dtype: str

    @staticmethod
    def for_gemm(x: jax.Array, w: jax.Array) -> "ShapeBucket":
        M, K = x.shape
        _, N = w.shape
        return ShapeBucket("gemm", M, K, N, str(x.dtype))


_seq = itertools.count()


@dataclasses.dataclass
class GemmProblem:
    """One pending GEMM from one tenant's model.

    Satisfies the ``Workload`` protocol (see ``core.workload``): ``bucket``
    / ``cost`` / ``merge_family`` are derived from the operand shapes, and
    its executor is the scheduler's built-in ``SuperKernelCache`` (it
    carries no ``execute`` callback).
    """

    kind = "kernel"               # monitor latency class (not a field)

    tenant_id: int
    x: jax.Array                  # (M, K) activation
    w: jax.Array                  # (K, N) this tenant's weights
    arrival_time: float = 0.0
    slo_s: float = 0.100
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    # filled by the scheduler on completion:
    result: Optional[jax.Array] = None
    completion_time: Optional[float] = None

    @property
    def bucket(self) -> ShapeBucket:
        return ShapeBucket.for_gemm(self.x, self.w)

    @property
    def merge_family(self) -> Tuple:
        """GEMMs sharing (op, K, N, dtype) may ragged-merge across M."""
        b = self.bucket
        return (b.op, b.K, b.N, b.dtype)

    @property
    def flops(self) -> int:
        M, K = self.x.shape
        N = self.w.shape[1]
        return 2 * M * K * N

    @property
    def cost(self) -> float:
        return float(self.flops)


class WorkQueue:
    """FIFO-per-bucket pending-workload store with per-tenant accounting."""

    def __init__(self) -> None:
        self._buckets: Dict[Hashable, Deque] = collections.defaultdict(
            collections.deque
        )
        self._per_tenant: Dict[int, int] = collections.defaultdict(int)

    def push(self, item) -> None:
        self._buckets[item.bucket].append(item)
        self._per_tenant[item.tenant_id] += 1

    def __len__(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def pending_for_tenant(self, tenant_id: int) -> int:
        return self._per_tenant.get(tenant_id, 0)

    def buckets(self) -> List[Tuple[Hashable, int]]:
        return [(b, len(q)) for b, q in self._buckets.items() if q]

    def peek(self, bucket: Hashable) -> List:
        """Pending items of one bucket, FIFO order, without popping."""
        return list(self._buckets.get(bucket, ()))

    def head(self, bucket: Hashable):
        """Oldest pending item of a bucket (None if empty), O(1)."""
        q = self._buckets.get(bucket)
        return q[0] if q else None

    def oldest_arrival(self, bucket: Hashable) -> Optional[float]:
        q = self._buckets.get(bucket)
        return q[0].arrival_time if q else None

    def pop_batch(self, bucket: Hashable, max_n: int) -> List:
        """Pop up to max_n items from a bucket, FIFO order."""
        q = self._buckets[bucket]
        out = []
        while q and len(out) < max_n:
            item = q.popleft()
            self._per_tenant[item.tenant_id] -= 1
            out.append(item)
        return out

    def drain(self) -> List:
        out = []
        for q in self._buckets.values():
            out.extend(q)
            q.clear()
        self._per_tenant.clear()
        return out


# Backwards-compatible alias: the queue predates the generic Workload
# refactor and most call sites still say "kernel queue".
KernelQueue = WorkQueue
