"""Generic schedulable work item — the unified space-time currency.

The paper's claim is that ONE dynamic scheduler can merge concurrent work
from disjoint tenants while preserving latency predictability. For that
to hold across layers, kernel-level work (a single GEMM) and
request-level work (a prefill, a tenant's decode step) must flow through
the SAME policy core. ``Workload`` is that common currency: anything
with a mergeability bucket, a cost estimate, a tenant, an SLO, and a way
to execute a batch of its peers.

Scheduler-facing protocol (duck-typed — ``GemmProblem`` satisfies it via
properties, ``Workload`` via plain fields):

    tenant_id        : int — isolation / SLO-accounting domain
    bucket           : Hashable — items sharing a bucket may be merged
                       into one super-dispatch
    cost             : float — abstract work estimate (FLOPs for GEMMs,
                       tokens for engine cohorts); feeds throughput stats
                       and virtual-clock cost models
    slo_s            : float — latency objective, drives the adaptive
                       batching window and violation accounting
    merge_family     : Optional[Hashable] — non-None marks buckets that
                       may additionally be ragged-merged across bucket
                       boundaries (e.g. GEMMs sharing (op, K, N, dtype))
    execute          : Optional[Callable[[List[Workload]], List[Any]]] —
                       batch executor; ``None`` routes the batch through
                       the scheduler's built-in SuperKernelCache (the
                       GEMM path)
    arrival_time     : float — stamped by the scheduler at submit
    result / completion_time — filled by the scheduler on completion
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Hashable, List, Optional

_seq = itertools.count()


def round_pow2(n: int) -> int:
    """Round ``n`` up to the next power of two (``round_pow2(0) == 1``).

    The canonical bucketing helper shared by the super-kernel compile
    cache (R and row-count buckets), the engine's ragged-group bucketing,
    and the simulator's calibrated cost-model keys — one definition so a
    live-measured (bucket, pow2-R) cost always lands in the same bucket a
    simulation will look up.
    """
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class Workload:
    """Concrete generic work item (see module docstring for the protocol).

    Layers above the kernel queue (the serving engine's prefill/decode
    cohorts, future async dispatch) build these directly; the ``execute``
    callback receives the whole merged batch so one callback invocation
    can run one super-dispatch for many tenants.
    """

    tenant_id: int
    bucket: Hashable
    cost: float = 0.0
    slo_s: float = 0.100
    execute: Optional[Callable[[List["Workload"]], List[Any]]] = None
    merge_family: Optional[Hashable] = None
    payload: Any = None
    # workload class for per-kind latency percentiles in the monitor
    # (e.g. "prefill" vs "decode" — compile-heavy prefills would otherwise
    # pollute decode-step p95s in engine reports)
    kind: str = "default"
    arrival_time: float = 0.0
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    # filled by the scheduler on completion:
    result: Any = None
    completion_time: Optional[float] = None
