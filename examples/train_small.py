"""End-to-end training driver: train a ~100M-param model for a few hundred
steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_small.py --steps 300
    PYTHONPATH=src python examples/train_small.py --arch rwkv6-1.6b --steps 100
"""

import argparse
import dataclasses

import jax

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.training import SyntheticTokenStream, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default="/tmp/repro_train_small.msgpack")
    args = ap.parse_args()

    base = get_config(args.arch)
    cfg = smoke_variant(base, num_layers=args.layers, d_model=args.d_model)
    cfg = dataclasses.replace(cfg, vocab_size=min(base.vocab_size, 8192), dtype="float32")
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size} (~{n_params/1e6:.1f}M params) "
          f"batch={args.batch} seq={args.seq}")

    data = SyntheticTokenStream(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch, seed=0
    )
    state = train(
        model, data,
        steps=args.steps, base_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        log_every=max(args.steps // 20, 1),
        checkpoint_path=args.checkpoint, checkpoint_every=100,
    )
    print(f"done at step {state.step}; checkpoint at {args.checkpoint}")


if __name__ == "__main__":
    main()
