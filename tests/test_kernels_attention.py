"""flash_attention / decode_attention / chunked-ref vs the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention

CASES = [
    # B, Hq, Hkv, S, D
    (2, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 160, 64),      # GQA 4:1, ragged S
    (1, 8, 1, 96, 32),       # MQA
    (2, 4, 2, 64, 128),      # wide head
]


@pytest.mark.parametrize("case", CASES, ids=str)
@pytest.mark.parametrize("window", [0, 32])
def test_flash_vs_dense(case, window, rng_key):
    B, Hq, Hkv, S, D = case
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, bq=64, bkv=64, interpret=True)
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_chunked_ref_vs_dense(rng_key):
    """The O(S) XLA fallback must equal the dense reference (incl. softcap)."""
    B, Hq, Hkv, S, D = 2, 4, 2, 200, 32
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    for window, cap in [(0, 0.0), (64, 0.0), (0, 30.0)]:
        got = ref.attention_chunked(q, k, v, causal=True, window=window,
                                    logit_softcap=cap, kv_chunk=64)
        want = ref.attention(q, k, v, causal=True, window=window, logit_softcap=cap)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_suffix_queries(rng_key):
    """Sq < Skv (queries are the suffix) must align causally."""
    B, Hq, Hkv, Skv, Sq, D = 1, 2, 2, 96, 32, 32
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=32, bkv=32, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("lengths", [[300, 17, 128], [1, 1, 1], [256, 256, 256]], ids=str)
def test_decode_vs_dense(lengths, rng_key):
    B, Hq, Hkv, S, D = 3, 8, 2, 300, 64
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    lens = jnp.asarray(lengths, jnp.int32)
    got = decode_attention(q, kc, vc, lens, bkv=128, interpret=True)
    want = ref.decode_attention(q, kc, vc, lens)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_decode_ignores_stale_cache(rng_key):
    """Cache positions beyond `lengths` must not affect the output —
    the property slot-reuse in the serving engine relies on."""
    B, Hq, Hkv, S, D = 1, 2, 1, 64, 32
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    lens = jnp.asarray([20], jnp.int32)
    out1 = decode_attention(q, kc, vc, lens, bkv=32, interpret=True)
    kc2 = kc.at[:, :, 20:].set(99.0)
    vc2 = vc.at[:, :, 20:].set(-99.0)
    out2 = decode_attention(q, kc2, vc2, lens, bkv=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
