"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only
repro.launch.dryrun (a separate process) forces 512 placeholder devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
