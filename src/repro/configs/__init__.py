"""Assigned architecture configs (one module per architecture).

Importing this package registers every assigned config with
``repro.config.registry``. Each module cites its source in the config's
``source`` field and module docstring.
"""

from repro.configs import (  # noqa: F401
    granite_moe_1b_a400m,
    zamba2_7b,
    paligemma_3b,
    granite_3_8b,
    musicgen_large,
    qwen2_7b,
    llama4_maverick_400b_a17b,
    stablelm_1_6b,
    gemma3_27b,
    rwkv6_1_6b,
    paper_sgemm,
)

ASSIGNED_ARCHS = [
    "granite-moe-1b-a400m",
    "zamba2-7b",
    "paligemma-3b",
    "granite-3-8b",
    "musicgen-large",
    "qwen2-7b",
    "llama4-maverick-400b-a17b",
    "stablelm-1.6b",
    "gemma3-27b",
    "rwkv6-1.6b",
]
