"""Section 4 claim: "overheads gradually decrease if we cache super-kernels
as workloads stabilize over time."

Stochastic (Poisson) kernel arrivals from R tenants drive the dynamic
scheduler; we report per-quarter mean latency, dispatch count and cache
hit-rate. Expected: hit-rate -> ~1 and latency anneals after the first
quarter (compiles amortized), demonstrating the super-kernel cache doing
its job under non-stationary R.

The ``policy`` knob selects the batching-window policy of the unified
core ("fixed" or "slo_adaptive"); the trace runs under both by default so
the SLO-aware window's latency win shows up on live (wall-clock)
arrivals, not just in the Fig-4 virtual-clock replay.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScheduleConfig
from repro.core import DynamicSpaceTimeScheduler, GemmProblem
from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES


def run(num_events: int = 200, tenants: int = 12, seed: int = 0, csv_rows=None,
        policy: str = "fixed", slo_s: float = 0.010):
    print(f"\n=== Dynamic trace: cache warm-up under stochastic arrivals "
          f"(policy={policy}) ===")
    g = PAPER_GEMM_SHAPES["resnet18_conv2_2"]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    # device-resident per-tenant weights; fresh activations per query
    ws = [jax.random.normal(jax.random.fold_in(key, t), (g.K, g.N), jnp.float32)
          for t in range(tenants)]
    xs = [jax.random.normal(jax.random.fold_in(key, 1000 + i), (g.M, g.K), jnp.float32)
          for i in range(8)]

    sched = DynamicSpaceTimeScheduler(
        ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32,
                       batching_policy=policy)
    )
    lat: List[float] = []
    hit_marks: List[float] = []
    t_clock = time.perf_counter()
    for i in range(num_events):
        # Poisson batch of arrivals (bursty, like online traffic)
        for _ in range(1 + rng.poisson(2.0)):
            t = int(rng.integers(tenants))
            # tight SLO so the adaptive policy's slack-shrinking window
            # actually diverges from the fixed one on a live trace
            sched.submit(GemmProblem(tenant_id=t, x=xs[int(rng.integers(len(xs)))],
                                     w=ws[t], slo_s=slo_s))
        done = sched.pump()
        for p in done:
            lat.append(p.completion_time - p.arrival_time)
            hit_marks.append(sched.cache.stats.hit_rate)
        time.sleep(0.0002)
    for p in sched.flush():
        lat.append(p.completion_time - p.arrival_time)
        hit_marks.append(sched.cache.stats.hit_rate)

    q = max(1, len(lat) // 4)
    print(f"{'quarter':>8s} {'mean lat ms':>12s} {'hit rate':>9s}")
    for qi in range(4):
        seg = lat[qi * q:(qi + 1) * q]
        hseg = hit_marks[qi * q:(qi + 1) * q]
        if not seg:
            continue
        print(f"{qi+1:8d} {np.mean(seg)*1e3:12.3f} {hseg[-1]:9.2f}")
        if csv_rows is not None:
            csv_rows.append((f"dynamic_trace/{policy}/q{qi+1}",
                             float(np.mean(seg) * 1e6),
                             f"hit_rate={hseg[-1]:.2f}"))
    rep = sched.report()
    print(f"final: dispatches={rep['dispatches']:.0f} problems={rep['problems']:.0f} "
          f"hit_rate={rep['cache_hit_rate']:.2f} spread={rep.get('spread', 0):.2%} "
          f"p95={rep.get('p95_s', 0)*1e3:.3f}ms")
    return rep


def run_all_policies(num_events: int = 200, tenants: int = 12, seed: int = 0,
                     csv_rows=None):
    """Same live trace parameters under both batching-window policies."""
    for policy in ("fixed", "slo_adaptive"):
        run(num_events=num_events, tenants=tenants, seed=seed,
            csv_rows=csv_rows, policy=policy)


if __name__ == "__main__":
    run_all_policies()
