"""Live fleet: N real engines behind the simulator's routing layer.

The serving half of the fleet story, on the SAME pump/router core the
simulator runs (``repro.core.pump.PumpCore``): each replica is one
``PumpCore`` — the real ``DynamicSpaceTimeScheduler`` with the ripeness
calendar, feasibility admission, EDF drain and preemption — except its
clock is the WALL clock and its dispatched batches execute on a real
engine instead of a no-op. Routers (``repro.sim.router``) are pure
functions of the pump signals (``queue_depth`` / ``backlog_s`` /
``estimate_item_s``), so round_robin / jsq / least_cost / affinity work
against real execution unchanged, and ``least_cost`` prices through
REAL measured dispatch seconds: the scheduler's ``on_dispatch`` tap
fires with ``t1 - t0`` around the actual kernel call (wall clocks make
``advance`` a no-op), feeding the same ``FleetCalibrator`` tables the
simulator fits from modeled costs.

Engine adapters, in decreasing realism:

* ``EngineReplica``  — one real jax ``MultiTenantEngine`` per replica;
  a dispatched cohort becomes ``InferenceRequest``s drained to
  completion. N replicas sharing one device is the paper's
  space-multiplexing story told at the cluster layer.
* ``FakeEngine``     — deterministic token generation with zero jax:
  CI and the parity suite exercise the full fleet path on any CPU.
* ``NullEngine``     — returns no results at all, exactly like the
  simulator's no-op kernels: with a ``VirtualClock`` factory this makes
  ``LiveFleet.run`` a bit-exact twin of ``FleetSimulator.run`` (the
  sim↔live parity contract — same routing decisions, same admission
  reason codes, same metrics bytes).

Determinism: with a virtual clock factory the fleet IS the simulator
(one shared core, no forked logic). On the wall clock, arrivals are
stamped with real time, so runs are *statistically* comparable but not
byte-stable — which is why ``python -m repro simulate --check`` checks
schema invariants, not bytes, for live specs.

This module never imports jax: ``EngineReplica`` takes an
already-constructed engine, and the spec layer builds those lazily.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable, List, Optional, Sequence, Union

from repro.config import ScheduleConfig
from repro.core.clock import Clock, WallClock
from repro.core.pump import PumpCore, drain_fleet_tail, drain_merged
from repro.launch.roofline import TPU_V5E, HardwareSpec
from repro.obs.recorder import route_price_vector
from repro.sim.costmodel import (
    ColdStartCostModel,
    FleetCalibrator,
    RooflineCostModel,
    resolve_spec,
)
from repro.sim.fleet import _arrival_stream, calibration_tap
from repro.sim.metrics import FleetMetrics, MetricsAccumulator
from repro.sim.router import Router, make_router
from repro.sim.traces import Arrival, TenantSpec, Trace


class LiveWorkload:
    """Scheduler workload carrying a real payload and executor.

    Same protocol surface as the simulator's ``SimWorkload`` (the
    scheduler and pump read identical fields), plus the live extras:
    ``execute`` is bound per-instance to the routed replica's engine,
    ``payload`` carries the request body (e.g. prompt token ids),
    ``result`` receives this item's slice of the batch output, and
    ``done`` is an optional ``threading.Event`` the pump signals on
    completion — the HTTP front door blocks on it.
    """

    __slots__ = ("tenant_id", "bucket", "cost", "slo_s", "kind", "flops",
                 "bytes", "arrival_time", "completion_time", "est_s",
                 "execute", "payload", "result", "done")

    merge_family = None

    def __init__(self, spec, cost: float, execute=None, payload=None,
                 done=None):
        self.tenant_id = spec.tenant_id
        self.bucket = spec.bucket
        self.cost = cost
        self.slo_s = spec.slo_s
        self.kind = spec.kind
        self.flops = spec.flops
        self.bytes = spec.bytes
        self.arrival_time = 0.0
        self.completion_time = None
        self.est_s = 0.0
        self.execute = execute
        self.payload = payload
        self.result = None
        self.done = done


# ----------------------------------------------------------- engine adapters
class NullEngine:
    """No results at all — the exact live twin of the simulator's no-op
    kernels (``outs is None`` skips the scheduler's result zip), so a
    virtual-clocked ``LiveFleet`` reproduces ``FleetSimulator`` bytes."""

    name = "null"

    def __init__(self, replica_id: int = 0):
        self.replica_id = replica_id

    def execute(self, batch: List) -> None:
        return None


class FakeEngine:
    """Deterministic token generation without jax: each item's output is
    a pure function of its tenant and payload (splitmix64 over the prompt
    bytes), so CI can assert exact responses across replicas/routers."""

    name = "fake"

    def __init__(self, replica_id: int = 0, max_new_tokens: int = 8,
                 vocab: int = 32000):
        self.replica_id = replica_id
        self.max_new_tokens = int(max_new_tokens)
        self.vocab = int(vocab)

    @staticmethod
    def _mix(h: int) -> int:
        h = (h + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = h
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return z ^ (z >> 31)

    def execute(self, batch: List) -> List[List[int]]:
        outs = []
        for w in batch:
            h = self._mix(int(w.tenant_id) + 1)
            for tok in (w.payload or ()):
                h = self._mix(h ^ int(tok))
            outs.append([(self._mix(h + k) % self.vocab)
                         for k in range(self.max_new_tokens)])
        return outs


class EngineReplica:
    """One real ``MultiTenantEngine`` as a fleet replica executor: a
    dispatched cohort becomes ``InferenceRequest``s submitted to the
    engine's own slot-based continuous batcher and drained to completion
    — the fleet's space-time scheduler decides WHEN and WHERE a cohort
    runs, the engine decides HOW it packs onto the chip."""

    name = "jax"

    def __init__(self, engine, replica_id: int = 0, max_new_tokens: int = 8):
        self.engine = engine
        self.replica_id = replica_id
        self.max_new_tokens = int(max_new_tokens)

    def execute(self, batch: List) -> List[List[int]]:
        from repro.serving.request import InferenceRequest

        engine = self.engine
        n_tenants = engine.cfg.num_tenants
        reqs = []
        for w in batch:
            payload = list(w.payload) if w.payload else [1]
            req = InferenceRequest(
                tenant_id=int(w.tenant_id) % n_tenants,
                prompt=payload,
                max_new_tokens=self.max_new_tokens,
                slo_s=float(w.slo_s) if w.slo_s else 0.1,
            )
            reqs.append(req)
            engine.submit(req)
        engine.run_until_drained()
        return [list(req.generated) for req in reqs]


def _signal_done(done: List) -> None:
    """Pump completion hook: resolve any per-request completion events."""
    for w in done:
        ev = getattr(w, "done", None)
        if ev is not None:
            ev.set()


class LiveFleet:
    """N engine-backed replicas of the real scheduler behind a router.

    The construction mirrors ``FleetSimulator`` knob for knob (shared
    ``cost_model`` XOR per-replica ``specs``; per-replica
    ``ColdStartCostModel`` wrap when ``compile_s > 0``; optional
    ``FleetCalibrator`` + flight recorder) so a live spec and its sim
    twin build the same pricing stack. Differences: replicas execute on
    real engines from ``engine_factory(replica_id)``, the clock is the
    wall by default (``clock_factory`` injects virtual time for the
    parity suite), and there is no autoscaler — live elasticity is a
    deployment concern (see the ROADMAP follow-on).
    """

    def __init__(
        self,
        replicas: int,
        engine_factory: Callable[[int], object],
        router: Union[Router, str] = "least_cost",
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        compile_s: float = 0.0,
        start_s: float = 0.0,
        specs: Optional[Sequence[Union[str, HardwareSpec]]] = None,
        strategy: str = "space_time",
        calibration: Optional[FleetCalibrator] = None,
        recorder=None,
        clock_factory: Optional[Callable[[float], Clock]] = None,
    ):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if specs is not None and cost_model is not None:
            raise ValueError(
                "pass per-replica specs OR a shared cost_model, not both")
        if specs is not None and not specs:
            raise ValueError("specs must be non-empty when given")
        self.router = make_router(router) if isinstance(router, str) else router
        self.schedule = schedule
        self.compile_s = float(compile_s)
        self.strategy = strategy
        self.specs = [resolve_spec(s) for s in specs] if specs else None
        self._shared_base = cost_model
        self.calibration = calibration
        self.recorder = recorder
        self.engine_factory = engine_factory
        self._clock_factory = clock_factory
        self.wall = clock_factory is None
        # wall mode: ONE shared clock — replicas live in the same real
        # time, so backlog_s's "clock ran ahead" residual is always zero
        # and the routing signal reduces to priced queue seconds
        self._wall_clock = WallClock() if self.wall else None
        self.start_s = (self._wall_clock.now() if self.wall
                        else float(start_s))

        self.pumps: List[PumpCore] = []
        self.active: List[PumpCore] = []
        self.engines: List = []
        self.routed_counts: List[int] = []
        self._fleet_acc = MetricsAccumulator()
        self._replica_accs: List[MetricsAccumulator] = []
        self._next_id = 0
        for _ in range(replicas):
            self._spawn(self.start_s)

    # -------------------------------------------------------- replica pool
    def _base_model(self, replica_id: int):
        if self.specs is not None:
            return RooflineCostModel(
                spec=self.specs[replica_id % len(self.specs)],
                strategy=self.strategy)
        return self._shared_base or RooflineCostModel()

    def _spawn(self, t_s: float) -> PumpCore:
        i = self._next_id
        self._next_id += 1
        base = self._base_model(i)
        clock = (self._wall_clock if self.wall
                 else self._clock_factory(t_s))
        model = base
        if self.compile_s > 0.0:
            model = ColdStartCostModel(base, compile_s=self.compile_s,
                                       clock=clock)
        pump = PumpCore(schedule=self.schedule, cost_model=model,
                        clock=clock, replica_id=i)
        pump.track_inflight = True  # routers read occupancy in fleet time
        pump.on_complete = _signal_done
        spec = getattr(base, "spec", None)
        if spec is not None:
            pump.spec_name = spec.name
            pump.speed_factor = spec.peak_flops / TPU_V5E.peak_flops
        if self.calibration is not None:
            pump.scheduler.on_dispatch = calibration_tap(
                self.calibration, model)
            pump.route_model = self.calibration.for_replica(i)
        if self.recorder is not None:
            # after calibration wiring: the recorder tap composes over it
            pump.attach_recorder(self.recorder.shard(i))
        self.engines.append(self.engine_factory(i))
        acc = MetricsAccumulator()
        pump.accs = [self._fleet_acc, acc]
        self.pumps.append(pump)
        self.active.append(pump)
        self.routed_counts.append(0)
        self._replica_accs.append(acc)
        return pump

    # ------------------------------------------------------------ event loop
    def now(self) -> float:
        return (self._wall_clock.now() if self.wall
                else max(p.clock.now() for p in self.pumps))

    def _drain_until(self, t_limit: float) -> None:
        drain_merged(self.active, t_limit)

    def submit_one(self, spec: TenantSpec, cost: float = 0.0,
                   payload=None, done=None,
                   t_s: Optional[float] = None):
        """Route and submit ONE arrival; the serving edge's unit of work.

        Returns ``(workload, replica_id, admitted, reason)`` — reason is
        the scheduler's admission code (0 admit, 1 oversubscribed,
        2 cap, 3 infeasible deadline).
        """
        if t_s is None:
            t_s = self._wall_clock.now() if self.wall else self.now()
        self._drain_until(t_s)
        idx = self.router.route(spec, self.active, t_s)
        pump = self.active[idx]
        if self.recorder is not None:
            # recompute the (idempotent) price vector the router just
            # read — recorded before submit so the decision context is
            # the pre-admission state it was actually made against
            rids, prices = route_price_vector(
                self.router, spec, self.active, t_s)
            self.recorder.record_route(t_s, spec.tenant_id, pump.replica_id,
                                       rids, prices)
        w = LiveWorkload(spec, cost,
                         execute=self.engines[pump.replica_id].execute,
                         payload=payload, done=done)
        w.est_s = pump.estimate_item_s(w)
        admitted = pump.submit(w, t_s)
        if admitted:
            self.routed_counts[pump.replica_id] += 1
        elif done is not None:
            done.set()  # rejected work never dispatches; unblock the caller
        return w, pump.replica_id, admitted, pump.scheduler.admit_reason

    def poll(self) -> int:
        """Pump every replica that has ripened by the current wall
        instant (the serving loop's heartbeat). Returns items completed.

        The clock read happens AFTER ``next_ripe_time``: that call clamps
        past instants to its own wall read, so comparing against an
        earlier timestamp would never fire (wall time is monotone) and
        ripened work would sit until the drain timeout force-flush."""
        n = 0
        for p in self.active:
            t = p.next_ripe_time()
            if t is not None and t <= self._wall_clock.now():
                n += len(p.pump_at(t))
        return n

    def next_ripe_time(self) -> Optional[float]:
        """Earliest instant any replica ripens (None = all queues dry)."""
        best = None
        for p in self.active:
            t = p.next_ripe_time()
            if t is not None and (best is None or t < best):
                best = t
        return best

    def run(self, trace: Union[Trace, Iterable[Arrival]],
            payload_fn: Optional[Callable[[TenantSpec], list]] = None
            ) -> FleetMetrics:
        """Replay a whole arrival trace through the fleet and freeze
        metrics — the ``RunReport`` path for live specs.

        Virtual mode replays the trace's own timeline (the parity twin of
        ``FleetSimulator.run``); wall mode replays open-loop at full
        speed, stamping each arrival with REAL time — measuring what the
        fleet actually sustains rather than what the trace offered.
        """
        t_start = self.start_s
        for t_s, spec, cost in _arrival_stream(trace):
            if self.wall:
                t_s = self._wall_clock.now()
            payload = payload_fn(spec) if payload_fn is not None else None
            self.submit_one(spec, cost, payload=payload, t_s=t_s)
        if self.wall:
            self._drain_wall_tail()
        else:
            drain_fleet_tail(self.pumps, self._drain_until)
        return self.freeze(self.now() - t_start)

    def _drain_wall_tail(self, timeout_s: float = 30.0) -> None:
        """Wall-clock tail: sleep to each ripeness instant and pump, with
        a hard timeout after which the remainder is force-flushed (the
        slack-aware policies' shrinking windows always terminate, but a
        serving drain must bound its own exit)."""
        clock = self._wall_clock
        t_stop = clock.now() + timeout_s
        pumps = self.pumps
        while any(len(p.scheduler.queue) for p in pumps):
            if clock.now() >= t_stop:
                for p in pumps:
                    if len(p.scheduler.queue):
                        p._absorb(p.scheduler.flush())
                return
            t_next = self.next_ripe_time()
            if t_next is None:
                for p in pumps:
                    if len(p.scheduler.queue):
                        p._absorb(p.scheduler.flush())
                return
            now = clock.now()
            if t_next > now:
                time.sleep(min(t_next - now, 0.050))
            self.poll()

    # ------------------------------------------------------------- metrics
    def freeze(self, horizon_s: Optional[float] = None) -> FleetMetrics:
        """Freeze the fleet's accumulated metrics into ``FleetMetrics`` —
        same schema the fleet simulator emits, so live and sim reports
        diff cleanly."""
        pumps = self.pumps
        if horizon_s is None:
            dispatched = [p.clock.now() for p in pumps
                          if p.scheduler.stats.dispatches > 0]
            horizon_s = (max(dispatched) if dispatched
                         else self.start_s) - self.start_s
        stats = [p.scheduler.stats for p in pumps]
        merged = self._fleet_acc.freeze(
            sim_duration_s=horizon_s,
            busy_time_s=sum(s.busy_time_s for s in stats),
            dispatches=sum(s.dispatches for s in stats),
            rejected=sum(s.rejected for s in stats),
            evicted_tenants=sum(len(p.scheduler.evicted) for p in pumps),
            ripe_nudges=sum(s.ripe_nudges for s in stats),
            deadline_rejected=sum(s.deadline_rejected for s in stats),
            oversubscribed=sum(s.oversubscribed for s in stats),
            preemptions=sum(s.preemptions for s in stats),
        )
        per_replica = [p.freeze(acc, sim_duration_s=horizon_s)
                       for p, acc in zip(pumps, self._replica_accs)]
        if self.recorder is not None:
            self.recorder.router_name = self.router.name
        import numpy as np

        cold_t: List = []
        cold_f: List = []
        for p in pumps:
            m = p.cost_model
            if isinstance(m, ColdStartCostModel):
                cold_t.append(np.asarray(m.dispatch_times, np.float64))
                cold_f.append(np.asarray(m.dispatch_cold, np.int64))
        if cold_t:
            t = np.concatenate(cold_t)
            f = np.concatenate(cold_f)
            order = np.argsort(t, kind="stable")
            cold_times, cold_flags = t[order], f[order]
        else:
            cold_times = np.zeros(0, np.float64)
            cold_flags = np.zeros(0, np.int64)
        return FleetMetrics(
            merged=merged,
            per_replica=per_replica,
            routed_counts=list(self.routed_counts),
            router=self.router.name,
            cold_times=cold_times,
            cold_flags=cold_flags,
            scale_events=[],
            replica_specs=[p.spec_name for p in pumps],
            final_active=len(self.active),
        )
