"""Hypothesis property-based tests for the scheduler's invariants.

The central invariant (what makes space-time batching SAFE): merging any
set of same-shape kernels from any tenants into super-kernels, in any
arrival order, under any window/max-size knobs, produces EXACTLY the same
per-tenant results as sequential per-tenant execution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import ScheduleConfig
from repro.core import DynamicSpaceTimeScheduler, GemmProblem
from repro.core.superkernel import SuperKernelCache, _round_pow2
from repro.core.tenancy import stack_params, unstack_params

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _problems(n, m, k, nn, seed):
    key = jax.random.PRNGKey(seed)
    out = []
    for t in range(n):
        kx, kw, key = jax.random.split(key, 3)
        out.append(
            GemmProblem(
                tenant_id=t,
                x=jax.random.normal(kx, (m, k), jnp.float32),
                w=jax.random.normal(kw, (k, nn), jnp.float32),
            )
        )
    return out


@given(
    n=st.integers(1, 17),
    m=st.sampled_from([8, 32, 96]),
    k=st.sampled_from([16, 48]),
    nn=st.sampled_from([1, 8, 40]),
    max_sk=st.integers(1, 8),
    bucketing=st.sampled_from(["pow2", "exact"]),
    seed=st.integers(0, 10),
)
def test_batched_equals_sequential(n, m, k, nn, max_sk, bucketing, seed):
    sched = DynamicSpaceTimeScheduler(
        ScheduleConfig(batching_window_s=0.0, max_superkernel_size=max_sk,
                       r_bucketing=bucketing)
    )
    ps = _problems(n, m, k, nn, seed)
    for p in ps:
        sched.submit(p)
    done = sched.flush()
    assert len(done) == n
    assert sorted(p.tenant_id for p in done) == list(range(n))
    for p in done:
        np.testing.assert_allclose(
            np.asarray(p.result), np.asarray(p.x @ p.w), rtol=1e-4, atol=1e-3
        )


@given(
    ms=st.lists(st.integers(1, 160), min_size=1, max_size=6),
    k=st.sampled_from([16, 64]),
    nn=st.sampled_from([8, 48]),
    seed=st.integers(0, 5),
)
def test_ragged_merge_matches_reference(ms, k, nn, seed):
    """Mixed-M problems through ONE grouped super-kernel == per-problem
    kernels/ref.py reference outputs."""
    from repro.kernels import ref

    cache = SuperKernelCache(ScheduleConfig())
    key = jax.random.PRNGKey(seed)
    problems = []
    for t, m in enumerate(ms):
        kx, kw, key = jax.random.split(key, 3)
        problems.append(GemmProblem(
            tenant_id=t,
            x=jax.random.normal(kx, (m, k), jnp.float32),
            w=jax.random.normal(kw, (k, nn), jnp.float32)))
    outs = cache.execute_ragged(problems)
    for p, out in zip(problems, outs):
        assert out.shape == (p.x.shape[0], nn)
        want = ref.batched_gemm(p.x[None], p.w[None])[0]
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-3)


@given(n=st.integers(1, 2049))
def test_pow2_rounding(n):
    r = _round_pow2(n)
    assert r >= n and r < 2 * n and (r & (r - 1)) == 0


@given(
    n=st.integers(1, 6),
    seed=st.integers(0, 5),
    evict=st.integers(0, 5),
)
def test_stack_unstack_roundtrip(n, seed, evict):
    key = jax.random.PRNGKey(seed)
    trees = []
    for t in range(n):
        k1, k2, key = jax.random.split(key, 3)
        trees.append({"a": jax.random.normal(k1, (4, 3)), "b": {"c": jax.random.normal(k2, (2,))}})
    stacked = stack_params(trees)
    back = unstack_params(stacked, n)
    for orig, rec in zip(trees, back):
        for lo, lr in zip(jax.tree.leaves(orig), jax.tree.leaves(rec)):
            np.testing.assert_array_equal(np.asarray(lo), np.asarray(lr))


@given(
    groups=st.lists(st.integers(0, 200), min_size=1, max_size=6),
    bm=st.sampled_from([8, 32, 128]),
)
def test_group_layout_properties(groups, bm):
    from repro.kernels.grouped_gemm import make_group_layout

    offs, bgroups, T = make_group_layout(np.array(groups), bm=bm)
    assert T % bm == 0
    assert len(bgroups) == T // bm
    # each group's padded extent covers its rows and block ids are ordered
    assert list(bgroups) == sorted(bgroups)
    for g, sz in enumerate(groups):
        assert offs[g + 1] - offs[g] >= sz
