"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) expert d_ff=512 vocab=49155, MoE 32 experts
top-8. ~1.3B total params, ~400M active.
"""

from repro.config import ModelConfig, MoEConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="granite-moe-1b-a400m",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        family="moe",
        num_layers=24,
        d_model=1024,
        vocab_size=49155,
        num_heads=16,
        num_kv_heads=8,
        d_ff=512,  # per-expert hidden width
        moe=MoEConfig(num_experts=32, experts_per_token=8, expert_d_ff=512),
        tie_embeddings=True,
        rope_theta=10_000.0,
    )
)
