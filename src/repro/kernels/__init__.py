"""Pallas TPU kernels for the space-time scheduler's compute hot-spots.

Layout (per repo convention):
    <name>.py  -- pl.pallas_call kernel + explicit BlockSpec VMEM tiling
    ops.py     -- jit'd dispatch wrappers (pallas on TPU / interpret or jnp
                  reference on CPU)
    ref.py     -- pure-jnp oracles used by tests and as the CPU fallback

Kernels:
    batched_gemm    -- THE paper super-kernel: R same-shape GEMMs from
                       disjoint models merged into one invocation
                       (cublasSgemmBatched analogue, MXU-tiled)
    grouped_gemm    -- variable-size batched GEMM via block->group metadata
                       (MAGMA vbatched analogue; also MoE expert compute)
    flash_attention -- blockwise online-softmax causal attention
                       (+ sliding window for gemma3-style local layers)
    decode_attention-- one-token GQA decode against a KV cache
    wkv6_scan       -- RWKV-6 data-dependent-decay recurrence, chunked scan
"""

# Submodules (ops, ref, individual kernels) are imported explicitly by
# consumers; no eager imports here to keep `import repro.kernels.<k>` cheap.
