"""The four multiplexing strategies under comparison (paper sections 3-4).

Each strategy executes the same list of per-tenant GEMM workloads
(``GemmProblem``, the kernel-level instance of the generic ``Workload``
protocol — same ``ShapeBucket``/cost types the unified scheduler and
``SuperKernelCache`` consume) and returns (outputs, wall_time_s). TPU
adaptation of the CUDA mechanisms:

    exclusive : one tenant owns the device; its problems run as ONE
                data-batched kernel (the paper's "batched exclusive access"
                upper bound -- only valid when all problems share weights).
    time_only : one jit'd dispatch per problem with a device sync between
                dispatches — models CUDA-context time-slicing, where only
                one context's kernel is resident per quantum.
    space_only: ONE XLA program containing R independent small GEMM ops.
                XLA may interleave them (instruction-level parallelism,
                the Hyper-Q analogue) but cannot widen any single GEMM.
    space_time: the proposed approach — all R problems merged into one
                batched super-kernel via SuperKernelCache.

The benchmark claims to validate (Table 1 / Fig 7): throughput ordering
space_time > space_only > time_only, with the gap growing in R.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.queue import GemmProblem
from repro.core.superkernel import SuperKernelCache
from repro.kernels import ops


Outputs = List[jax.Array]


def _sync(x):
    return jax.block_until_ready(x)


class Strategy:
    """Measurement protocol (matches the paper): ``prepare`` moves the
    problems into the strategy's natural device-resident layout and warms
    the compile cache — "data is preallocated on the device as in a
    real-world DNN inference setting" — so ``run`` times pure dispatch +
    compute."""

    name: str = "base"

    def prepare(self, problems: List[GemmProblem]) -> None:
        raise NotImplementedError

    def run(self) -> Tuple[Outputs, float]:
        raise NotImplementedError


class TimeOnly(Strategy):
    """Sequential per-tenant dispatch with a sync per dispatch (context switch)."""

    name = "time_only"

    def __init__(self, switch_overhead_s: float = 0.0):
        # Optional modeled CUDA-context-switch cost; 0 keeps it purely
        # measured (the dispatch+sync overhead is real on its own).
        self.switch_overhead_s = switch_overhead_s
        self._fn = jax.jit(lambda x, w: x @ w)
        self._data: List[Tuple[jax.Array, jax.Array]] = []

    def prepare(self, problems: List[GemmProblem]) -> None:
        self._data = [
            (_sync(jnp.asarray(p.x)), _sync(jnp.asarray(p.w))) for p in problems
        ]
        _sync(self._fn(*self._data[0]))

    def run(self) -> Tuple[Outputs, float]:
        t0 = time.perf_counter()
        outs = []
        for x, w in self._data:
            outs.append(_sync(self._fn(x, w)))  # sync = context-switch boundary
            if self.switch_overhead_s:
                time.sleep(self.switch_overhead_s)
        return outs, time.perf_counter() - t0


class SpaceOnly(Strategy):
    """One XLA program with R independent GEMM ops (stream/Hyper-Q analogue)."""

    name = "space_only"

    def __init__(self):
        self._fns: Dict[int, Callable] = {}
        self._xs: List[jax.Array] = []
        self._ws: List[jax.Array] = []

    def _get(self, r: int) -> Callable:
        fn = self._fns.get(r)
        if fn is None:
            def call(xs, ws):
                # R *separate* ops — deliberately NOT stacked: XLA sees R
                # small dots it may schedule concurrently but cannot merge.
                return [x @ w for x, w in zip(xs, ws)]
            fn = jax.jit(call)
            self._fns[r] = fn
        return fn

    def prepare(self, problems: List[GemmProblem]) -> None:
        self._xs = [_sync(jnp.asarray(p.x)) for p in problems]
        self._ws = [_sync(jnp.asarray(p.w)) for p in problems]
        _sync(self._get(len(problems))(self._xs, self._ws))

    def run(self) -> Tuple[Outputs, float]:
        fn = self._get(len(self._xs))
        t0 = time.perf_counter()
        outs = _sync(fn(self._xs, self._ws))
        return list(outs), time.perf_counter() - t0


class SpaceTime(Strategy):
    """The proposed super-kernel path (batched GEMM via SuperKernelCache).

    Tenant weights live stacked (TenantManager layout); inputs are staged
    into a stacked slab — both device-resident before the timed region.
    """

    name = "space_time"

    def __init__(self, cache: SuperKernelCache):
        self.cache = cache
        self._xs = None
        self._ws = None
        self._bucket = None
        self._r = 0

    def prepare(self, problems: List[GemmProblem]) -> None:
        self._bucket = problems[0].bucket
        self._r = len(problems)
        self._xs = _sync(jnp.stack([p.x for p in problems]))
        self._ws = _sync(jnp.stack([p.w for p in problems]))
        self.cache.execute_stacked(self._bucket, self._xs, self._ws, self._r)

    def run(self) -> Tuple[Outputs, float]:
        t0 = time.perf_counter()
        out = self.cache.execute_stacked(self._bucket, self._xs, self._ws, self._r)
        dt = time.perf_counter() - t0
        # unstacking happens outside the timed region (consumers read slices
        # of the stacked slab in-place in the real serving path)
        return [out[i] for i in range(self._r)], dt


class Exclusive(Strategy):
    """Single-tenant data-batched upper bound (shared weights, batched inputs)."""

    name = "exclusive"

    def __init__(self):
        self._fn = jax.jit(lambda xs, w: jnp.einsum("rmk,kn->rmn", xs, w))
        self._xs = None
        self._w = None
        self._r = 0

    def prepare(self, problems: List[GemmProblem]) -> None:
        self._r = len(problems)
        self._xs = _sync(jnp.stack([p.x for p in problems]))
        self._w = _sync(jnp.asarray(problems[0].w))  # single tenant: one weight
        _sync(self._fn(self._xs, self._w))

    def run(self) -> Tuple[Outputs, float]:
        t0 = time.perf_counter()
        out = _sync(self._fn(self._xs, self._w))
        return [out[i] for i in range(self._r)], time.perf_counter() - t0
