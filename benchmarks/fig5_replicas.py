"""Figure 5: how many model replicas fit — stacked-weight tenancy vs
per-process replication.

Paper: MPS/time-sharing hit the 16 GB V100 wall at ~18 ResNet-50 replicas
(per-process CUDA context ~= 300 MB each); explicit streams scaled past 60.
Here: measured stacked-pytree bytes per tenant (repro.core.tenancy) vs a
per-process model charging each replica the measured weight bytes + a
300 MB context. Derived column: max replicas under 16 GB (v5e HBM).
"""

from __future__ import annotations

import dataclasses

import jax

from repro.config import get_config, smoke_variant
from repro.core.tenancy import TenantManager, tenant_bytes
from repro.models import build_model

HBM = 16 * 2**30
CONTEXT_BYTES = 300 * 2**20  # per-process framework/context overhead


def run(csv_rows=None):
    print("\n=== Fig 5: replica scaling — stacked tenancy vs per-process ===")
    key = jax.random.PRNGKey(0)

    # measured: stack real smoke-model weights and verify linear growth
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")), dtype="float32")
    m = build_model(cfg)
    tm = TenantManager()
    per = None
    for t in range(8):
        tm.register(t, m.init(jax.random.fold_in(key, t)))
    stacked = tm.stacked()
    per = tenant_bytes(jax.tree.map(lambda x: x[0], stacked))
    total = tenant_bytes(stacked)
    overhead = total - 8 * per
    print(f"measured (stablelm smoke): 8 tenants, {per/2**20:.1f} MiB each, "
          f"stack overhead {overhead} bytes (exactly 0 = no duplication)")
    if csv_rows is not None:
        csv_rows.append(("fig5/stacked_overhead_bytes", float(overhead), "0=ideal"))

    print(f"\n{'arch':28s} {'W (GiB, bf16)':>14s} {'max R stacked':>14s} "
          f"{'max R per-proc':>15s}")
    for arch in ("stablelm-1.6b", "rwkv6-1.6b", "granite-moe-1b-a400m",
                 "paligemma-3b", "qwen2-7b", "granite-3-8b"):
        cfg = get_config(arch)
        w = cfg.param_count() * 2  # bf16 serving weights
        r_stack = HBM // w
        r_proc = HBM // (w + CONTEXT_BYTES)
        print(f"{arch:28s} {w/2**30:14.2f} {r_stack:14d} {r_proc:15d}")
        if csv_rows is not None:
            csv_rows.append((f"fig5/{arch}/max_replicas_stacked", float(r_stack),
                             f"per_proc={r_proc}"))
    print("(single-chip 16 GB; on the pod mesh the tenant axis shards over "
          "`data`, multiplying capacity by 16)")


if __name__ == "__main__":
    run()
