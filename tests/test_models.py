"""Per-architecture smoke tests (assignment deliverable f) + decode
consistency + MoE/SSD component correctness.

Every assigned architecture instantiates a REDUCED same-family variant
(<=2-3 layers, d_model<=512, <=4 experts) and runs one forward/train step
on CPU asserting output shapes + no NaNs; the FULL configs are exercised
only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, list_configs, smoke_variant
from repro.configs import ASSIGNED_ARCHS
from repro.models import build_model
from repro.models.ssm import ssd_scan
from repro.models.transformer import find_unit


def _smoke(arch):
    return dataclasses.replace(smoke_variant(get_config(arch)), dtype="float32")


def test_all_assigned_archs_registered():
    assert set(ASSIGNED_ARCHS) <= set(list_configs())
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch, rng_key):
    cfg = _smoke(arch)
    m = build_model(cfg)
    params = m.init(rng_key)
    B, S = 2, 32
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    pref = None
    if cfg.num_prefix_embeddings:
        fed = cfg.frontend_embed_dim or cfg.d_model
        pref = jax.random.normal(rng_key, (B, cfg.num_prefix_embeddings, fed), jnp.float32)

    def loss_fn(p):
        loss, _ = m.forward_train(p, toks, labels, prefix_embeds=pref)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), arch
    gleaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in gleaves), arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in gleaves), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_serve_shapes(arch, rng_key):
    cfg = _smoke(arch)
    m = build_model(cfg)
    params = m.init(rng_key)
    B, S = 2, 16
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    pref = None
    if cfg.num_prefix_embeddings:
        fed = cfg.frontend_embed_dim or cfg.d_model
        pref = jax.random.normal(rng_key, (B, cfg.num_prefix_embeddings, fed), jnp.float32)
    logits, caches = m.forward_prefill(params, toks, cache_len=S + 4, prefix_embeds=pref)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches2 = m.forward_decode(params, tok, caches, jnp.full((B,), S, jnp.int32))
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch",
    ["stablelm-1.6b", "gemma3-27b", "rwkv6-1.6b", "zamba2-7b", "granite-moe-1b-a400m"],
)
def test_decode_matches_full_forward(arch, rng_key):
    """Incremental decode == full-sequence forward (cache correctness)."""
    cfg = _smoke(arch)
    m = build_model(cfg)
    params = m.init(rng_key)
    B, S_total, S0 = 2, 20, 14
    toks = jax.random.randint(rng_key, (B, S_total), 0, cfg.vocab_size)
    logits, caches = m.forward_prefill(params, toks[:, :S0], cache_len=S_total)
    outs = [logits]
    lengths = jnp.full((B,), S0, jnp.int32)
    for t in range(S0, S_total):
        logits, caches = m.forward_decode(params, toks[:, t], caches, lengths)
        outs.append(logits)
        lengths = lengths + 1
    for i, t_end in enumerate(range(S0, S_total + 1)):
        want, _ = m.forward_prefill(params, toks[:, :t_end], cache_len=S_total)
        scale = max(float(jnp.max(jnp.abs(want))), 1.0)
        np.testing.assert_allclose(
            np.asarray(outs[i]) / scale, np.asarray(want) / scale, atol=2e-4
        )


def test_unit_finding():
    assert find_unit(get_config("stablelm-1.6b"))[1:] == (24, 0)  # unit=1
    unit, reps, rem = find_unit(get_config("zamba2-7b"))
    assert len(unit) == 6 and reps == 13 and rem == 3
    unit, reps, rem = find_unit(get_config("gemma3-27b"))
    assert len(unit) == 6 and reps == 10 and rem == 2  # 5 local : 1 global
    unit, reps, rem = find_unit(get_config("llama4-maverick-400b-a17b"))
    assert len(unit) == 2 and reps == 24 and rem == 0  # dense/moe interleave


def test_zamba2_shared_attention_weights(rng_key):
    """All shared-attn applications must use ONE weight set."""
    cfg = _smoke("zamba2-7b")
    m = build_model(cfg)
    params = m.init(rng_key)
    assert "shared_attn" in params
    # no stacked attn params should exist in the scanned unit
    for k, sub in params["unit"].items():
        flat = jax.tree_util.tree_flatten_with_path(sub)[0]
        for path, _ in flat:
            assert "attn" not in str(path), (k, path)


class TestMoE:
    def _cfg(self):
        return _smoke("granite-moe-1b-a400m")

    def test_capacity_drop_and_gates(self, rng_key):
        from repro.models import moe as moe_mod

        cfg = self._cfg()
        m = moe_mod.moe_init(rng_key, cfg, jnp.float32)
        x = jax.random.normal(rng_key, (2, 16, cfg.d_model), jnp.float32)
        y, aux = moe_mod.moe_forward(m, x, cfg)
        assert y.shape == x.shape
        assert jnp.isfinite(aux) and aux >= 0.0

    def test_identical_tokens_get_identical_outputs(self, rng_key):
        from repro.models import moe as moe_mod

        cfg = self._cfg()
        m = moe_mod.moe_init(rng_key, cfg, jnp.float32)
        x1 = jax.random.normal(rng_key, (1, 8, cfg.d_model), jnp.float32)
        x = jnp.concatenate([x1, x1], axis=0)  # two identical sequences
        y, _ = moe_mod.moe_forward(m, x, cfg)
        np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y[1]), rtol=1e-5, atol=1e-5)


class TestSSD:
    def test_ssd_scan_vs_naive_recurrence(self, rng_key):
        B, S, H, P, N = 2, 37, 3, 4, 5
        ks = jax.random.split(rng_key, 4)
        xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
        Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, S, N), jnp.float32)

        y, final = ssd_scan(xh, dt, A, Bm, Cm, chunk=8)

        # naive per-step oracle
        state = np.zeros((B, H, P, N), np.float64)
        xs, dts, As = np.asarray(xh, np.float64), np.asarray(dt, np.float64), np.asarray(A, np.float64)
        Bs, Cs = np.asarray(Bm, np.float64), np.asarray(Cm, np.float64)
        ys = np.zeros((B, S, H, P), np.float64)
        for t in range(S):
            decay = np.exp(dts[:, t] * As[None, :])                   # (B,H)
            state = decay[..., None, None] * state + np.einsum(
                "bh,bhp,bn->bhpn", dts[:, t], xs[:, t], Bs[:, t]
            )
            ys[:, t] = np.einsum("bhpn,bn->bhp", state, Cs[:, t])
        np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(final), state, rtol=1e-4, atol=1e-4)

    def test_chunk_invariance(self, rng_key):
        B, S, H, P, N = 1, 24, 2, 4, 4
        ks = jax.random.split(rng_key, 4)
        xh = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(jax.random.fold_in(ks[3], 2), (B, S, N))
        y1, f1 = ssd_scan(xh, dt, A, Bm, Cm, chunk=6)
        y2, f2 = ssd_scan(xh, dt, A, Bm, Cm, chunk=24)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4, atol=1e-4)
