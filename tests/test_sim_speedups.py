"""Fast-path equivalence contracts for the hardware-fast simulator.

The perf rewrite (chunked columnar intake, calendar-queue ripeness,
batched absorb, sharded fleet workers) must be INVISIBLE in the output:
same seed in, byte-identical metrics JSON out. These tests pin that by
running the SAME seeded trace through the vectorized path and through
the legacy per-event scan path (calendar off, ``iter_chunks`` hidden)
and diffing the frozen JSON — and, for the fleet, by diffing
``workers=K`` sharded runs against single-process.

The hypothesis variants live at the bottom behind the usual importorskip
guard; plain parametrized versions of the same properties run everywhere.
"""

import json
import pathlib

import pytest

from repro.api import SystemSpec
from repro.config import ScheduleConfig
from repro.sim import (
    ColdStartCostModel,
    CsvReplayTrace,
    FleetSimulator,
    PoissonTrace,
    RooflineCostModel,
    Simulator,
    estimate_capacity_hz,
    fleet_sgemm_mix,
    make_trace,
    paper_sgemm_mix,
)

REPO = pathlib.Path(__file__).resolve().parents[1]
SMOKE_CSV = REPO / "examples" / "traces" / "smoke_replay.csv"


class _PerEventTrace:
    """Wrapper hiding ``iter_chunks`` so ``Simulator.run`` takes the
    per-event submit path; with ``pump._use_calendar`` forced off too,
    this is exactly the pre-rewrite event loop."""

    def __init__(self, trace):
        self._trace = trace

    def __iter__(self):
        return iter(self._trace)


def _mk_trace(process, mix, events, seed):
    model = RooflineCostModel()
    rate = 0.7 * estimate_capacity_hz(mix, model)
    return make_trace(process, mix, rate, events, seed=seed)


def _solo_json(trace, *, legacy, policy="fixed", cold=False):
    sched = ScheduleConfig(batching_policy=policy)
    sim = Simulator(schedule=sched, cost_model=RooflineCostModel())
    if cold:
        model = ColdStartCostModel(sim.pump.cost_model, compile_s=5e-4,
                                   clock=sim.clock)
        sim.pump.cost_model = model
        sim.scheduler.cost_model = model
    if legacy:
        sim.pump._use_calendar = False
        trace = _PerEventTrace(trace)
    return sim.run(trace).to_json()


# ------------------------------------------------- solo path equivalence
class TestChunkedEqualsPerEvent:
    @pytest.mark.parametrize("process", ["poisson", "mmpp", "flash"])
    @pytest.mark.parametrize("policy", ["fixed", "slo_adaptive"])
    def test_processes_and_policies(self, process, policy):
        mix = paper_sgemm_mix(6)
        fast = _solo_json(_mk_trace(process, mix, 4000, seed=7),
                          legacy=False, policy=policy)
        slow = _solo_json(_mk_trace(process, mix, 4000, seed=7),
                          legacy=True, policy=policy)
        assert fast == slow

    @pytest.mark.parametrize("strategy", ["time_only", "space_only",
                                          "space_time"])
    def test_strategies(self, strategy):
        mix = paper_sgemm_mix(6)

        def run(legacy):
            trace = _mk_trace("poisson", mix, 4000, seed=11)
            sim = Simulator(cost_model=RooflineCostModel(strategy=strategy))
            if legacy:
                sim.pump._use_calendar = False
                trace = _PerEventTrace(trace)
            return sim.run(trace).to_json()

        assert run(False) == run(True)

    def test_cold_start_accounting(self):
        """Compile-cache cold starts record per-dispatch series; the
        chunked loop must hit the cache in the same order."""
        mix = paper_sgemm_mix(6)
        fast = _solo_json(_mk_trace("mmpp", mix, 3000, seed=3),
                          legacy=False, cold=True)
        slow = _solo_json(_mk_trace("mmpp", mix, 3000, seed=3),
                          legacy=True, cold=True)
        assert fast == slow

    def test_admission_cap_fallback(self):
        """Per-tenant admission caps force the chunked loop onto its
        slow-submit fallback; outputs must still match."""
        mix = paper_sgemm_mix(6)
        sched = ScheduleConfig(max_pending_per_tenant=8)

        def run(legacy):
            trace = _mk_trace("flash", mix, 4000, seed=5)
            sim = Simulator(schedule=sched, cost_model=RooflineCostModel())
            if legacy:
                sim.pump._use_calendar = False
                trace = _PerEventTrace(trace)
            return sim.run(trace).to_json()

        assert run(False) == run(True)


# ------------------------------------------------------- chunk iterator
class TestIterChunks:
    def test_chunks_equal_arrivals(self):
        """Columnar chunks flatten back to exactly the per-event stream
        (times, spec identity, cost) for a generated trace."""
        mix = paper_sgemm_mix(4)
        trace = _mk_trace("poisson", mix, 5000, seed=1)
        flat = []
        for times, idx, costs, table in trace.iter_chunks():
            assert len(times) == len(idx) == len(costs)
            for t, i, c in zip(times.tolist(), idx.tolist(), costs.tolist()):
                flat.append((t, table[i], c))
        ref = [(a.t_s, a.spec, a.cost) for a in trace]
        assert len(flat) == len(ref) == 5000
        assert flat == ref

    def test_replay_csv_roundtrip(self):
        """The committed smoke CSV rides the generic chunk fallback and
        produces the same simulation as the per-event path."""
        assert SMOKE_CSV.is_file()
        mix = paper_sgemm_mix(4)
        fast = _solo_json(CsvReplayTrace(mix, str(SMOKE_CSV)), legacy=False)
        slow = _solo_json(CsvReplayTrace(mix, str(SMOKE_CSV)), legacy=True)
        assert fast == slow
        assert json.loads(fast)["summary"]["completed"] == 240


# ------------------------------------------------------- ripeness metrics
class TestRipeNudges:
    def test_counted_and_reported(self):
        mix = paper_sgemm_mix(6)
        sim = Simulator(cost_model=RooflineCostModel())
        sim.run(_mk_trace("flash", mix, 3000, seed=9))
        stats = sim.scheduler.stats
        report = sim.scheduler.report()
        assert stats.ripe_nudges >= 0
        assert report["ripe_nudges"] == stats.ripe_nudges


# ------------------------------------------------------- sharded fleet
def _fleet_json(workers, replicas=3, events=4000, seed=2, specs=None,
                schedule=None):
    mix = fleet_sgemm_mix(10)
    rate = 0.7 * replicas * estimate_capacity_hz(mix, RooflineCostModel())
    trace = PoissonTrace(mix, rate, events, seed=seed)
    fleet = FleetSimulator(replicas, router="round_robin", workers=workers,
                           schedule=schedule, specs=specs, compile_s=5e-4)
    return fleet.run(trace).to_json()


class TestShardedFleet:
    @pytest.mark.parametrize("workers", [2, 3])
    def test_byte_identical_to_single_process(self, workers):
        assert _fleet_json(workers) == _fleet_json(1)

    def test_heterogeneous_specs(self):
        kw = dict(replicas=4, specs=["v5e", "v5e_half"],
                  schedule=ScheduleConfig(max_pending_per_tenant=32))
        assert _fleet_json(2, **kw) == _fleet_json(1, **kw)

    def test_more_workers_than_replicas(self):
        # workers clamp to replica count; still identical
        assert _fleet_json(8, replicas=2) == _fleet_json(1, replicas=2)

    def test_spec_level_parity(self):
        base = SystemSpec.from_dict({
            "mode": "sim",
            "workload": {"mix": "fleet", "tenants": 10, "process": "mmpp",
                         "events": 4000, "seed": 4, "rho": 0.7},
            "fleet": {"replicas": 4},
            "router": {"policy": "round_robin"},
            "cost_model": {"compile_us": 500.0},
            "scheduler": {"batching_policy": "fixed"},
        })
        solo = base.build().run_metrics().to_json()
        sharded = base.replace(**{"fleet.workers": 4}) \
                      .build().run_metrics().to_json()
        assert sharded == solo

    def test_rejects_stateful_router(self):
        mix = fleet_sgemm_mix(4)
        trace = PoissonTrace(mix, 1000.0, 100, seed=0)
        fleet = FleetSimulator(2, router="jsq", workers=2)
        with pytest.raises(ValueError, match="round_robin"):
            fleet.run(trace)

    def test_spec_validation_rejects_bad_combos(self):
        base = {
            "mode": "sim",
            "workload": {"mix": "fleet", "tenants": 4},
            "fleet": {"replicas": 2, "workers": 2},
            "router": {"policy": "round_robin"},
            "scheduler": {"batching_policy": "fixed"},
        }
        SystemSpec.from_dict(base)  # valid
        bad_router = {**base, "router": {"policy": "jsq"}}
        with pytest.raises(ValueError, match="round_robin"):
            SystemSpec.from_dict(bad_router)
        bad_auto = {**base, "fleet": {"replicas": 2, "workers": 2,
                                      "autoscale": {"policy": "backlog"}}}
        with pytest.raises(ValueError, match="autoscale"):
            SystemSpec.from_dict(bad_auto)
        bad_sched = {**base,
                     "scheduler": {"batching_policy": "slo_adaptive"}}
        with pytest.raises(ValueError, match="fixed"):
            SystemSpec.from_dict(bad_sched)


# --------------------------------------------------- hypothesis (optional)
def test_equivalence_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        process=st.sampled_from(["poisson", "mmpp", "flash"]),
        policy=st.sampled_from(["fixed", "slo_adaptive"]),
        tenants=st.integers(2, 8),
        seed=st.integers(0, 50),
    )
    def prop(process, policy, tenants, seed):
        mix = paper_sgemm_mix(tenants)
        fast = _solo_json(_mk_trace(process, mix, 1500, seed=seed),
                          legacy=False, policy=policy)
        slow = _solo_json(_mk_trace(process, mix, 1500, seed=seed),
                          legacy=True, policy=policy)
        assert fast == slow

    prop()


def test_sharded_parity_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(
        replicas=st.integers(2, 5),
        workers=st.integers(2, 4),
        seed=st.integers(0, 20),
    )
    def prop(replicas, workers, seed):
        a = _fleet_json(workers, replicas=replicas, events=1500, seed=seed)
        b = _fleet_json(1, replicas=replicas, events=1500, seed=seed)
        assert a == b

    prop()
