"""Benchmark harnesses — one per paper table/figure.

    table1_sgemm     Table 1 / Fig 7: SGEMM R-scaling, 4 strategies
    fig2_batch_sweep Fig 2: batch size vs throughput under an SLO
    fig3_latency     Fig 3: per-tenant latency vs tenant count (model level)
    fig4_predictability  Fig 4: inter-tenant latency spread
    fig5_replicas    Fig 5: replica memory scaling (stacked vs per-process)
    dynamic_trace    §4: stochastic arrivals — cache warmup + latency anneal
    roofline_report  §Roofline: the (arch x shape x mesh) table from dry-runs

Run everything: ``PYTHONPATH=src python -m benchmarks.run``
"""
