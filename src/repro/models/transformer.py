"""Decoder-only LM assembled from a ModelConfig.

The layer stack runs as ``lax.scan`` over the pattern's smallest repeating
unit (dense: unit=1; llama4: [dense, moe]; zamba2: [5x mamba2, shared-attn];
gemma3: [5x local, global]) so that full-scale dry-runs lower to compact HLO
— 81 layers become one scan over 13 units plus a short unrolled remainder.

Zamba2's shared attention block is the one weight-sharing case: its params
live OUTSIDE the scanned (stacked) pytree and are closed over, so every
application reuses the same weights — exactly the paper's semantics.

Three entry points (all pure):
    forward_train   tokens -> (loss, metrics)
    forward_prefill tokens -> (last-token logits, caches)
    forward_decode  token  -> (logits, new caches)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionKind, BlockKind, Modality, ModelConfig
from repro.distributed.constraints import constrain
from repro.models import attention, layers, moe, rwkv, ssm

Params = Dict[str, Any]
Cache = Any


# ----------------------------------------------------------------- unit finding
def _extended_pattern(cfg: ModelConfig) -> List[Tuple[BlockKind, AttentionKind]]:
    return [
        (kind, cfg.attention_kind_at(i)) for i, kind in enumerate(cfg.layer_pattern)
    ]


def find_unit(cfg: ModelConfig) -> Tuple[List[Tuple[BlockKind, AttentionKind]], int, int]:
    """Smallest repeating unit of (block kind, attention kind).

    Returns (unit, num_repeats, num_remainder). Remainder layers (pattern
    tail shorter than one unit) are unrolled.
    """
    ext = _extended_pattern(cfg)
    n = len(ext)
    for u in range(1, n + 1):
        unit = ext[:u]
        reps = n // u
        if reps == 0:
            continue
        if all(ext[i] == unit[i % u] for i in range(reps * u)):
            rem = n - reps * u
            if all(ext[reps * u + j] == unit[j] for j in range(rem)):
                return unit, reps, rem
    return ext, 1, 0  # fallback: whole pattern as one unit


# ----------------------------------------------------------------- block init
def _block_init(key: jax.Array, kind: BlockKind, cfg: ModelConfig, dtype) -> Params:
    keys = jax.random.split(key, 2)
    if kind in (BlockKind.ATTN_MLP, BlockKind.HYBRID_SHARED_ATTN):
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model, dtype),
            "attn": attention.attn_init(keys[0], cfg, dtype),
            "norm2": layers.rmsnorm_init(cfg.d_model, dtype),
            "mlp": layers.mlp_init(keys[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype),
        }
    if kind == BlockKind.ATTN_MOE:
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model, dtype),
            "attn": attention.attn_init(keys[0], cfg, dtype),
            "norm2": layers.rmsnorm_init(cfg.d_model, dtype),
            "moe": moe.moe_init(keys[1], cfg, dtype),
        }
    if kind == BlockKind.MAMBA2:
        return {
            "norm": layers.rmsnorm_init(cfg.d_model, dtype),
            "mamba": ssm.mamba2_init(keys[0], cfg, dtype),
        }
    if kind == BlockKind.RWKV6:
        return rwkv.rwkv6_init(keys[0], cfg, dtype)
    raise ValueError(kind)


def _block_cache(
    kind: BlockKind,
    attn_kind: AttentionKind,
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    dtype,
) -> Cache:
    if kind in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE, BlockKind.HYBRID_SHARED_ATTN):
        return attention.init_cache(cfg, attn_kind, batch, seq_len, dtype)
    if kind == BlockKind.MAMBA2:
        return ssm.init_cache(cfg, batch, dtype)
    if kind == BlockKind.RWKV6:
        return rwkv.init_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ----------------------------------------------------------------- block apply
def _apply_block(
    params: Params,
    x: jax.Array,
    kind: BlockKind,
    attn_kind: AttentionKind,
    cfg: ModelConfig,
    mode: str,                      # "train" | "prefill" | "decode"
    cache: Optional[Cache],
    lengths: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[Cache], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)

    if kind in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE, BlockKind.HYBRID_SHARED_ATTN):
        h = layers.rmsnorm(params["norm1"], x, cfg.norm_eps)
        if mode == "train":
            a = attention.attn_forward(params["attn"], h, cfg, attn_kind)
            new_cache = None
        elif mode == "prefill":
            a, new_cache = attention.attn_prefill_with_cache(
                params["attn"], h, cfg, attn_kind, cache
            )
        elif mode == "prefill_continue":
            a, new_cache = attention.attn_prefill_continue(
                params["attn"], h, cfg, attn_kind, cache, lengths
            )
        else:
            a, new_cache = attention.attn_decode(
                params["attn"], h, cfg, attn_kind, cache, lengths
            )
        x = x + a
        h = layers.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if kind == BlockKind.ATTN_MOE:
            y, aux = moe.moe_forward(params["moe"], h, cfg)
        else:
            y = layers.mlp(params["mlp"], h, cfg.mlp_gated)
        return x + y, new_cache, aux

    if kind == BlockKind.MAMBA2:
        h = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
        if mode == "decode":
            y, new_cache = ssm.mamba2_decode(params["mamba"], h, cfg, cache)
        elif mode == "prefill_continue":
            y, new_cache = ssm.mamba2_forward(
                params["mamba"], h, cfg, return_cache=True, init_cache_state=cache
            )
        else:
            y, new_cache = ssm.mamba2_forward(
                params["mamba"], h, cfg, return_cache=(mode == "prefill")
            )
            if mode == "train":
                new_cache = None
        return x + y, new_cache, aux

    if kind == BlockKind.RWKV6:
        if mode == "train":
            dummy = rwkv.init_cache(cfg, x.shape[0], x.dtype)
            y, _ = rwkv.rwkv6_block(params, x, cfg, dummy, "train")
            return y, None, aux
        # rwkv's "prefill" path is already continuation-correct: it honors
        # the incoming wkv/shift state, zero or not.
        rmode = "prefill" if mode == "prefill_continue" else mode
        y, new_cache = rwkv.rwkv6_block(params, x, cfg, cache, rmode)
        return y, new_cache, aux

    raise ValueError(kind)


# ----------------------------------------------------------------- model
@dataclasses.dataclass(frozen=True)
class Model:
    """Bundles a config with pure apply functions (params are external).

    remat: "none" | "block" — "block" wraps the scanned unit body in
    jax.checkpoint for training (activation memory = one residual per layer,
    everything else recomputed in the backward pass).
    """

    cfg: ModelConfig
    remat: str = "block"

    # -------------------------------------------------------------- init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        unit, reps, rem = find_unit(cfg)
        keys = jax.random.split(key, 8)

        params: Params = {
            "embed": layers.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(
                keys[1], cfg.d_model, cfg.vocab_size, dtype
            )
        if cfg.num_prefix_embeddings:
            fed = cfg.frontend_embed_dim or cfg.d_model
            params["frontend_proj"] = layers.dense_init(keys[2], fed, cfg.d_model, dtype)

        # shared attention block (zamba2): single copy
        if any(k == BlockKind.HYBRID_SHARED_ATTN for k, _ in unit):
            params["shared_attn"] = _block_init(
                keys[3], BlockKind.HYBRID_SHARED_ATTN, cfg, dtype
            )

        # stacked per-unit params
        unit_keys = jax.random.split(keys[4], max(reps, 1) * len(unit)).reshape(
            max(reps, 1), len(unit), -1
        )
        unit_params: Dict[str, Any] = {}
        for p, (kind, _) in enumerate(unit):
            if kind == BlockKind.HYBRID_SHARED_ATTN:
                continue  # shared, not stacked
            per_unit = [
                _block_init(unit_keys[r, p], kind, cfg, dtype) for r in range(reps)
            ]
            unit_params[f"pos{p}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_unit)
        params["unit"] = unit_params

        # remainder layers, unrolled
        rem_keys = jax.random.split(keys[5], max(rem, 1))
        rem_params: Dict[str, Any] = {}
        for j in range(rem):
            kind, _ = unit[j]
            if kind == BlockKind.HYBRID_SHARED_ATTN:
                continue
            rem_params[f"rem{j}"] = _block_init(rem_keys[j], kind, cfg, dtype)
        params["rem"] = rem_params
        return params

    # -------------------------------------------------------------- caches
    def init_caches(self, batch: int, seq_len: int, dtype=None) -> Cache:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        unit, reps, rem = find_unit(cfg)
        unit_caches = {
            f"pos{p}": jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape).copy()
                if reps > 1
                else x[None],
                _block_cache(kind, ak, cfg, batch, seq_len, dtype),
            )
            for p, (kind, ak) in enumerate(unit)
        }
        rem_caches = {
            f"rem{j}": _block_cache(unit[j][0], unit[j][1], cfg, batch, seq_len, dtype)
            for j in range(rem)
        }
        return {"unit": unit_caches, "rem": rem_caches}

    # -------------------------------------------------------------- embedding
    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_embed:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        return constrain(x, "batch", None, None)

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        if cfg.logit_softcap > 0.0:
            logits = layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        return constrain(logits, "batch", None, "model")

    # -------------------------------------------------------------- stack walk
    def _run_stack(
        self,
        params: Params,
        x: jax.Array,
        mode: str,
        caches: Optional[Cache],
        lengths: Optional[jax.Array],
    ) -> Tuple[jax.Array, Optional[Cache], jax.Array]:
        cfg = self.cfg
        unit, reps, rem = find_unit(cfg)
        shared = params.get("shared_attn")
        aux_total = jnp.zeros((), jnp.float32)

        def unit_body(carry, xs):
            x, aux = carry
            x = constrain(x, "batch", None, None)
            u_params, u_caches = xs
            new_caches = {}
            for p, (kind, ak) in enumerate(unit):
                pkey = f"pos{p}"
                bparams = shared if kind == BlockKind.HYBRID_SHARED_ATTN else u_params[pkey]
                bcache = None if u_caches is None else u_caches[pkey]
                if mode == "train" and self.remat == "block":
                    # per-block remat INSIDE the unit: the unit-level
                    # checkpoint bounds the scan, this bounds the recompute
                    # working set to one block's internals.
                    x, a = jax.checkpoint(
                        lambda bp, xx, _kind=kind, _ak=ak: _apply_block(
                            bp, xx, _kind, _ak, cfg, mode, None, None
                        )[::2],
                        prevent_cse=False,
                    )(bparams, x)
                    nc = None
                else:
                    x, nc, a = _apply_block(
                        bparams, x, kind, ak, cfg, mode, bcache, lengths
                    )
                aux = aux + a
                if u_caches is not None:
                    new_caches[pkey] = nc
            return (x, aux), (new_caches if u_caches is not None else 0)

        # scanned segment
        if reps > 0:
            unit_caches = None if caches is None else caches["unit"]
            # shared-attn positions have no stacked params; give scan a dummy leaf
            u_params_xs = dict(params["unit"])
            for p, (kind, _) in enumerate(unit):
                if kind == BlockKind.HYBRID_SHARED_ATTN:
                    u_params_xs[f"pos{p}"] = jnp.zeros((reps,), jnp.int8)  # placeholder

            def unit_body_wrapped(carry, xs):
                u_params, u_caches = xs
                # restore sentinel -> shared handled inside unit_body
                return unit_body(carry, (u_params, u_caches))

            if mode == "train" and self.remat == "block":
                unit_body_wrapped = jax.checkpoint(
                    unit_body_wrapped, prevent_cse=False
                )

            (x, aux_total), new_unit_caches = jax.lax.scan(
                unit_body_wrapped,
                (x, aux_total),
                (u_params_xs, unit_caches),
            )
        else:
            new_unit_caches = None

        # remainder, unrolled
        new_rem_caches = {}
        for j in range(rem):
            kind, ak = unit[j]
            bparams = shared if kind == BlockKind.HYBRID_SHARED_ATTN else params["rem"][f"rem{j}"]
            bcache = None if caches is None else caches["rem"][f"rem{j}"]
            x, nc, a = _apply_block(bparams, x, kind, ak, cfg, mode, bcache, lengths)
            aux_total = aux_total + a
            if caches is not None:
                new_rem_caches[f"rem{j}"] = nc

        new_caches = (
            None if caches is None else {"unit": new_unit_caches, "rem": new_rem_caches}
        )
        return x, new_caches, aux_total

    # -------------------------------------------------------------- entrypoints
    def forward_train(
        self,
        params: Params,
        tokens: jax.Array,
        labels: jax.Array,
        prefix_embeds: Optional[jax.Array] = None,
        loss_mask: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """tokens/labels: (B, S) (S includes prefix positions for VLM/audio)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if cfg.num_prefix_embeddings and prefix_embeds is not None:
            pref = prefix_embeds.astype(x.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([pref, x[:, prefix_embeds.shape[1]:, :]], axis=1)
            pmask = jnp.arange(x.shape[1])[None, :] >= prefix_embeds.shape[1]
            loss_mask = pmask if loss_mask is None else loss_mask * pmask
        x, _, aux = self._run_stack(params, x, "train", None, None)
        logits = self._logits(params, x)
        ce = layers.cross_entropy(logits, labels, loss_mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "loss": loss}

    def forward_prefill(
        self,
        params: Params,
        tokens: jax.Array,
        cache_len: int,
        prefix_embeds: Optional[jax.Array] = None,
        caches: Optional[Cache] = None,
        start: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Cache]:
        """Prefill. Returns (last-position logits, caches).

        Fresh sequences: leave ``caches``/``start`` unset. CHUNKED
        continuation: pass the previous chunk's caches and the absolute
        position of this chunk's first token (traced scalar) — one compile
        per chunk length, exact state carry for attention/SSM/RWKV. Not
        supported for sliding-window ring caches (gemma3-style local
        layers raise NotImplementedError).
        """
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens)
        if cfg.num_prefix_embeddings and prefix_embeds is not None:
            pref = prefix_embeds.astype(x.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([pref, x[:, prefix_embeds.shape[1]:, :]], axis=1)
        if caches is None:
            caches = self.init_caches(B, cache_len)
            x, new_caches, _ = self._run_stack(params, x, "prefill", caches, None)
        else:
            start = jnp.asarray(0 if start is None else start, jnp.int32)
            x, new_caches, _ = self._run_stack(
                params, x, "prefill_continue", caches, start
            )
        logits = self._logits(params, x[:, -1:, :])
        return logits[:, 0, :], new_caches

    def forward_decode(
        self,
        params: Params,
        token: jax.Array,       # (B,) int32 current token
        caches: Cache,
        lengths: jax.Array,     # (B,) tokens already in cache
    ) -> Tuple[jax.Array, Cache]:
        """One decode step. Returns (logits (B, V), new caches)."""
        x = self._embed(params, token[:, None])
        x, new_caches, _ = self._run_stack(params, x, "decode", caches, lengths)
        logits = self._logits(params, x)
        return logits[:, 0, :], new_caches


def build_model(cfg: ModelConfig, remat: str = "block") -> Model:
    return Model(cfg, remat=remat)
