"""Composable arrival-process generators — timestamped workload streams.

A *trace* is an iterable of time-ordered ``Arrival(t_s, spec, cost)``
events over a heterogeneous tenant mix. Traces are the input language of
the discrete-event simulator (``repro.sim.simulator``) and the pacing
source for live replay (``benchmarks/dynamic_trace.py``): the SAME seeded
generator drives both, so a live wall-clock run and a virtual-clock
simulation see bit-identical arrival sequences.

Processes (all deterministic per seed, generated lazily in vectorized
numpy chunks so million-event traces stream in O(chunk) memory):

    PoissonTrace          -- homogeneous Poisson arrivals (the paper's
                             stochastic-query setting)
    MarkovModulatedTrace  -- 2-state MMPP: calm/burst regimes with
                             exponential dwell times (bursty online traffic)
    DiurnalTrace          -- sinusoidal rate over a configurable period
                             (day/night load curves), via thinning
    FlashCrowdTrace       -- constant base rate plus a rate spike window
                             (launch-day / retry-storm shape)
    CsvReplayTrace        -- replay recorded ``t_s,tenant`` rows (real
                             production timestamps)

Tenant mixes are lists of ``TenantSpec`` — one entry per (tenant,
workload-class) with a mergeability bucket, roofline quantities
(flops/bytes), an SLO, and an arrival weight. Two builders cover the
repo's two scheduling layers:

    paper_sgemm_mix       -- kernel-level GEMM tenants over the paper's
                             Table-1 shapes (tiered SLOs)
    prefill_decode_mix    -- engine-shaped cohorts: rare compile-heavy
                             prefills + frequent decode steps per tenant,
                             bucketed exactly like MultiTenantEngine
                             submits them
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import operator
from typing import Hashable, Iterable, Iterator, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.queue import ShapeBucket
from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES

_CHUNK = 8192


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant workload class: what arrives, how it merges, what it owes.

    ``bucket`` uses the same key types the live schedulers use
    (``ShapeBucket`` for GEMMs, ``("decode", "cohort")`` tuples for engine
    cohorts) so calibrated cost-model entries measured on live runs
    resolve for simulated batches too.
    """

    tenant_id: int
    name: str
    bucket: Hashable
    cost: float                 # abstract work units (FLOPs / tokens)
    flops: float                # roofline compute term per arrival
    bytes: float                # roofline HBM term per arrival
    slo_s: float
    kind: str = "default"
    merge_family: Optional[Hashable] = None
    weight: float = 1.0         # relative arrival share within the mix


class Arrival(NamedTuple):
    t_s: float
    spec: TenantSpec
    cost: float


#: One columnar block of arrivals: ``(times, spec_idx, costs, specs)``.
#: ``times``/``costs`` are float64 arrays, ``spec_idx`` indexes int64 into
#: the ``specs`` table — the table is shared (and may grow) across blocks.
TraceChunk = Tuple[np.ndarray, np.ndarray, np.ndarray, List[TenantSpec]]


class Trace:
    """Iterable of time-ordered arrivals; ``+`` composes two traces."""

    def __iter__(self) -> Iterator[Arrival]:
        raise NotImplementedError

    def iter_chunks(self) -> Iterator[TraceChunk]:
        """Columnar view of the arrival stream in ``_CHUNK``-event blocks.

        Yields ``(times, spec_idx, costs, specs)`` with specs interned
        into an int-indexed table, so consumers touch numpy columns and
        small-int indices instead of one ``Arrival`` namedtuple (and one
        ``TenantSpec`` hash) per event. The event VALUES are exactly the
        ones ``__iter__`` yields — this is a representation change, not a
        resampling — so chunked and per-event consumers see bit-identical
        streams.

        This generic fallback batches ``__iter__`` (correct for any
        trace, including CSV replay and merged traces); generated mixes
        override it with a vectorized path that skips the per-event hop
        entirely.
        """
        table: List[TenantSpec] = []
        index: dict = {}            # id(spec) -> table slot
        ts: List[float] = []
        ii: List[int] = []
        cs: List[float] = []
        for t, spec, cost in self:
            j = index.get(id(spec))
            if j is None:
                j = len(table)
                index[id(spec)] = j
                table.append(spec)
            ts.append(t)
            ii.append(j)
            cs.append(cost)
            if len(ts) >= _CHUNK:
                yield (np.asarray(ts, np.float64), np.asarray(ii, np.int64),
                       np.asarray(cs, np.float64), table)
                ts, ii, cs = [], [], []
        if ts:
            yield (np.asarray(ts, np.float64), np.asarray(ii, np.int64),
                   np.asarray(cs, np.float64), table)

    def __add__(self, other: "Trace") -> "Trace":
        return MergedTrace(self, other)


class MergedTrace(Trace):
    """Time-ordered merge of component traces (composition operator)."""

    def __init__(self, *traces: Trace):
        self.traces = traces

    def __iter__(self) -> Iterator[Arrival]:
        # attrgetter, not a lambda: heapq.merge evaluates the key once per
        # yielded event, and the C-level getter shaves ~0.2us each — a
        # micro-regression that compounds at million-event scale
        return heapq.merge(*self.traces, key=operator.attrgetter("t_s"))


class _MixTrace(Trace):
    """Shared machinery: per-chunk tenant assignment over mix weights."""

    def __init__(self, mix: Sequence[TenantSpec], events: int, seed: int = 0,
                 start_s: float = 0.0):
        if not mix:
            raise ValueError("tenant mix must be non-empty")
        if events < 0:
            raise ValueError(f"events must be >= 0, got {events}")
        self.mix = list(mix)
        self.events = int(events)
        self.seed = seed
        self.start_s = float(start_s)
        w = np.array([s.weight for s in self.mix], np.float64)
        if np.any(w < 0) or w.sum() <= 0:
            raise ValueError("spec weights must be non-negative with a positive sum")
        self._cum_w = np.cumsum(w / w.sum())
        # float cumsum can land one ULP below 1.0 — exactly the largest
        # value rng.random() can draw, which would searchsorted past the
        # last spec; pin the tail so every draw lands in range
        self._cum_w[-1] = 1.0

    def _init_state(self, rng: np.random.Generator) -> dict:
        """Per-iteration generator state (kept off the instance so two
        concurrent iterations of one trace object stay independent)."""
        return {}

    def _times(self, rng: np.random.Generator, n: int, t0: float,
               state: dict) -> np.ndarray:
        """Return ``n`` monotone arrival times starting after ``t0``."""
        raise NotImplementedError

    def __iter__(self) -> Iterator[Arrival]:
        mix = self.mix
        for times, idx, _costs, _table in self.iter_chunks():
            for t, i in zip(times, idx):
                spec = mix[i]
                yield Arrival(float(t), spec, spec.cost)

    def iter_chunks(self) -> Iterator[TraceChunk]:
        """Vectorized chunk path: the same RNG draws and chunk boundaries
        as the historical per-event iterator (``t0`` restarts each block
        from ``float(times[-1])``, so block size is part of the float
        accumulation and must stay ``_CHUNK``), minus the per-event
        namedtuple hop."""
        rng = np.random.default_rng(self.seed)
        mix, cum_w = self.mix, self._cum_w
        costs = np.array([s.cost for s in mix], np.float64)
        state = self._init_state(rng)
        remaining, t0 = self.events, self.start_s
        while remaining > 0:
            n = min(_CHUNK, remaining)
            times = self._times(rng, n, t0, state)
            idx = np.searchsorted(cum_w, rng.random(n), side="right")
            yield times, idx.astype(np.int64, copy=False), costs[idx], mix
            t0 = float(times[-1])
            remaining -= n


class PoissonTrace(_MixTrace):
    """Homogeneous Poisson arrivals at ``rate_hz`` over the mix."""

    def __init__(self, mix: Sequence[TenantSpec], rate_hz: float, events: int,
                 seed: int = 0, start_s: float = 0.0):
        super().__init__(mix, events, seed, start_s)
        if rate_hz <= 0:
            raise ValueError(f"rate_hz must be > 0, got {rate_hz}")
        self.rate_hz = float(rate_hz)

    def _times(self, rng, n, t0, state):
        return t0 + np.cumsum(rng.exponential(1.0 / self.rate_hz, n))


class MarkovModulatedTrace(_MixTrace):
    """2-state MMPP: Poisson at ``calm_hz``/``burst_hz`` with exponential
    dwell times — the classic bursty-traffic model."""

    def __init__(self, mix: Sequence[TenantSpec], calm_hz: float, burst_hz: float,
                 events: int, mean_calm_s: float = 1.0, mean_burst_s: float = 0.2,
                 seed: int = 0, start_s: float = 0.0):
        super().__init__(mix, events, seed, start_s)
        if calm_hz <= 0 or burst_hz <= 0:
            raise ValueError("state rates must be > 0")
        self.rates = (float(calm_hz), float(burst_hz))
        self.dwells = (float(mean_calm_s), float(mean_burst_s))

    def _init_state(self, rng):
        return {"regime": 0,
                "next_switch": self.start_s + rng.exponential(self.dwells[0])}

    def _times(self, rng, n, t0, state):
        out = np.empty(n, np.float64)
        t, k = t0, 0
        regime, next_switch = state["regime"], state["next_switch"]
        while k < n:
            t = t + rng.exponential(1.0 / self.rates[regime])
            while t >= next_switch:
                # first-order regime change: restart the inter-arrival gap
                # at the switch point under the new state's rate
                regime = 1 - regime
                t = next_switch + rng.exponential(1.0 / self.rates[regime])
                next_switch = next_switch + rng.exponential(self.dwells[regime])
            out[k] = t
            k += 1
        state["regime"], state["next_switch"] = regime, next_switch
        return out


class _ThinnedTrace(_MixTrace):
    """Non-homogeneous Poisson via Lewis-Shedler thinning against a
    constant majorant rate."""

    peak_hz: float = 1.0

    def _rate_at(self, t: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _times(self, rng, n, t0, state):
        out = np.empty(n, np.float64)
        filled, t = 0, t0
        while filled < n:
            cand = t + np.cumsum(rng.exponential(1.0 / self.peak_hz, max(n - filled, 64)))
            keep = cand[rng.random(cand.shape[0]) * self.peak_hz < self._rate_at(cand)]
            take = min(keep.shape[0], n - filled)
            out[filled:filled + take] = keep[:take]
            filled += take
            t = float(cand[-1])
        return out


class DiurnalTrace(_ThinnedTrace):
    """Sinusoidal rate between ``trough_hz`` and ``peak_hz`` with period
    ``period_s`` — the day/night load curve, time-compressed."""

    def __init__(self, mix: Sequence[TenantSpec], trough_hz: float, peak_hz: float,
                 period_s: float, events: int, seed: int = 0, start_s: float = 0.0):
        super().__init__(mix, events, seed, start_s)
        if not (0 < trough_hz <= peak_hz):
            raise ValueError("need 0 < trough_hz <= peak_hz")
        self.trough_hz, self.peak_hz = float(trough_hz), float(peak_hz)
        self.period_s = float(period_s)

    def _rate_at(self, t):
        mid = (self.peak_hz + self.trough_hz) / 2.0
        amp = (self.peak_hz - self.trough_hz) / 2.0
        return mid + amp * np.sin(2.0 * math.pi * t / self.period_s)


class FlashCrowdTrace(_ThinnedTrace):
    """Constant ``base_hz`` plus a ``spike_hz`` window — launch-day load."""

    def __init__(self, mix: Sequence[TenantSpec], base_hz: float, spike_hz: float,
                 spike_start_s: float, spike_duration_s: float, events: int,
                 seed: int = 0, start_s: float = 0.0):
        super().__init__(mix, events, seed, start_s)
        if not (0 < base_hz <= spike_hz):
            raise ValueError("need 0 < base_hz <= spike_hz")
        self.base_hz, self.peak_hz = float(base_hz), float(spike_hz)
        self.spike = (float(spike_start_s), float(spike_start_s + spike_duration_s))

    def _rate_at(self, t):
        lo, hi = self.spike
        return np.where((t >= lo) & (t < hi), self.peak_hz, self.base_hz)


class CsvReplayTrace(Trace):
    """Replay recorded arrivals: rows of ``t_s,spec_index`` (or
    ``t_s,spec_name``) against a tenant mix.

    ``rows`` may be a path to a CSV file or any iterable of strings —
    production trace replay without a separate code path.
    """

    def __init__(self, mix: Sequence[TenantSpec], rows):
        self.mix = list(mix)
        self.rows = rows
        self._by_name = {s.name: s for s in self.mix}

    def _resolve(self, token: str) -> TenantSpec:
        token = token.strip()
        if token in self._by_name:
            return self._by_name[token]
        return self.mix[int(token)]

    def __iter__(self) -> Iterator[Arrival]:
        rows: Iterable[str]
        close = None
        if isinstance(self.rows, str):
            fh = open(self.rows)
            rows, close = fh, fh.close
        else:
            rows = self.rows
        try:
            last_t = -math.inf
            for line in rows:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                t_str, spec_str = line.split(",")[:2]
                t = float(t_str)
                if t < last_t:
                    raise ValueError(f"CSV trace times must be non-decreasing ({t} < {last_t})")
                last_t = t
                spec = self._resolve(spec_str)
                yield Arrival(t, spec, spec.cost)
        finally:
            if close is not None:
                close()


# --------------------------------------------------------------- tenant mixes
def paper_sgemm_mix(
    tenants: int,
    slo_tiers_s: Sequence[float] = (0.005, 0.010, 0.025),
    shapes: Optional[Sequence[str]] = None,
    dtype: str = "float32",
) -> List[TenantSpec]:
    """Kernel-level mix: each tenant repeatedly launches one of the paper's
    Table-1 SGEMM geometries under a tiered SLO.

    Buckets are real ``ShapeBucket`` keys and merge families match
    ``GemmProblem.merge_family``, so a cost model calibrated on live
    ``dynamic_trace`` dispatches prices these simulated batches directly.
    """
    names = list(shapes or PAPER_GEMM_SHAPES)
    dt_bytes = 4 if dtype == "float32" else 2
    out = []
    for t in range(tenants):
        g = PAPER_GEMM_SHAPES[names[t % len(names)]]
        bucket = ShapeBucket("gemm", g.M, g.K, g.N, dtype)
        out.append(TenantSpec(
            tenant_id=t,
            name=f"t{t}/{g.name}",
            bucket=bucket,
            cost=float(g.flops),
            flops=float(g.flops),
            bytes=float(dt_bytes * (g.M * g.K + g.K * g.N + g.M * g.N)),
            slo_s=float(slo_tiers_s[t % len(slo_tiers_s)]),
            kind="kernel",
            merge_family=(bucket.op, bucket.K, bucket.N, bucket.dtype),
        ))
    return out


def fleet_sgemm_mix(
    tenants: int,
    zipf_a: float = 1.1,
    slo_tiers_s: Sequence[float] = (0.005, 0.010, 0.025),
    shapes: Optional[Sequence[str]] = None,
    dtype: str = "float32",
) -> List[TenantSpec]:
    """Fleet-scale mix: many tenants with Zipf-distributed arrival shares.

    Same Table-1 GEMM tenants as ``paper_sgemm_mix``, but tenant t's
    arrival weight is ``(t+1)^-zipf_a`` — a few hot tenants dominate the
    stream, the long tail trickles. That skew is what makes fleet routing
    a real decision: sticky/affinity policies keep a hot tenant's compiled
    variants warm on few replicas, load balancers spread its traffic (and
    its compiles) everywhere. ``zipf_a=0`` recovers the uniform mix.
    """
    if zipf_a < 0.0:
        raise ValueError(f"zipf_a must be >= 0, got {zipf_a}")
    return [
        dataclasses.replace(spec, weight=float((t + 1) ** -zipf_a))
        for t, spec in enumerate(
            paper_sgemm_mix(tenants, slo_tiers_s=slo_tiers_s,
                            shapes=shapes, dtype=dtype))
    ]


def prefill_decode_mix(
    tenants: int,
    prompt_tokens: int = 128,
    decode_slots: int = 4,
    active_params: float = 1.6e9,
    decode_slo_s: float = 0.020,
    prefill_slo_s: float = 0.250,
    decode_per_prefill: float = 64.0,
    dtype_bytes: int = 2,
) -> List[TenantSpec]:
    """Engine-shaped cohort mix: per tenant, a rare prefill stream plus a
    frequent decode-step stream, bucketed exactly as ``MultiTenantEngine``
    submits them (prefills merge by prompt length, decode cohorts share one
    bucket). Decode is weight-streaming memory-bound; prefill is
    compute-heavy — the roofline prior prices them accordingly.
    """
    out = []
    param_bytes = active_params * dtype_bytes
    for t in range(tenants):
        out.append(TenantSpec(
            tenant_id=t,
            name=f"t{t}/prefill",
            bucket=("prefill", prompt_tokens),
            cost=float(prompt_tokens),
            flops=2.0 * active_params * prompt_tokens,
            bytes=param_bytes + 8.0 * prompt_tokens * dtype_bytes * 2048,
            slo_s=prefill_slo_s,
            kind="prefill",
            weight=1.0,
        ))
        out.append(TenantSpec(
            tenant_id=t,
            name=f"t{t}/decode",
            bucket=("decode", "cohort"),
            cost=float(decode_slots),
            flops=2.0 * active_params * decode_slots,
            bytes=param_bytes,
            slo_s=decode_slo_s,
            kind="decode",
            weight=decode_per_prefill,
        ))
    return out


def make_trace(
    process: str,
    mix: Sequence[TenantSpec],
    rate_hz: float,
    events: int,
    seed: int = 0,
) -> Trace:
    """Name-keyed trace factory (the CLI surface of this module)."""
    if process == "poisson":
        return PoissonTrace(mix, rate_hz, events, seed=seed)
    if process == "mmpp":
        return MarkovModulatedTrace(
            mix, calm_hz=rate_hz * 0.5, burst_hz=rate_hz * 3.0, events=events,
            mean_calm_s=2000.0 / rate_hz, mean_burst_s=400.0 / rate_hz, seed=seed)
    if process == "diurnal":
        return DiurnalTrace(
            mix, trough_hz=rate_hz * 0.25, peak_hz=rate_hz * 1.75,
            period_s=events / rate_hz / 4.0, events=events, seed=seed)
    if process == "flash":
        horizon = events / rate_hz
        return FlashCrowdTrace(
            mix, base_hz=rate_hz * 0.6, spike_hz=rate_hz * 4.0,
            spike_start_s=horizon * 0.4, spike_duration_s=horizon * 0.1,
            events=events, seed=seed)
    raise ValueError(f"unknown arrival process: {process!r}")
