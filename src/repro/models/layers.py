"""Shared primitive layers: norms, RoPE, MLPs, embeddings.

All layers are pure functions of (params, inputs); params are nested dicts
of arrays so they stack/shard/vmap trivially (the tenant axis of the
space-time scheduler is a vmap over these pytrees).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------- init
def dense_init(key: jax.Array, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norm
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 kept ONLY for the variance reduction.

    The normalized tensor itself stays in the residual dtype: materializing
    an f32 copy of (B,S,d) makes XLA hoist the convert before GSPMD's
    resharding collectives, doubling all-gather/all-reduce bytes of the
    residual stream (measured ~25 GiB/step on zamba2 train_4k).
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def groupnorm_heads(x: jax.Array, num_heads: int, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS normalization over the trailing dim split into heads.

    Used by RWKV6 (ln_x over heads) and Mamba2's gated norm variant.
    x: (..., H*P) -> normalized per (H,) group.
    """
    shape = x.shape
    xh = x.reshape(*shape[:-1], num_heads, shape[-1] // num_heads).astype(jnp.float32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    out = xh * jax.lax.rsqrt(var + eps)
    return out.reshape(shape).astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, D) with D even; positions: broadcastable to (..., S).
    """
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- mlp
def mlp_init(key: jax.Array, d_model: int, d_ff: int, gated: bool, dtype) -> Params:
    keys = jax.random.split(key, 3)
    p: Params = {
        "up": dense_init(keys[0], d_model, d_ff, dtype),
        "down": dense_init(keys[1], d_ff, d_model, dtype),
    }
    if gated:
        p["gate"] = dense_init(keys[2], d_model, d_ff, dtype)
    return p


def mlp(params: Params, x: jax.Array, gated: bool) -> jax.Array:
    up = x @ params["up"]
    if gated:
        h = jax.nn.silu(x @ params["gate"]) * up
    else:
        h = jax.nn.gelu(up)
    if h.ndim == 3:
        h = constrain(h, "batch", None, "model")
    return h @ params["down"]


# --------------------------------------------------------------------------- misc
def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean next-token cross-entropy. logits (B,S,V), labels (B,S).

    The gold logit is selected with an iota-compare-select reduction rather
    than take_along_axis: a vocab-dim gather forces GSPMD to all-gather
    vocab-sharded logits onto every device, while the select form stays
    elementwise on the shard and reduces with a cheap psum.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(labels.dtype, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
