"""Train loop: jitted step (optionally pjit-sharded), metrics, checkpoints."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWState, adamw_init, adamw_update, lr_schedule


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: int = 0


def make_train_step(
    model: Model,
    *,
    base_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 1000,
    weight_decay: float = 0.1,
) -> Callable:
    """Builds the pure (params, opt, tokens, labels) -> updated step fn."""

    def train_step(params, opt: AdamWState, tokens, labels):
        def loss_fn(p):
            loss, metrics = model.forward_train(p, tokens, labels)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = lr_schedule(opt.step, base_lr, warmup_steps, total_steps)
        params, opt, opt_metrics = adamw_update(
            grads, opt, params, lr, weight_decay=weight_decay
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt, metrics

    return train_step


def train(
    model: Model,
    data: Iterable[Tuple[Any, Any]],
    *,
    steps: int,
    seed: int = 0,
    base_lr: float = 3e-4,
    warmup_steps: int = 100,
    weight_decay: float = 0.1,
    log_every: int = 10,
    checkpoint_path: Optional[str] = None,
    checkpoint_every: int = 100,
    log_fn: Callable[[str], None] = print,
) -> TrainState:
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    step_fn = jax.jit(
        make_train_step(
            model,
            base_lr=base_lr,
            warmup_steps=warmup_steps,
            total_steps=steps,
            weight_decay=weight_decay,
        )
    )

    t0 = time.perf_counter()
    it = iter(data)
    losses: Dict[int, float] = {}
    for step in range(steps):
        tokens, labels = next(it)
        params, opt, metrics = step_fn(params, opt, jnp.asarray(tokens), jnp.asarray(labels))
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses[step] = loss
            dt = time.perf_counter() - t0
            log_fn(
                f"step {step:5d}  loss {loss:8.4f}  ce {float(metrics['ce']):8.4f}  "
                f"grad_norm {float(metrics['grad_norm']):7.3f}  "
                f"lr {float(metrics['lr']):.2e}  {dt:7.1f}s"
            )
        if checkpoint_path and (step + 1) % checkpoint_every == 0:
            ckpt.save_checkpoint(checkpoint_path, {"params": params, "opt": opt}, step)
    if checkpoint_path:
        ckpt.save_checkpoint(checkpoint_path, {"params": params, "opt": opt}, steps - 1)
    return TrainState(params=params, opt=opt, step=steps)
