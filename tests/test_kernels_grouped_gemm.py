"""grouped_gemm (MAGMA-vbatched analogue / MoE expert GEMM) vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.grouped_gemm import grouped_gemm, make_group_layout


@pytest.mark.parametrize("sizes", [[64, 64], [100, 5, 0, 260], [1, 1, 1], [300]], ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_groups(sizes, dtype, rng_key):
    bm = 32
    offs, bgroups, T = make_group_layout(np.array(sizes), bm=bm)
    G, K, N = len(sizes), 48, 40
    x = np.zeros((T, K), np.float32)
    rng = np.random.default_rng(0)
    for g, sz in enumerate(sizes):
        x[offs[g]:offs[g] + sz] = rng.normal(size=(sz, K))
    w = jax.random.normal(rng_key, (G, K, N), dtype)
    xj = jnp.asarray(x, dtype)
    got = grouped_gemm(xj, w, jnp.asarray(bgroups), bm=bm, bn=32, bk=32, interpret=True)
    want = ref.grouped_gemm(xj, w, jnp.asarray(bgroups), bm)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol * 10
    )


def test_group_isolation(rng_key):
    """Rows of group g must only see w[g]."""
    bm = 16
    offs, bgroups, T = make_group_layout(np.array([16, 16]), bm=bm)
    x = jax.random.normal(rng_key, (T, 24), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(rng_key, 1), (2, 24, 8), jnp.float32)
    out = grouped_gemm(x, w, jnp.asarray(bgroups), bm=bm, bn=8, bk=24, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:16]), np.asarray(x[:16] @ w[0]), rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out[16:]), np.asarray(x[16:] @ w[1]), rtol=2e-5, atol=1e-4)


def test_layout_helper():
    offs, bgroups, T = make_group_layout(np.array([5, 0, 129]), bm=64)
    assert T == 64 + 0 + 192
    assert list(offs) == [0, 64, 64, 256]
    assert list(bgroups) == [0] + [2] * 3
