"""Fleet routing policies: which replica does the next arrival go to?

A ``Router`` sees one arrival's ``TenantSpec`` plus the live per-replica
state (queue depth, estimated backlog seconds, warm compile caches) and
returns a replica index. Routers are deterministic pure functions of that
state — the fleet determinism contract (same seed, byte-identical
metrics) extends through routing.

The four policies span the classic trade-off surface:

    round_robin  -- load-oblivious; perfectly balanced COUNTS, blind to
                    cost heterogeneity and backlog (the baseline).
    jsq          -- join-shortest-queue on pending item count; the
                    textbook load balancer (Zhao et al.'s predictable-
                    latency setting).
    least_cost   -- join-least-estimated-WORK: residual busy time +
                    estimated backlog seconds + this item's estimated
                    cost on that replica, cold-start compile term
                    included. Sees both cost heterogeneity and warm-cache
                    affinity, so it lands hot shapes on replicas that
                    already compiled them unless the queue gap says
                    otherwise.
    affinity     -- tenant-sticky (session affinity): tenant t pins to
                    replica t mod N, which maximizes warm-cache reuse and
                    per-tenant ordering, spilling JSQ-style only when the
                    pinned replica's queue is badly out of line. The
                    D-STACK-ish "keep a tenant's state where it is" play.

``route`` receives the list of ``ReplicaPump``s (``repro.sim.simulator``)
— the routing signals are methods on the pump: ``queue_depth()``,
``backlog_s(now)``, ``estimate_item_s(w)``.
"""

from __future__ import annotations

from typing import Sequence

ROUTERS = ("round_robin", "jsq", "least_cost", "affinity")


class Router:
    """Chooses a replica for each arrival; stateful but deterministic."""

    name: str = "base"

    def route(self, w, replicas: Sequence, now: float) -> int:
        """Return the index in ``replicas`` this workload is routed to."""
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Cycle through replicas regardless of state."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def route(self, w, replicas, now) -> int:
        idx = self._next
        self._next = (idx + 1) % len(replicas)
        return idx


class JoinShortestQueueRouter(Router):
    """Fewest pending + in-flight items wins; ties rotate round-robin.

    The rotating tie-break matters: always breaking to the lowest index
    herds every arrival that lands on an all-idle fleet onto replica 0,
    which concentrates micro-bursts and loses to plain round-robin. With
    rotation, JSQ degenerates to round-robin exactly when queues are even
    and only deviates when there is real imbalance to correct.
    """

    name = "jsq"

    def __init__(self) -> None:
        self._rr = 0

    def route(self, w, replicas, now) -> int:
        depths = [r.queue_depth(now) for r in replicas]
        shortest = min(depths)
        ties = [i for i, d in enumerate(depths) if d == shortest]
        idx = ties[self._rr % len(ties)]
        self._rr += 1
        return idx


class LeastEstimatedCostRouter(Router):
    """Least estimated finish time for THIS item: replica backlog seconds
    plus the item's estimated dispatch cost there (compile term included
    when the replica is cold for the item's bucket)."""

    name = "least_cost"

    def route(self, w, replicas, now) -> int:
        return min(
            range(len(replicas)),
            key=lambda i: (replicas[i].backlog_s(now)
                           + replicas[i].estimate_item_s(w), i),
        )


class TenantAffinityRouter(Router):
    """Session-sticky: tenant t pins to replica ``t mod N`` (maximal
    warm-cache reuse), spilling to the shortest queue only when the
    pinned replica's queue exceeds ``spill_factor`` x the fleet's
    shortest queue (plus a small absolute grace so near-empty fleets
    never spill)."""

    name = "affinity"

    def __init__(self, spill_factor: float = 4.0, spill_grace: int = 8):
        if spill_factor < 1.0:
            raise ValueError("spill_factor must be >= 1")
        self.spill_factor = spill_factor
        self.spill_grace = spill_grace

    def route(self, w, replicas, now) -> int:
        pinned = w.tenant_id % len(replicas)
        depth = replicas[pinned].queue_depth(now)
        shortest = min(range(len(replicas)),
                       key=lambda i: (replicas[i].queue_depth(now), i))
        if depth > self.spill_grace + self.spill_factor * \
                replicas[shortest].queue_depth(now):
            return shortest
        return pinned


def make_router(name: str, **kwargs) -> Router:
    """Name-keyed router factory (the CLI surface of this module)."""
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "jsq":
        return JoinShortestQueueRouter()
    if name == "least_cost":
        return LeastEstimatedCostRouter()
    if name == "affinity":
        return TenantAffinityRouter(**kwargs)
    raise ValueError(f"unknown router: {name!r} (have {ROUTERS})")
