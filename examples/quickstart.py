"""Quickstart: the paper's mechanism in 60 lines.

Builds R tenant "models" (same GEMM shape, different weights), runs them
through the four multiplexing strategies, and shows the dynamic space-time
scheduler doing shape-bucketed super-kernel dispatch with its compile
cache warming up.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.config import ScheduleConfig
from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES
from repro.core import DynamicSpaceTimeScheduler, GemmProblem
from repro.core.strategies import Exclusive, SpaceOnly, SpaceTime, TimeOnly
from repro.core.superkernel import SuperKernelCache


def main() -> None:
    g = PAPER_GEMM_SHAPES["resnet18_conv2_2"]  # M=256, N=128, K=1152
    R = 16
    key = jax.random.PRNGKey(0)
    problems = []
    for tenant in range(R):
        kx, kw, key = jax.random.split(key, 3)
        problems.append(GemmProblem(
            tenant_id=tenant,
            x=jax.random.normal(kx, (g.M, g.K), jnp.float32),
            w=jax.random.normal(kw, (g.K, g.N), jnp.float32),
        ))

    print(f"{R} tenants, one {g.M}x{g.K}x{g.N} GEMM each "
          f"({g.flops * R / 1e9:.1f} GFLOP total)\n")

    print("strategy      wall ms   GFLOP/s")
    for strat in (TimeOnly(), SpaceOnly(),
                  SpaceTime(SuperKernelCache(ScheduleConfig())), Exclusive()):
        strat.prepare(problems)      # device-resident layout + compile
        _, t = strat.run()
        print(f"{strat.name:12s} {t*1e3:8.2f}  {g.flops*R/t/1e9:8.1f}")

    print("\ndynamic scheduler (stochastic arrivals):")
    sched = DynamicSpaceTimeScheduler(ScheduleConfig(batching_window_s=0.001))
    for p in problems:
        sched.submit(p)
    done = sched.flush()
    print(f"  completed {len(done)} kernels in "
          f"{sched.stats.dispatches} super-kernel dispatches")
    print(f"  report: { {k: round(v, 4) for k, v in sched.report().items()} }")


if __name__ == "__main__":
    main()
