"""Benchmark driver: one function per paper table/figure.

Prints human-readable sections followed by a machine-readable CSV block
(``name,us_per_call,derived``). Usage:

    PYTHONPATH=src python -m benchmarks.run           # everything
    PYTHONPATH=src python -m benchmarks.run --quick   # reduced sweeps
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sweeps")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,fig2,fig3,fig4,fig5,trace,sim,fleet,hetero,roofline,speed")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    csv_rows = []
    t0 = time.time()

    from benchmarks import (
        dynamic_trace,
        fig2_batch_sweep,
        fig3_latency,
        fig4_predictability,
        fig5_replicas,
        fleet_sweep,
        roofline_report,
        sim_speed,
        sim_sweep,
        table1_sgemm,
    )

    if want("table1"):
        r_sweep = (2, 8, 32) if args.quick else (2, 4, 8, 16, 32)
        table1_sgemm.run(r_sweep=r_sweep, reps=3 if args.quick else 5, csv_rows=csv_rows)
    if want("fig2"):
        fig2_batch_sweep.run(csv_rows=csv_rows)
    if want("fig3"):
        fig3_latency.run(csv_rows=csv_rows)
    if want("fig4"):
        fig4_predictability.run(csv_rows=csv_rows)
    if want("fig5"):
        fig5_replicas.run(csv_rows=csv_rows)
    if want("trace"):
        dynamic_trace.run_all_policies(
            num_events=80 if args.quick else 200, csv_rows=csv_rows)
    if want("sim"):
        sim_sweep.run(events=20_000 if args.quick else 200_000,
                      csv_rows=csv_rows)
    if want("fleet"):
        fleet_sweep.run(events=5_000 if args.quick else 20_000,
                        csv_rows=csv_rows)
    if want("hetero"):
        fleet_sweep.run_hetero(events=5_000 if args.quick else 20_000,
                               autoscale=True, csv_rows=csv_rows)
    if want("speed"):
        sim_speed.run(events=100_000 if args.quick else 1_000_000,
                      fleet_events=100_000 if args.quick else 2_000_000,
                      repeats=1 if args.quick else 3, csv_rows=csv_rows)
    if want("roofline"):
        roofline_report.run(csv_rows=csv_rows)
        roofline_report.run(mesh="pod2", csv_rows=csv_rows)

    print(f"\n=== CSV (name,us_per_call,derived) — total {time.time()-t0:.0f}s ===")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
