"""Sharding rule tables + constraints: pure-python properties (no big mesh
needed — a 2x2 host mesh via 4 fake devices is enough to exercise the rules,
but those require a separate process; here we test the pure spec logic and
no-op behavior of constraints without a mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.constraints import active_mesh, constrain, use_mesh
from repro.launch.roofline import (
    RooflineReport,
    analytic_cost,
    collective_bytes,
    model_flops_for,
)
from repro.config import get_config, get_shape

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


class FakeMeshShape(dict):
    pass


def test_constrain_noop_without_mesh():
    assert active_mesh() is None
    x = jnp.ones((4, 8))
    y = constrain(x, "batch", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_rank_mismatch_raises():
    class M:  # minimal mesh stand-in
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 2}

    with use_mesh(M()):
        with pytest.raises(ValueError):
            constrain(jnp.ones((4, 8)), "batch")


def test_collective_bytes_parsing():
    hlo = """
  %all-gather.22 = f32[256,4096,2048]{1,0,2} all-gather(%x), replica_groups=[16,16]<=[16,16]
  %ar = bf16[1024]{0} all-reduce(%y), to_apply=%add
  %rs.1 = (f32[64,64]{1,0}, f32[32]{0}) reduce-scatter(%a, %b), dimensions={0}
  %ag-start = f32[128]{0} all-gather-start(%c)
  %ag-done = f32[128]{0} all-gather-done(%ag-start)
  %p = f32[2,2]{1,0} add(%q, %r)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 256 * 4096 * 2048 * 4 + 128 * 4
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 64 * 64 * 4 + 32 * 4
    assert out["collective-permute"] == 0


def test_roofline_report_bottleneck():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="pod1", chips=256,
        hlo_flops=0, hlo_bytes=0,
        coll_bytes={"all-gather": 50_000_000_000, "all-reduce": 0,
                    "reduce-scatter": 0, "all-to-all": 0, "collective-permute": 0},
        model_flops=1e16, analytic_flops=1.3e16, analytic_bytes=1e12,
    )
    assert rep.t_collective == pytest.approx(1.0)  # 50GB / 50GB/s
    assert rep.bottleneck == "collective"
    assert 0 < rep.useful_flops_ratio < 1


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "granite-moe-1b-a400m", "rwkv6-1.6b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_analytic_cost_sane(arch, shape):
    cfg = get_config(arch)
    shp = get_shape(shape)
    cost = analytic_cost(cfg, shp)
    mf = model_flops_for(cfg, shp)
    assert cost["flops"] > 0 and cost["hbm_bytes"] > 0
    # analytic FLOPs must be >= the 6ND/2ND floor and within ~3x of it
    assert cost["flops"] >= mf * 0.9
    assert cost["flops"] < mf * 5


@given(
    dims=st.lists(st.sampled_from([1, 3, 16, 48, 160, 4096]), min_size=1, max_size=4),
)
def test_choose_spec_divisibility(dims):
    """choose_spec must never assign an axis to a non-divisible dim.

    Uses a real 1-device mesh reshaped logically — we only exercise the
    pure assignment logic so mesh sizes come from a stub."""
    from repro.distributed.sharding import choose_spec

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = choose_spec(dims, M())
    for d, axis in enumerate(spec):
        if axis is not None:
            assert dims[d] % 16 == 0
    # each axis used at most once
    used = [a for a in spec if a is not None]
    assert len(used) == len(set(used))
