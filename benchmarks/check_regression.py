"""Bench-regression gate: compare a fresh BENCH_*.json against a committed
baseline and fail on real performance regressions.

The simulators are deterministic per seed, so in practice current ==
baseline byte-for-byte on an unchanged scheduler; the tolerance exists so
*intentional* small policy shifts don't demand a baseline refresh on every
PR, while a >10% tail-latency or goodput regression fails CI.

Direction-aware: a row regresses only in its bad direction —

    lower is better    .../p50  .../p95  .../p99        (latency)
    higher is better   .../attainment  .../slo_attainment  .../goodput
                       .../events_per_s

Everything else (utilization, imbalance, cold fraction, spread, ...) is
informational: tracked in the JSON, never gated — those metrics trade
off against the gated ones by design (e.g. cheaper dispatches LOWER
utilization while improving goodput), so gating them would block
improvements.

Baselines are refreshed by re-running the sweep with the SAME arguments
CI uses and committing the output over the old file:

    PYTHONPATH=src python benchmarks/sim_sweep.py   --events 5000 \
        --json benchmarks/baselines/BENCH_baseline_sim_sweep.json
    PYTHONPATH=src python benchmarks/fleet_sweep.py --events 5000 \
        --replicas 4 --json benchmarks/baselines/BENCH_baseline_fleet_sweep.json

    python benchmarks/check_regression.py \
        --baseline benchmarks/baselines/BENCH_baseline_sim_sweep.json \
        --current BENCH_sim_sweep.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

LOWER_BETTER = ("/p50", "/p95", "/p99")
HIGHER_BETTER = ("/attainment", "/slo_attainment", "/goodput",
                 "/events_per_s")

# below this, a metric is noise-floor: relative comparison of two nearly
# zero values (e.g. 0.0001% attainment) would gate on float dust
ABS_FLOOR = 1e-9


def _rows(doc: dict) -> Dict[str, float]:
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def _direction(name: str) -> int:
    """+1 = higher is better, -1 = lower is better, 0 = ungated."""
    if name.endswith(LOWER_BETTER):
        return -1
    if name.endswith(HIGHER_BETTER):
        return +1
    return 0


def compare(baseline: Dict[str, float], current: Dict[str, float],
            tolerance: float) -> Tuple[List[str], int]:
    """Return (regression messages, number of gated rows compared)."""
    problems: List[str] = []
    gated = 0
    for name, base in sorted(baseline.items()):
        sign = _direction(name)
        if sign == 0:
            continue
        if name not in current:
            problems.append(f"gated row missing from current run: {name}")
            continue
        gated += 1
        cur = current[name]
        if abs(base) <= ABS_FLOOR and abs(cur) <= ABS_FLOOR:
            continue
        denom = max(abs(base), ABS_FLOOR)
        delta = (cur - base) / denom
        if sign * delta < -tolerance:
            # one self-contained line per failure: metric path, baseline,
            # observed, direction — actionable straight from the CI log,
            # no artifact download needed
            direction = "higher is better" if sign > 0 else "lower is better"
            problems.append(
                f"{name}: baseline={base:.6g} observed={cur:.6g} "
                f"({delta * 100.0:+.1f}%, {direction}, exceeds "
                f"{tolerance * 100.0:.0f}% tolerance)")
    return problems, gated


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_baseline_*.json")
    ap.add_argument("--current", required=True,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative slack in the bad direction "
                         "(default 0.10 = 10%%)")
    args = ap.parse_args()

    with open(args.baseline) as fh:
        base_doc = json.load(fh)
    with open(args.current) as fh:
        cur_doc = json.load(fh)
    if base_doc.get("benchmark") != cur_doc.get("benchmark"):
        print(f"REGRESSION GATE: comparing different benchmarks "
              f"({base_doc.get('benchmark')!r} vs {cur_doc.get('benchmark')!r})",
              file=sys.stderr)
        sys.exit(2)
    # schema_version is informational, never gated: the comparison below
    # only reads rows, but a version drift between baseline and current
    # means the JSON layout evolved — say so instead of staying silent
    # (older baselines predate the field; treat absent as "unversioned").
    base_ver = base_doc.get("schema_version")
    cur_ver = cur_doc.get("schema_version")
    if base_ver != cur_ver:
        print(f"note: metrics schema_version differs (baseline "
              f"{base_ver!r} vs current {cur_ver!r}); rows are still "
              f"compared, refresh the baseline to silence this")

    problems, gated = compare(_rows(base_doc), _rows(cur_doc), args.tolerance)
    bench = base_doc.get("benchmark", "?")
    if problems:
        print(f"REGRESSION GATE [{bench}]: {len(problems)} problem(s) over "
              f"{gated} gated rows", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        print("If the change is intentional, refresh the baseline (see "
              "module docstring) and commit it.", file=sys.stderr)
        sys.exit(1)
    print(f"regression gate [{bench}]: {gated} gated rows within "
          f"{args.tolerance * 100.0:.0f}% of baseline")


if __name__ == "__main__":
    main()
