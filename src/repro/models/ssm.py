"""Mamba2 (SSD) block: chunked scan for train/prefill, O(1) decode step.

The state-space recurrence per head (state S in R^{P x N}):

    S_t = exp(A * dt_t) * S_{t-1} + dt_t * x_t (x) B_t
    y_t = S_t . C_t + D * x_t

Train/prefill uses the chunked (SSD) formulation: quadratic within a chunk
(MXU-friendly GEMMs) + a sequential inter-chunk state pass. Decode carries
(conv_state, ssm_state) per layer.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.constraints import constrain
from repro.models import layers

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    assert s is not None
    d_inner = s.expand * cfg.d_model
    H = s.num_ssm_heads or d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.state_dim


def mamba2_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    # NOTE: z/x/B/C/dt use SEPARATE projection matrices rather than one
    # fused in_proj. A fused (d, 2*d_inner+2N+H) projection splits at
    # offsets that don't align with the model-axis shard grid, and GSPMD
    # reshards every split piece (measured 46 GiB/step of f32 residual
    # all-gathers + odd-width collective-permutes on zamba2 train_4k).
    # Separate outputs are each individually shard-aligned; the extra
    # dispatches are free at MXU scale. Same total params/FLOPs.
    s = cfg.ssm
    d_inner, H, P, N = _dims(cfg)
    keys = jax.random.split(key, 8)
    return {
        "wz": layers.dense_init(keys[0], cfg.d_model, d_inner, dtype),
        "wx": layers.dense_init(keys[1], cfg.d_model, d_inner, dtype),
        "wB": layers.dense_init(keys[2], cfg.d_model, N, dtype),
        "wC": layers.dense_init(keys[3], cfg.d_model, N, dtype),
        "wdt": layers.dense_init(keys[4], cfg.d_model, H, dtype),
        "conv_x_w": (jax.random.normal(keys[5], (s.conv_width, d_inner)) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_B_w": (jax.random.normal(keys[6], (s.conv_width, N)) * 0.1).astype(dtype),
        "conv_B_b": jnp.zeros((N,), dtype),
        "conv_C_w": (jax.random.normal(keys[7], (s.conv_width, N)) * 0.1).astype(dtype),
        "conv_C_b": jnp.zeros((N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": layers.dense_init(keys[2], d_inner, cfg.d_model, dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, dtype) -> Cache:
    s = cfg.ssm
    d_inner, H, P, N = _dims(cfg)
    w = s.conv_width - 1
    return {
        "conv_x": jnp.zeros((batch, w, d_inner), dtype),
        "conv_B": jnp.zeros((batch, w, N), dtype),
        "conv_C": jnp.zeros((batch, w, N), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (W,C) -> (B,S,C)."""
    W, C = w.shape
    lhs = x.transpose(0, 2, 1)                       # (B, C, S)
    rhs = w.T[:, None, :]                            # (C, 1, W)  OIH
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32), rhs.astype(jnp.float32),
        window_strides=(1,), padding=[(W - 1, 0)],
        feature_group_count=C,
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    return (out.transpose(0, 2, 1) + b.astype(jnp.float32)).astype(x.dtype)


def ssd_scan(
    xh: jax.Array,      # (B, S, H, P)
    dt: jax.Array,      # (B, S, H)  post-softplus
    A: jax.Array,       # (H,)       negative
    Bm: jax.Array,      # (B, S, N)
    Cm: jax.Array,      # (B, S, N)
    chunk: int,
    init_state=None,    # (B, H, P, N) or None
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan -> (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    Sp = -(-S // L) * L
    if Sp != S:
        pad = Sp - S
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> no decay, no input
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = Sp // L

    la = (dt * A[None, None, :]).reshape(B, nc, L, H).astype(jnp.float32)
    xbar = (xh * dt[..., None]).reshape(B, nc, L, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, L, N).astype(jnp.float32)
    cum = jnp.cumsum(la, axis=2)  # (B, nc, L, H)

    # ---- intra-chunk (quadratic in L, GEMM-shaped). The (B,nc,L,L,H)
    # decay tensor is the memory hot-spot; with H sharded over the `model`
    # mesh axis its per-chip slice is modest (~1.9 GB for zamba2 at
    # train_4k), so we keep the einsum whole and pin the sharding.
    # NOTE a lax.scan over head blocks was tried and REVERTED: the scan
    # iteration space can't carry the model-axis sharding, so every chip
    # recomputed all head blocks and GSPMD re-gathered H (59 GiB/step).
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, None, :, :, None]
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,L,M,H)
    # clamp BEFORE exp: masked (l < m) entries have rel >> 0; exp(rel)
    # overflows and the where-VJP turns 0 * inf into NaN gradients.
    rel = jnp.where(mask, rel, 0.0)
    decay = jnp.where(mask, jnp.exp(rel), 0.0)
    decay = constrain(decay, "batch", None, None, None, "model")
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", scores, decay, xbar)

    # ---- per-chunk state contribution + decay
    last = cum[:, :, -1:, :]                                      # (B,nc,1,H)
    tail_decay = jnp.exp(last - cum)                              # (B,nc,L,H)
    chunk_state = jnp.einsum("bclh,bcln,bclhp->bchpn", tail_decay, Bc, xbar)
    chunk_decay = jnp.exp(last[:, :, 0, :])                       # (B,nc,H)

    # ---- inter-chunk sequential pass
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(state, inp):
        cdecay, cstate = inp  # (B,H), (B,H,P,N)
        new = cdecay[..., None, None] * state + cstate
        return new, state  # emit the state *before* this chunk

    final_state, before = jax.lax.scan(
        step,
        init_state.astype(jnp.float32),
        (chunk_decay.transpose(1, 0, 2), chunk_state.transpose(1, 0, 2, 3, 4)),
    )
    before = before.transpose(1, 0, 2, 3, 4)                      # (B,nc,H,P,N)

    y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bcln,bchpn->bclhp", Cc, before)
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    return y.astype(xh.dtype), final_state


def _project(params: Params, x: jax.Array, cfg: ModelConfig):
    """Separate, shard-aligned z/x/B/C/dt projections."""
    z = x @ params["wz"]
    xs = x @ params["wx"]
    Bm = x @ params["wB"]
    Cm = x @ params["wC"]
    dt = x @ params["wdt"]
    if x.ndim == 3:
        z = constrain(z, "batch", None, "model")
        xs = constrain(xs, "batch", None, "model")
    return z, xs, Bm, Cm, dt


def mamba2_forward(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    return_cache: bool = False,
    init_cache_state: Cache = None,
) -> Tuple[jax.Array, Cache]:
    """Train/prefill forward. x: (B, S, d_model).

    init_cache_state: continuation prefill — conv tails and SSM state from
    a previous chunk (same structure as the returned cache).
    """
    s = cfg.ssm
    d_inner, H, P, N = _dims(cfg)
    B, S, _ = x.shape

    z, xs_raw, Bm_raw, Cm_raw, dt_raw = _project(params, x, cfg)

    def conv_with_history(raw, w, b, hist):
        if hist is None:
            return jax.nn.silu(_causal_conv(raw, w, b))
        # prepend the previous chunk's tail, drop the warm-up outputs
        ext = jnp.concatenate([hist.astype(raw.dtype), raw], axis=1)
        full = _causal_conv(ext, w, b)
        return jax.nn.silu(full[:, hist.shape[1]:, :])

    hist = init_cache_state
    xs = conv_with_history(
        xs_raw, params["conv_x_w"], params["conv_x_b"],
        None if hist is None else hist["conv_x"],
    )
    xs = constrain(xs, "batch", None, "model")
    Bm = conv_with_history(
        Bm_raw, params["conv_B_w"], params["conv_B_b"],
        None if hist is None else hist["conv_B"],
    )
    Cm = conv_with_history(
        Cm_raw, params["conv_C_w"], params["conv_C_b"],
        None if hist is None else hist["conv_C"],
    )

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B, S, H, P)
    y, final_state = ssd_scan(
        xh, dt, A, Bm, Cm, s.chunk_size,
        init_state=None if hist is None else hist["ssm"],
    )
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    # drop to the residual dtype BEFORE the gated norm: keeping (B,S,d_inner)
    # in f32 doubles the activation-collective bytes in the backward pass.
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = constrain(y, "batch", None, "model")

    y = layers.groupnorm_heads(y * jax.nn.silu(z), H) * params["norm"]
    out = y.astype(x.dtype) @ params["out_proj"]
    out = constrain(out, "batch", None, None)  # anchor the residual stream

    cache: Cache = {}
    if return_cache:
        W = s.conv_width

        def tail(a, h):
            if h is not None:
                a = jnp.concatenate([h.astype(a.dtype), a], axis=1)
            t = a[:, -(W - 1):, :]
            pad = (W - 1) - t.shape[1]
            return jnp.pad(t, ((0, 0), (pad, 0), (0, 0))) if pad > 0 else t

        cache = {
            "conv_x": tail(xs_raw, None if hist is None else hist["conv_x"]),
            "conv_B": tail(Bm_raw, None if hist is None else hist["conv_B"]),
            "conv_C": tail(Cm_raw, None if hist is None else hist["conv_C"]),
            "ssm": final_state,
        }
    return out, cache


def mamba2_decode(
    params: Params, x: jax.Array, cfg: ModelConfig, cache: Cache
) -> Tuple[jax.Array, Cache]:
    """One-token decode. x: (B, 1, d_model)."""
    s = cfg.ssm
    d_inner, H, P, N = _dims(cfg)
    B = x.shape[0]

    z, xs_raw, Bm_raw, Cm_raw, dt_raw = _project(params, x[:, 0, :], cfg)

    def conv_step(prev, new, w, b):
        window = jnp.concatenate([prev, new[:, None, :]], axis=1)  # (B, W, C)
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        out = jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)
        return out, window[:, 1:, :]

    xs, new_conv_x = conv_step(cache["conv_x"], xs_raw, params["conv_x_w"], params["conv_x_b"])
    Bm, new_conv_B = conv_step(cache["conv_B"], Bm_raw, params["conv_B_w"], params["conv_B_b"])
    Cm, new_conv_C = conv_step(cache["conv_C"], Cm_raw, params["conv_C_w"], params["conv_C_b"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B, H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)

    state = cache["ssm"]
    state = decay[..., None, None] * state + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, d_inner)

    y = layers.groupnorm_heads(y * jax.nn.silu(z), H) * params["norm"]
    out = (y.astype(x.dtype) @ params["out_proj"])[:, None, :]
    return out, {
        "conv_x": new_conv_x,
        "conv_B": new_conv_B,
        "conv_C": new_conv_C,
        "ssm": state,
    }
