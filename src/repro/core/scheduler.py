"""DynamicSpaceTimeScheduler — the paper's proposed scheduler (section 4).

Queries arrive stochastically, so super-kernels cannot be precomputed
ahead-of-time. The scheduler:

  1. enqueues arriving kernels into shape buckets (``KernelQueue``);
  2. waits up to ``batching_window_s`` for more mergeable arrivals (the
     space-time trade-off knob: window=0 degrades toward per-kernel
     dispatch, window=inf degrades toward offline batching);
  3. dispatches each ripe bucket as ONE super-kernel through the compile
     cache (``SuperKernelCache``), bounded by ``max_superkernel_size``;
  4. records per-tenant latency, detects stragglers, and evicts them
     (``LatencyMonitor`` + caller-provided eviction hook).

The pump is synchronous and host-driven — the paper's scheduler is also a
software scheduler above the accelerator; determinism here is what makes
the property-based tests (batched == sequential) possible.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax

from repro.config import ScheduleConfig
from repro.core.queue import GemmProblem, KernelQueue, ShapeBucket
from repro.core.slo import LatencyMonitor
from repro.core.superkernel import SuperKernelCache


@dataclasses.dataclass
class SchedulerStats:
    dispatches: int = 0
    problems_completed: int = 0
    total_flops: int = 0
    busy_time_s: float = 0.0

    @property
    def achieved_tflops(self) -> float:
        if self.busy_time_s == 0.0:
            return 0.0
        return self.total_flops / self.busy_time_s / 1e12


class DynamicSpaceTimeScheduler:
    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        on_evict: Optional[Callable[[int], None]] = None,
    ):
        self.schedule = schedule or ScheduleConfig()
        self.queue = KernelQueue()
        self.cache = SuperKernelCache(self.schedule)
        self.monitor = LatencyMonitor(
            self.schedule.latency_ewma_alpha,
            self.schedule.straggler_eviction_ratio,
        )
        self.stats = SchedulerStats()
        self.on_evict = on_evict
        self.evicted: List[int] = []

    # ---------------------------------------------------------------- intake
    def submit(self, problem: GemmProblem, now: Optional[float] = None) -> None:
        problem.arrival_time = now if now is not None else time.perf_counter()
        self.queue.push(problem)

    # ---------------------------------------------------------------- dispatch
    def _ripe(self, bucket: ShapeBucket, count: int, now: float) -> bool:
        if count >= self.schedule.max_superkernel_size:
            return True
        oldest = self.queue.oldest_arrival(bucket)
        return oldest is not None and (now - oldest) >= self.schedule.batching_window_s

    def pump(self, now: Optional[float] = None, force: bool = False) -> List[GemmProblem]:
        """Dispatch every ripe bucket; returns completed problems.

        With ``allow_ragged_merge`` (beyond-paper, MAGMA-vbatched analogue),
        ripe buckets sharing (op, K, N, dtype) but differing in M are merged
        into ONE grouped super-kernel instead of one uniform super-kernel
        per exact shape.
        """
        now = now if now is not None else time.perf_counter()
        completed: List[GemmProblem] = []

        if self.schedule.allow_ragged_merge:
            families: Dict[tuple, List] = {}
            for bucket, count in self.queue.buckets():
                if not force and not self._ripe(bucket, count, now):
                    continue
                families.setdefault(
                    (bucket.op, bucket.K, bucket.N, bucket.dtype), []
                ).append(bucket)
            for fam_buckets in families.values():
                batch: List[GemmProblem] = []
                for b in fam_buckets:
                    batch.extend(
                        self.queue.pop_batch(
                            b, self.schedule.max_superkernel_size - len(batch)
                        )
                    )
                    if len(batch) >= self.schedule.max_superkernel_size:
                        break
                if batch:
                    ragged = len({p.x.shape[0] for p in batch}) > 1
                    completed.extend(self._dispatch(batch, ragged=ragged))
            return completed

        for bucket, count in self.queue.buckets():
            if not force and not self._ripe(bucket, count, now):
                continue
            while True:
                batch = self.queue.pop_batch(bucket, self.schedule.max_superkernel_size)
                if not batch:
                    break
                completed.extend(self._dispatch(batch))
                if len(batch) < self.schedule.max_superkernel_size:
                    break
        return completed

    def flush(self) -> List[GemmProblem]:
        """Force-dispatch everything pending (end-of-benchmark drain)."""
        return self.pump(force=True)

    def _dispatch(
        self, batch: List[GemmProblem], ragged: bool = False
    ) -> List[GemmProblem]:
        t0 = time.perf_counter()
        outs = self.cache.execute_ragged(batch) if ragged else self.cache.execute(batch)
        t1 = time.perf_counter()

        self.stats.dispatches += 1
        self.stats.problems_completed += len(batch)
        self.stats.total_flops += sum(p.flops for p in batch)
        self.stats.busy_time_s += t1 - t0

        for p, out in zip(batch, outs):
            p.result = out
            p.completion_time = t1
            latency = t1 - p.arrival_time
            self.monitor.record(p.tenant_id, latency, p.slo_s)

        self._evict_stragglers()
        return batch

    # ---------------------------------------------------------------- isolation
    def _evict_stragglers(self) -> None:
        for tid in self.monitor.stragglers():
            if tid in self.evicted:
                continue
            self.evicted.append(tid)
            if self.on_evict is not None:
                self.on_evict(tid)

    # ---------------------------------------------------------------- reporting
    def report(self) -> Dict[str, float]:
        rep = {
            "dispatches": float(self.stats.dispatches),
            "problems": float(self.stats.problems_completed),
            "achieved_tflops": self.stats.achieved_tflops,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "evicted_tenants": float(len(self.evicted)),
        }
        rep.update(self.monitor.summary())
        return rep
