"""Inference request lifecycle + per-request metrics."""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional


class RequestState(str, enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


_ids = itertools.count()


@dataclasses.dataclass
class InferenceRequest:
    tenant_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    slo_s: float = 0.1
    eos_token: Optional[int] = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))

    # lifecycle
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)

    # timing
    arrival_time: float = 0.0
    prefill_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        if self.eos_token is not None and self.generated and self.generated[-1] == self.eos_token:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time
