"""Training launcher: pjit-sharded train loop on the active mesh.

On this CPU container it runs reduced configs end-to-end; on a real pod
the same code paths run the full configs (the dry-run proves they lower).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 100 --batch 8 --seq 128 --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import get_config, smoke_variant
from repro.distributed.constraints import use_mesh
from repro.distributed.sharding import param_specs, to_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.training import SyntheticTokenStream
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs need a real pod)")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    mesh = make_host_mesh()
    print(f"training {cfg.name} on mesh {dict(mesh.shape)}")

    with mesh, use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        p_sh = to_shardings(param_specs(jax.eval_shape(lambda: params), mesh), mesh)
        params = jax.device_put(params, p_sh)

        @jax.jit
        def step(params, opt, tokens, labels):
            def loss_fn(p):
                loss, m = model.forward_train(p, tokens, labels)
                return loss

            loss, grads = jax.value_and_grad(loss_fn)(params)
            lr = lr_schedule(opt.step, args.lr, 10, args.steps)
            params, opt, _ = adamw_update(grads, opt, params, lr)
            return params, opt, loss

        data = SyntheticTokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)
        t0 = time.perf_counter()
        for i, (tok, lab) in zip(range(args.steps), data):
            params, opt, loss = step(params, opt, jnp.asarray(tok), jnp.asarray(lab))
            if i % max(args.steps // 10, 1) == 0:
                print(f"step {i:4d} loss {float(loss):7.4f} "
                      f"({time.perf_counter()-t0:5.1f}s)")
    print("done")


if __name__ == "__main__":
    main()
