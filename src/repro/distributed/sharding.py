"""Sharding rule tables for params, inputs, and caches.

Strategy (single-pod mesh ``(data=16, model=16)``; multi-pod adds a leading
``pod=2`` axis used for data parallelism and ZeRO-style optimizer-state
sharding):

* **Weights: FSDP-style 2D sharding.** Every >=2-D parameter leaf greedily
  assigns the ``model`` axis to its largest divisible dim, then the ``data``
  axis to the largest remaining divisible dim. 1-D leaves shard over
  ``model`` when divisible, else replicate. This is uniform across all ten
  architectures — heads/experts/d_ff usually land on ``model``, d_model or
  vocab on ``data`` — and lets 400B-class weights fit per-device HBM.
* **Batch-bearing activations** shard batch over ``(pod, data)`` when
  divisible, falling back to ``data`` then replication.
* **Decode caches**: batch over ``data``; KV heads over ``model`` when
  divisible, else head_dim; for global_batch=1 long-context decode the
  *sequence* axis takes ``data`` instead (sequence-sharded KV cache).
* **Optimizer state (mu/nu)** inherits the param spec, plus — multi-pod —
  the ``pod`` axis on the largest still-unsharded divisible dim (ZeRO-1
  across pods).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


# --------------------------------------------------------------------- helpers
def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes usable for batch data parallelism, biggest grouping first."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def choose_spec(
    shape: Sequence[int],
    mesh: Mesh,
    axes_priority: Sequence[Any] = ("model", "data"),
    taken: Optional[Dict[int, Any]] = None,
) -> P:
    """Greedy divisible assignment: each axis (in priority order) goes to the
    largest not-yet-sharded dim it divides evenly."""
    assign: Dict[int, Any] = dict(taken or {})
    for axis in axes_priority:
        if axis not in mesh.axis_names and not isinstance(axis, tuple):
            continue
        size = _axis_size(mesh, axis)
        best, best_dim = None, 0
        for d, n in enumerate(shape):
            if d in assign:
                continue
            if n % size == 0 and n // size > 0 and n > best_dim:
                best, best_dim = d, n
        if best is not None:
            assign[best] = axis
    return P(*[assign.get(d) for d in range(len(shape))])


def _batch_spec(mesh: Mesh, batch: int) -> Optional[Any]:
    """Pick the widest divisible data-parallel grouping for a batch dim."""
    dp = data_axes(mesh)
    for cand in (dp, dp[-1:] if dp else ()):
        if not cand:
            continue
        axes = cand if len(cand) > 1 else cand[0]
        if batch % _axis_size(mesh, axes) == 0:
            return axes
    return None


# --------------------------------------------------------------------- params
def param_specs(params_shape: Pytree, mesh: Mesh, policy: str = "fsdp") -> Pytree:
    """Weight sharding specs for a parameter pytree of ShapeDtypeStructs.

    policy:
      "fsdp"      2D (model, data) — minimal memory, pays weight all-gathers
                  every step. Right for huge models / big per-step compute.
      "tp"        model-axis only, replicated across data — zero weight
                  gathers (activation all-reduces instead). Right for
                  latency-critical decode when W/16 fits HBM.
      "replicate" no weight sharding at all — zero weight collectives.
                  Right for small models (the paper's multi-tenant regime).
      "auto"      per-model choice by replicated-weight footprint:
                  <= 4 GiB -> replicate; <= 4 GiB model-sharded -> tp;
                  else fsdp.
    """
    if policy == "auto":
        total = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(params_shape)
        )
        if total <= 4 * 2**30:
            policy = "replicate"
        elif total / mesh.shape.get("model", 1) <= 4 * 2**30:
            policy = "tp"
        else:
            policy = "fsdp"

    axes = {
        "fsdp": ("model", "data"),
        "tp": ("model",),
        "replicate": (),
    }[policy]

    def rule(leaf) -> P:
        shape = leaf.shape
        if not axes:
            return P(*([None] * len(shape)))
        if len(shape) <= 1:
            return choose_spec(shape, mesh, axes[:1])
        if len(shape) == 2:
            return choose_spec(shape, mesh, axes)
        # stacked leaves (reps/experts leading): never shard the stack axis
        # of scanned units; DO shard expert axis. Heuristic: axis 0 is
        # protected, remaining dims get model/data greedily.
        return choose_spec(shape, mesh, axes, taken={0: None})

    return jax.tree.map(rule, params_shape)


def opt_state_specs(params_shape: Pytree, mesh: Mesh, policy: str = "fsdp") -> Pytree:
    """mu/nu: param spec + pod axis on the largest remaining dim (ZeRO-1)."""
    base = param_specs(params_shape, mesh, policy)
    if "pod" not in mesh.axis_names:
        return base

    def widen(leaf, spec: P) -> P:
        shape = leaf.shape
        taken = {d: a for d, a in enumerate(spec) if a is not None}
        if len(shape) >= 3:
            taken.setdefault(0, None)
        return choose_spec(shape, mesh, ("pod",), taken=taken)

    return jax.tree.map(widen, params_shape, base)


# --------------------------------------------------------------------- caches
def cache_specs(cache_shape: Pytree, mesh: Mesh, batch: int) -> Pytree:
    """Specs for the decode-cache pytree (see models.transformer layout).

    Leaf layouts (unit caches carry a leading reps axis, rem caches don't):
        k/v      (B, Hkv, S, D)   attention KV
        conv     (B, W, C)        mamba conv state
        ssm      (B, H, P, N)     mamba SSM state
        wkv      (B, H, N, N)     rwkv state
        shift_*  (B, D)           rwkv token-shift state
    """
    bspec = _batch_spec(mesh, batch)

    def rule(path, leaf) -> P:
        shape = leaf.shape
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        is_unit = any(
            isinstance(e, jax.tree_util.DictKey) and str(e.key) == "unit" for e in path
        )
        off = 1 if is_unit else 0  # skip the reps axis
        spec: list = [None] * len(shape)

        def set_if_div(dim: int, axis) -> bool:
            size = _axis_size(mesh, axis)
            if shape[dim] % size == 0 and spec[dim] is None:
                spec[dim] = axis
                return True
            return False

        if name in ("k", "v"):
            # (B, Hkv, S, D). NEVER shard head_dim D: contracting a
            # model-sharded D turns every decode score tensor into a
            # (B,H,S)-sized all-reduce per layer (measured 16.8 MB x L —
            # the dominant collective in the decode baseline). When KV
            # heads don't divide the model axis, shard the SEQUENCE dim
            # instead: softmax/value contractions then reduce to
            # (B,H,D)-sized partials only.
            b, h, s, d = off, off + 1, off + 2, off + 3
            if bspec is not None and shape[b] % _axis_size(mesh, bspec) == 0:
                spec[b] = bspec
                set_if_div(h, "model") or set_if_div(s, "model")
            else:
                # batch=1 long-context: sequence-sharded cache
                set_if_div(s, "data")
                set_if_div(h, "model") or set_if_div(s, "model")
        elif name is not None and name.startswith("conv"):
            b, w, c = off, off + 1, off + 2
            if bspec is not None and shape[b] % _axis_size(mesh, bspec) == 0:
                spec[b] = bspec
            set_if_div(c, "model")
        elif name in ("ssm", "wkv"):
            b, h = off, off + 1
            if bspec is not None and shape[b] % _axis_size(mesh, bspec) == 0:
                spec[b] = bspec
            set_if_div(h, "model")
        elif name is not None and name.startswith("shift"):
            b, d = off, off + 1
            if bspec is not None and shape[b] % _axis_size(mesh, bspec) == 0:
                spec[b] = bspec
            set_if_div(d, "model")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


# --------------------------------------------------------------------- inputs
def input_specs_shardings(
    mesh: Mesh, batch: int, kind: str
) -> Dict[str, P]:
    """Specs for token-level step inputs."""
    bspec = _batch_spec(mesh, batch)
    return {
        "tokens": P(bspec, None),
        "labels": P(bspec, None),
        "token": P(bspec),
        "lengths": P(bspec),
        "prefix_embeds": P(bspec, None, None),
    }


def to_shardings(specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
