"""Training substrate: optimizer, data pipeline, checkpointing, train loop."""

from repro.training.optimizer import adamw_init, adamw_update, lr_schedule  # noqa: F401
from repro.training.data import SyntheticTokenStream  # noqa: F401
from repro.training.train_loop import TrainState, make_train_step, train  # noqa: F401
