"""GQA attention block: prefill (flash) and single-token decode paths.

Cache contract: each attention layer owns ``{"k": (B, Hkv, S_alloc, D),
"v": (B, Hkv, S_alloc, D)}`` where ``S_alloc`` is the full sequence length
for global layers and ``min(window, S)`` for sliding-window layers (ring
buffer). Keys are stored with RoPE already applied, so ring-buffer slots
stay position-correct.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionKind, ModelConfig
from repro.distributed.constraints import constrain
from repro.kernels import ops
from repro.models import layers

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]


def attn_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    keys = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p: Params = {
        "wq": layers.dense_init(keys[0], d, cfg.num_heads * hd, dtype),
        "wk": layers.dense_init(keys[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": layers.dense_init(keys[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": layers.dense_init(keys[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def cache_alloc_len(cfg: ModelConfig, kind: AttentionKind, seq_len: int) -> int:
    if kind == AttentionKind.SLIDING and cfg.sliding_window > 0:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(
    cfg: ModelConfig, kind: AttentionKind, batch: int, seq_len: int, dtype
) -> Cache:
    s = cache_alloc_len(cfg, kind, seq_len)
    shape = (batch, cfg.num_kv_heads, s, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _project_qkv(params: Params, x: jax.Array, cfg: ModelConfig):
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    B = x.shape[0]
    S = x.shape[1] if x.ndim == 3 else 1
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    q = constrain(q, "batch", "model", None, None)
    k = constrain(k, "batch", "model", None, None)
    v = constrain(v, "batch", "model", None, None)
    return q, k, v


def attn_forward(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: AttentionKind,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence (train / prefill) attention. x: (B, S, d_model)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    q, k, v = _project_qkv(params, x, cfg)
    q = layers.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = layers.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    window = cfg.sliding_window if kind == AttentionKind.SLIDING else 0
    o = ops.flash_attention(q, k, v, causal=True, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return o @ params["wo"]


def attn_prefill_with_cache(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: AttentionKind,
    cache: Cache,
) -> Tuple[jax.Array, Cache]:
    """Prefill that also fills the KV cache (fresh sequences, positions 0..S)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    q, k, v = _project_qkv(params, x, cfg)
    q = layers.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = layers.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    window = cfg.sliding_window if kind == AttentionKind.SLIDING else 0
    o = ops.flash_attention(q, k, v, causal=True, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * cfg.head_dim)

    s_alloc = cache["k"].shape[2]
    if s_alloc >= S:
        new_k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    else:
        # sliding ring buffer: keep the last s_alloc keys, aligned to ring
        # slot (S - s_alloc) % s_alloc onward; store rolled so that slot
        # i holds position (S - s_alloc + i) ... ring write index = pos % s_alloc.
        tail_k = k[:, :, S - s_alloc :, :]
        tail_v = v[:, :, S - s_alloc :, :]
        shift = (S - s_alloc) % s_alloc
        new_k = jnp.roll(tail_k, shift, axis=2).astype(cache["k"].dtype)
        new_v = jnp.roll(tail_v, shift, axis=2).astype(cache["v"].dtype)
    return o @ params["wo"], {"k": new_k, "v": new_v}


def attn_prefill_continue(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: AttentionKind,
    cache: Cache,
    start: jax.Array,
) -> Tuple[jax.Array, Cache]:
    """Chunked-prefill continuation: process S new tokens starting at
    absolute position ``start`` (traced scalar, same for all rows), with
    ``start`` tokens already in the cache.

    Linear (non-ring) caches only: slot == position, so causal masking
    against the full cache is exact and stale slots beyond start+S are
    excluded by causality. Sliding-window (ring) layers would need
    per-slot position tracking — not supported; callers fall back to
    exact-length prefill for those architectures.
    """
    if kind == AttentionKind.SLIDING and cfg.sliding_window > 0:
        raise NotImplementedError(
            "chunked prefill is not supported for sliding-window (ring-cache) layers"
        )
    from repro.kernels import ref  # traced q_offset needs the jnp path

    B, S, _ = x.shape
    positions = start + jnp.arange(S)[None, :].repeat(B, axis=0)
    q, k, v = _project_qkv(params, x, cfg)
    q = layers.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = layers.apply_rope(k, positions[:, None, :], cfg.rope_theta)

    new_k = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, start, 0)
    )
    new_v = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, start, 0)
    )

    s_alloc = new_k.shape[2]
    attn_fn = ref.attention_chunked if s_alloc > 2048 else ref.attention
    o = attn_fn(q, new_k, new_v, causal=True, q_offset=start)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return o @ params["wo"], {"k": new_k, "v": new_v}


def attn_decode(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: AttentionKind,
    cache: Cache,
    lengths: jax.Array,
) -> Tuple[jax.Array, Cache]:
    """One-token decode. x: (B, 1, d_model); lengths: (B,) tokens already cached."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, cfg)  # (B, H, 1, D)
    positions = lengths[:, None]  # new token's absolute position
    q = layers.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = layers.apply_rope(k, positions[:, None, :], cfg.rope_theta)

    s_alloc = cache["k"].shape[2]
    slot = (lengths % s_alloc).astype(jnp.int32)  # ring slot (== lengths when global)

    def write(c, kv):
        # c: (Hkv, S, D), kv: (Hkv, 1, D), slot scalar
        def upd(c, kv, s):
            return jax.lax.dynamic_update_slice(c, kv.astype(c.dtype), (0, s, 0))
        return upd
    new_k = jax.vmap(
        lambda c, kv, s: jax.lax.dynamic_update_slice(c, kv.astype(c.dtype), (0, s, 0))
    )(cache["k"], k, slot)
    new_v = jax.vmap(
        lambda c, kv, s: jax.lax.dynamic_update_slice(c, kv.astype(c.dtype), (0, s, 0))
    )(cache["v"], v, slot)

    live = jnp.minimum(lengths + 1, s_alloc).astype(jnp.int32)
    o = ops.decode_attention(q[:, :, 0, :], new_k, new_v, live)
    o = o.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return o @ params["wo"], {"k": new_k, "v": new_v}
