"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 (+1 shared expert, llama4 style). Maverick interleaves dense and MoE
FFN layers (interleave_moe_layer_step=2), which is what lands the total at
~400B with 128 experts. Early-fusion multimodal: the vision frontend is
stubbed; text-token path is exercised here.
"""

from repro.config import BlockKind, ModelConfig, MoEConfig, register_config

_PATTERN = tuple(
    BlockKind.ATTN_MOE if i % 2 == 1 else BlockKind.ATTN_MLP for i in range(48)
)

CONFIG = register_config(
    ModelConfig(
        name="llama4-maverick-400b-a17b",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        family="moe",
        num_layers=48,
        d_model=5120,
        vocab_size=202048,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        moe=MoEConfig(
            num_experts=128,
            experts_per_token=1,
            expert_d_ff=8192,
            num_shared_experts=1,
        ),
        block_pattern=_PATTERN,
        rope_theta=500_000.0,
    )
)
