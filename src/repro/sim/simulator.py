"""Discrete-event simulator over the real scheduling core.

NOT a model of the scheduler — the actual ``DynamicSpaceTimeScheduler``
(same queue, same batching policies, same admission control, same
straggler eviction) runs on a ``VirtualClock``, with a cost model pricing
each super-dispatch. Only the kernels are replaced: simulated workloads
carry a no-op executor, so a million-event policy sweep runs in seconds
on CPU with zero device work — and any policy conclusion transfers to the
live pump because it IS the live pump.

Event ordering: between consecutive trace arrivals the loop advances the
virtual clock to each bucket's next ripeness instant and pumps there, so
batching-window dispatches happen at their exact modeled time rather than
being quantized to arrival times. Arrivals are stamped with their TRACE
time even when the (busy) virtual clock has run ahead — queueing delay
under overload is measured honestly.

Determinism: trace generation is seeded numpy, the clock is virtual, the
cost model is pure arithmetic — same seed in, byte-identical metrics JSON
out. That contract is what lets CI assert on simulated SLO orderings.
"""

from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Sequence

from repro.config import ScheduleConfig
from repro.core.clock import VirtualClock
from repro.core.scheduler import DynamicSpaceTimeScheduler
from repro.sim.costmodel import RooflineCostModel
from repro.sim.metrics import MetricsAccumulator, SimMetrics
from repro.sim.traces import Arrival, Trace


def _noop_execute(batch: List) -> List[None]:
    return [None] * len(batch)


class SimWorkload:
    """Minimal object satisfying the scheduler's Workload protocol.

    Deliberately not the ``Workload`` dataclass: a ``__slots__`` class with
    a no-op executor keeps per-event cost low enough for million-event
    traces (the dataclass's default-factory fields roughly double intake
    time at that scale).
    """

    __slots__ = ("tenant_id", "bucket", "cost", "slo_s", "kind", "flops",
                 "bytes", "merge_family", "execute", "arrival_time",
                 "result", "completion_time")

    def __init__(self, spec, cost: float):
        self.tenant_id = spec.tenant_id
        self.bucket = spec.bucket
        self.cost = cost
        self.slo_s = spec.slo_s
        self.kind = spec.kind
        self.flops = spec.flops
        self.bytes = spec.bytes
        self.merge_family = None  # ragged merge is a live-kernel concern
        self.execute = _noop_execute
        self.arrival_time = 0.0
        self.result = None
        self.completion_time = None


class Simulator:
    """Drives the real scheduler over a trace on a virtual timeline."""

    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        start_s: float = 0.0,
    ):
        self.clock = VirtualClock(start_s)
        self.scheduler = DynamicSpaceTimeScheduler(
            schedule or ScheduleConfig(),
            clock=self.clock,
            cost_model=cost_model or RooflineCostModel(),
        )

    # ------------------------------------------------------------ event loop
    def _next_ripe_time(self) -> Optional[float]:
        """Earliest instant any bucket becomes dispatchable.

        For slack-aware policies the window shrinks as time passes, so
        ``oldest + window(now)`` is an upper bound on the true ripeness
        instant — pumping there is guaranteed to dispatch (the estimate
        errs at most by how much the window shrank in between), which
        keeps the drain loop strictly progressing.
        """
        sched = self.scheduler
        now = self.clock.now()
        queue, policy = sched.queue, sched.policy
        cap = sched.schedule.max_superkernel_size
        best = None
        for bucket, count in queue.buckets():
            if count >= cap:
                return now
            oldest = queue.oldest_arrival(bucket)
            pending = queue.peek(bucket) if policy.needs_pending else ()
            t = max(now, oldest + policy.window_s(pending, now))
            if best is None or t < best:
                best = t
        return best

    # 1 simulated nanosecond — larger than any float rounding error at
    # realistic trace horizons, negligible against microsecond dispatches
    _RIPE_EPS = 1e-9

    def _pump_at(self, t_ripe: float, acc: MetricsAccumulator) -> List:
        """Advance to a ripeness instant and pump; nudge one epsilon past
        it if float rounding left the window a ULP short of elapsed."""
        self.clock.advance_to(t_ripe)
        done = self.scheduler.pump()
        if not done:
            self.clock.advance_to(t_ripe + self._RIPE_EPS)
            done = self.scheduler.pump()
        self._absorb(done, acc)
        return done

    def _drain_until(self, t_limit: float, acc: MetricsAccumulator) -> None:
        """Pump every bucket that ripens strictly before ``t_limit``."""
        while True:
            t_ripe = self._next_ripe_time()
            if t_ripe is None or t_ripe >= t_limit:
                return
            if not self._pump_at(t_ripe, acc):
                return  # estimate failed to ripen anything; arrivals resume

    def _absorb(self, done: List, acc: MetricsAccumulator) -> None:
        add = acc.add
        for w in done:
            add(w.tenant_id, w.completion_time - w.arrival_time,
                w.slo_s, w.cost, w.kind)

    def run(self, trace: Trace | Iterable[Arrival]) -> SimMetrics:
        sched, clock = self.scheduler, self.clock
        submit, pump = sched.submit, sched.pump
        acc = MetricsAccumulator()
        t_start = clock.now()

        for t_s, spec, cost in trace:
            self._drain_until(t_s, acc)
            clock.advance_to(t_s)
            # stamp TRUE arrival time even when the busy clock ran ahead
            submit(SimWorkload(spec, cost), now=t_s)
            self._absorb(pump(), acc)

        # drain the tail at exact ripeness instants, then force-flush
        # whatever remainder is left
        while len(sched.queue):
            t_ripe = self._next_ripe_time()
            if t_ripe is None or not self._pump_at(t_ripe, acc):
                self._absorb(sched.flush(), acc)
                break

        return acc.freeze(
            sim_duration_s=clock.now() - t_start,
            busy_time_s=sched.stats.busy_time_s,
            dispatches=sched.stats.dispatches,
            rejected=sched.stats.rejected,
            evicted_tenants=len(sched.evicted),
        )


def simulate(
    trace: Trace | Iterable[Arrival],
    schedule: Optional[ScheduleConfig] = None,
    cost_model: Optional[Callable[[Sequence], float]] = None,
) -> SimMetrics:
    """One-shot convenience wrapper: fresh simulator, one trace, metrics."""
    return Simulator(schedule=schedule, cost_model=cost_model).run(trace)
