"""musicgen-large [arXiv:2306.05284].

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048. Decoder-only LM over
EnCodec audio tokens. Per assignment rules the EnCodec/conv frontend is a
STUB: ``input_specs()`` supplies precomputed frame embeddings; the decoder
consumes codec-token ids with a 2048-entry codebook vocabulary.
"""

from repro.config import Modality, ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="musicgen-large",
        source="arXiv:2306.05284",
        family="audio",
        num_layers=48,
        d_model=2048,
        vocab_size=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        mlp_gated=False,  # musicgen uses plain (non-gated) FFN
        modality=Modality.AUDIO_TOKENS,
        num_prefix_embeddings=64,   # stubbed conditioning frames
        frontend_embed_dim=1024,
    )
)
