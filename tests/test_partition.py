"""Fractional spatial shares: knee curves, the deterministic planner,
spec validation, and the partitioned fleet executor.

The contracts pinned here are the ``repro.partition`` tentpole:

* ``HardwareSpec.sliced`` scales roofs, never overheads — which is why
  throughput-vs-share curves have a knee at all;
* the planner is a pure function of (mix, hardware, config): its plan
  JSON is byte-identical across calls, and its shares never
  oversubscribe the chip;
* ``PartitionSpec`` validates eagerly with one-line actionable errors
  (shares summing past 1.0, pairing with live mode / sharded workers /
  autoscale / hetero specs) and round-trips through JSON;
* a partitioned fleet run is byte-identical per seed — metrics JSON and
  exported Chrome trace bytes, partition assign/replan events included.
"""

import dataclasses
import json

import pytest

from repro.api import (
    CostModelSpec,
    PartitionSpec,
    SystemSpec,
    WorkloadSpec,
    build_mix,
    build_partition,
)
from repro.launch.roofline import TPU_V5E
from repro.partition import (
    DEFAULT_SHARE_GRID,
    PartitionPlan,
    PartitionShare,
    PlannerConfig,
    knee_share,
    plan_partitions,
    share_pricer,
    throughput_curve,
)
from repro.sim.costmodel import CalibratedCostModel, RooflineCostModel


def _mix(tenants=6):
    return build_mix(WorkloadSpec(mix="sgemm", tenants=tenants))


def _spec(events=1500, tenants=6, **partition_kwargs):
    return SystemSpec(
        workload=WorkloadSpec(mix="sgemm", tenants=tenants, events=events,
                              seed=3, rho=1.05),
        partition=PartitionSpec(**partition_kwargs),
    )


# --------------------------------------------------------------- hardware


def test_sliced_scales_roofs_not_overheads():
    half = TPU_V5E.sliced(0.5)
    assert half.peak_flops == pytest.approx(TPU_V5E.peak_flops * 0.5)
    assert half.hbm_bw == pytest.approx(TPU_V5E.hbm_bw * 0.5)
    assert half.dispatch_overhead_s == TPU_V5E.dispatch_overhead_s
    assert "0.5" in TPU_V5E.sliced(0.5, name="v5e@g:0.5").name


@pytest.mark.parametrize("bad", [0.0, -0.25, 1.5])
def test_sliced_rejects_bad_share(bad):
    with pytest.raises(ValueError, match="share"):
        TPU_V5E.sliced(bad)


# ------------------------------------------------------------- knee curves


def test_throughput_curve_monotone_and_knee_below_one():
    # tiny R: launch overhead dominates, so most of the chip is wasted
    # past a small share — the knee must land strictly below the whole
    # chip on this curve
    w = _mix()[0]
    price = share_pricer(TPU_V5E)
    curve = throughput_curve(w, 1, price, DEFAULT_SHARE_GRID)
    thrs = [thr for _, thr in curve]
    assert all(b >= a * (1 - 1e-12) for a, b in zip(thrs, thrs[1:])), \
        "throughput must be non-decreasing in share"
    assert knee_share(curve, knee_fraction=0.5) < 1.0


def test_knee_is_smallest_share_reaching_fraction():
    curve = ((0.25, 50.0), (0.5, 90.0), (1.0, 100.0))
    assert knee_share(curve, knee_fraction=0.9) == 0.5
    assert knee_share(curve, knee_fraction=1.0) == 1.0
    assert knee_share(curve, knee_fraction=0.9, min_share=0.75) == 1.0


def test_knee_rejects_bad_inputs():
    with pytest.raises(ValueError, match="non-empty"):
        knee_share(())
    with pytest.raises(ValueError, match="knee_fraction"):
        knee_share(((1.0, 1.0),), knee_fraction=0.0)


def test_calibrated_dispatch_share_decomposes_overhead():
    model = CalibratedCostModel(prior=RooflineCostModel(spec=TPU_V5E))
    batch = [_mix()[0]] * 4
    t_full = model(batch)
    spec = model.prior.spec
    overhead = spec.dispatch_overhead_s + spec.pipe_fill_s()
    assert model.dispatch_share_s(batch, 1.0) == pytest.approx(t_full)
    expected = min(t_full, overhead) + max(t_full - overhead, 0.0) / 0.5
    assert model.dispatch_share_s(batch, 0.5) == pytest.approx(expected)
    with pytest.raises(ValueError, match="share"):
        model.dispatch_share_s(batch, 0.0)


def test_estimate_item_s_scales_inverse_share():
    w = _mix()[0]
    for model in (RooflineCostModel(spec=TPU_V5E),
                  CalibratedCostModel(prior=RooflineCostModel(spec=TPU_V5E))):
        solo = model.estimate_item_s(w)
        assert model.estimate_item_s(w, share=0.25) == pytest.approx(solo * 4)
        with pytest.raises(ValueError, match="share"):
            model.estimate_item_s(w, share=1.5)


def test_prior_strength_blends_toward_prior():
    # one observation of a key priced 10x the prior: with pseudo-count
    # k=3 the blend is (1*fitted + 3*prior) / 4
    prior = RooflineCostModel(spec=TPU_V5E)
    batch = [_mix()[0]] * 2
    p = prior(batch)
    model = CalibratedCostModel(prior=prior, prior_strength=3.0)
    model.observe(batch, 10.0 * p)
    assert model(batch) == pytest.approx((10.0 * p + 3.0 * p) / 4.0)
    # shrinkage round-trips through JSON, and an explicit load override
    # wins over the stored value
    clone = CalibratedCostModel.from_json(model.to_json(), prior=prior)
    assert clone.prior_strength == 3.0
    assert clone(batch) == pytest.approx(model(batch))
    off = CalibratedCostModel.from_json(model.to_json(), prior=prior,
                                        prior_strength=0.0)
    assert off(batch) == pytest.approx(10.0 * p)


# ----------------------------------------------------------------- planner


def test_planner_deterministic_and_subscribed():
    mix = _mix()
    a = plan_partitions(mix, TPU_V5E)
    b = plan_partitions(mix, TPU_V5E)
    assert a.to_json() == b.to_json()
    assert a.total_share <= 1.0 + 1e-9
    assert len(a.groups) == 3  # one slice per sgemm shape
    assert sorted(t for g in a.groups for t in g.tenants) == list(range(6))
    # round trip
    assert PartitionPlan.from_json(a.to_json()).to_json() == a.to_json()


def test_planner_r_override_changes_plan():
    mix = _mix()
    base = plan_partitions(mix, TPU_V5E)
    tiny = plan_partitions(
        mix, TPU_V5E,
        r_override={g.name: 1 for g in base.groups})
    # observed R=1 makes every slice launch-dominated: knees shrink, so
    # the replanned total must not exceed the chip either
    assert tiny.total_share <= 1.0 + 1e-9
    assert tiny.to_json() == plan_partitions(
        mix, TPU_V5E, r_override={g.name: 1 for g in base.groups}).to_json()


def test_planner_squeeze_preserves_deadline_floors():
    # min_share high enough that three knees oversubscribe: the squeeze
    # must land the plan back at <= 1.0 without dropping a group
    cfg = PlannerConfig(min_share=0.5, share_grid=(0.5, 0.75, 1.0))
    plan = plan_partitions(_mix(), TPU_V5E, cfg)
    assert len(plan.groups) == 3
    assert plan.total_share <= 1.0 + 1e-9


def test_plan_validation_one_liners():
    with pytest.raises(ValueError, match="sum"):
        PartitionPlan(groups=(PartitionShare(name="a", share=0.9),
                              PartitionShare(name="b", share=0.2)))
    with pytest.raises(ValueError, match="disjoint"):
        PartitionPlan(groups=(
            PartitionShare(name="a", share=0.4, tenants=(0,)),
            PartitionShare(name="b", share=0.4, tenants=(0,))))
    with pytest.raises(ValueError, match="unique"):
        PartitionPlan(groups=(PartitionShare(name="a", share=0.4),
                              PartitionShare(name="a", share=0.4)))
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        PartitionShare(name="a", share=0.0)


# ----------------------------------------------------------- spec surface


def test_partition_spec_round_trip():
    spec = _spec(policy="knee", replan_interval_s=0.01)
    clone = SystemSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert clone == spec
    assert clone.to_dict() == spec.to_dict()
    # specs without a partition stay byte-identical to pre-partition docs
    plain = SystemSpec(workload=WorkloadSpec(mix="sgemm"))
    assert "partition" not in {
        k for k, v in plain.to_dict().items() if v is not None} or \
        plain.to_dict()["partition"] is None


def test_partition_spec_validation_errors():
    with pytest.raises(ValueError, match="policy"):
        PartitionSpec(policy="magic")
    with pytest.raises(ValueError, match="sum"):
        _spec(policy="explicit", shares=(0.7, 0.7))
    with pytest.raises(ValueError, match="shares"):
        PartitionSpec(policy="explicit")  # explicit needs shares
    with pytest.raises(ValueError, match="live"):
        dataclasses.replace(_spec(policy="knee"), mode="live")
    with pytest.raises(ValueError, match="workers"):
        _spec(policy="knee").replace(**{"fleet.workers": 2})
    with pytest.raises(ValueError, match="autoscale"):
        _spec(policy="knee").replace(
            **{"fleet.autoscale.policy": "backlog"})
    with pytest.raises(ValueError, match="specs"):
        _spec(policy="knee").replace(**{"fleet.specs": ("v5e", "v5e_half")})


def test_build_partition_policies():
    spec = _spec(policy="explicit", shares=(0.5, 0.25, 0.25))
    plan, replanner = build_partition(spec, build_mix(spec.workload))
    assert [g.share for g in plan.groups] == [0.5, 0.25, 0.25]
    assert replanner is None  # explicit plans never replan
    knee_spec = _spec(policy="knee")
    plan, replanner = build_partition(knee_spec,
                                      build_mix(knee_spec.workload))
    assert plan.total_share <= 1.0 + 1e-9
    assert callable(replanner)
    assert replanner(None).to_json() == plan.to_json()
    none_plan, none_rp = build_partition(
        SystemSpec(workload=WorkloadSpec(mix="sgemm")), _mix())
    assert none_plan is None and none_rp is None


def test_cost_model_spec_prior_strength_validation():
    with pytest.raises(ValueError, match="prior_strength"):
        CostModelSpec(prior_strength=-1.0)


# ------------------------------------------------------------ executor


def test_partitioned_run_deterministic_with_trace():
    spec = _spec(events=1500).replace(
        **{"observability.enabled": True})
    run_a = spec.build()
    m_a = run_a.run_metrics()
    run_b = spec.build()
    m_b = run_b.run_metrics()
    assert m_a.to_json() == m_b.to_json()
    doc = json.loads(m_a.to_json())
    assert doc["partition"]["plan"]["groups"]
    assert any(e["action"] == "assign" for e in doc["partition"]["events"])

    from repro.obs.trace_export import export_chrome_trace
    trace_a = export_chrome_trace(run_a.last_recorder)
    trace_b = export_chrome_trace(run_b.last_recorder)
    assert trace_a == trace_b
    events = json.loads(trace_a)["traceEvents"]
    part = [e for e in events if e.get("cat") == "partition"]
    assert len(part) >= len(doc["partition"]["plan"]["groups"])
    assert {e["name"] for e in part} >= {"partition_assign"}


def test_partitioned_replan_emits_events():
    # enough load that observed merged batch sizes diverge from the
    # weight-derived representative R — replan events only fire when a
    # share actually changes
    spec = SystemSpec(
        workload=WorkloadSpec(mix="sgemm", tenants=6, events=4000,
                              seed=3, rho=1.2),
        partition=PartitionSpec(policy="knee", replan_interval_s=0.0002))
    m = spec.build().run_metrics()
    doc = json.loads(m.to_json())
    actions = [e["action"] for e in doc["partition"]["events"]]
    assert "replan" in actions
    # replans only ever swap shares; the plan stays subscribed
    total = sum(g["share"] for g in doc["partition"]["plan"]["groups"])
    assert total <= 1.0 + 1e-9
