"""The unified space-time execution core: generic workloads, injected
clocks, pluggable batching policies, admission control — and the serving
engine routing its prefill/decode cohorts through the same scheduler.

These tests run without hypothesis (the property-based variants live in
test_scheduler_properties.py)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ScheduleConfig, get_config, smoke_variant
from repro.core import (
    DynamicSpaceTimeScheduler,
    GemmProblem,
    VirtualClock,
    Workload,
)
from repro.core.policy import FixedWindowPolicy, SLOAdaptiveWindowPolicy
from repro.core.superkernel import SuperKernelCache
from repro.kernels import ref
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def mk_problem(tenant, M=32, K=16, N=8, seed=0, slo_s=0.1):
    k = jax.random.PRNGKey(seed * 1000 + tenant)
    return GemmProblem(
        tenant_id=tenant,
        x=jax.random.normal(k, (M, K), jnp.float32),
        w=jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32),
        slo_s=slo_s,
    )


class TestGenericWorkload:
    def test_callback_workloads_dispatch_through_pump(self):
        sched = DynamicSpaceTimeScheduler(ScheduleConfig(batching_window_s=0.0))
        calls = []

        def execute(batch):
            calls.append(len(batch))
            return [w.payload * 2 for w in batch]

        for t in range(3):
            sched.submit(Workload(tenant_id=t, bucket=("custom", "a"),
                                  cost=1.0, execute=execute, payload=t))
        done = sched.flush()
        assert [w.result for w in done] == [0, 2, 4]
        assert calls == [3]  # ONE merged dispatch for the shared bucket
        assert sched.stats.dispatches == 1
        # the same monitor tracked all three tenants
        assert len(sched.monitor.tenants) == 3

    def test_distinct_buckets_dispatch_separately(self):
        sched = DynamicSpaceTimeScheduler(ScheduleConfig(batching_window_s=0.0))
        execute = lambda batch: [None] * len(batch)
        sched.submit(Workload(tenant_id=0, bucket=("a",), execute=execute))
        sched.submit(Workload(tenant_id=1, bucket=("b",), execute=execute))
        sched.flush()
        assert sched.stats.dispatches == 2

    def test_admission_control_rejects_over_cap(self):
        sched = DynamicSpaceTimeScheduler(
            ScheduleConfig(batching_window_s=1000.0, max_pending_per_tenant=2))
        assert sched.submit(mk_problem(0))
        assert sched.submit(mk_problem(0))
        assert not sched.submit(mk_problem(0))   # third pending rejected
        assert sched.submit(mk_problem(1))       # other tenants unaffected
        assert sched.stats.rejected == 1
        assert len(sched.queue) == 3
        sched.flush()
        assert sched.submit(mk_problem(0))       # capacity freed after dispatch


class TestRaggedFlushDrains:
    def test_flush_drains_family_over_size_cap(self):
        """A merge family larger than max_superkernel_size must drain
        fully across several dispatches, not leave a remainder queued."""
        sched = DynamicSpaceTimeScheduler(ScheduleConfig(
            batching_window_s=0.0, allow_ragged_merge=True,
            max_superkernel_size=4))
        for t in range(9):  # same (K, N, dtype) family, mixed M
            sched.submit(mk_problem(t, M=16 + 16 * (t % 3), K=16, N=8))
        done = sched.flush()
        assert len(done) == 9
        assert len(sched.queue) == 0
        assert sched.stats.dispatches == 3  # 4 + 4 + 1
        for p in done:
            np.testing.assert_allclose(
                np.asarray(p.result), np.asarray(p.x @ p.w), rtol=1e-4, atol=1e-3)


class TestClockAndPolicy:
    def test_virtual_clock_trace_is_deterministic(self):
        def trace():
            clock = VirtualClock()
            sched = DynamicSpaceTimeScheduler(
                ScheduleConfig(batching_window_s=0.002),
                clock=clock,
                cost_model=lambda batch: 1e-4 * len(batch),
            )
            done = []
            rng = np.random.default_rng(0)
            for i in range(40):
                clock.advance_to(i * 0.001)
                for _ in range(rng.poisson(1.0)):
                    sched.submit(mk_problem(int(rng.integers(4))))
                done.extend(sched.pump())
            done.extend(sched.flush())
            return [round(p.completion_time - p.arrival_time, 12) for p in done]

        assert trace() == trace()

    def test_fixed_window_holds_until_elapsed(self):
        clock = VirtualClock()
        sched = DynamicSpaceTimeScheduler(
            ScheduleConfig(batching_window_s=0.010), clock=clock)
        sched.submit(mk_problem(0))
        assert sched.pump() == []
        clock.advance(0.011)
        assert len(sched.pump()) == 1

    def test_adaptive_window_shrinks_with_slack(self):
        pol = SLOAdaptiveWindowPolicy(base_window_s=0.010, slack_fraction=0.5)
        relaxed = mk_problem(0, slo_s=1.0)
        relaxed.arrival_time = 0.0
        assert pol.window_s([relaxed], now=0.0) == pytest.approx(0.010)
        urgent = mk_problem(1, slo_s=0.004)
        urgent.arrival_time = 0.0
        assert pol.window_s([urgent], now=0.0) == pytest.approx(0.002)
        # past the deadline -> no waiting at all
        assert pol.window_s([urgent], now=0.005) == 0.0
        # the most urgent pending item rules the bucket
        assert pol.window_s([relaxed, urgent], now=0.0) == pytest.approx(0.002)

    def test_adaptive_dispatches_urgent_item_before_fixed_window(self):
        clock = VirtualClock()
        sched = DynamicSpaceTimeScheduler(
            ScheduleConfig(batching_window_s=0.010,
                           batching_policy="slo_adaptive"),
            clock=clock)
        sched.submit(mk_problem(0, slo_s=0.002))
        clock.advance(0.001)  # half the SLO gone; fixed window would hold
        assert len(sched.pump()) == 1

    def test_adaptive_p95_not_worse_than_fixed_on_same_trace(self):
        from benchmarks.fig4_predictability import policy_trace

        fixed = policy_trace("fixed", tenants=4, events=120)
        adaptive = policy_trace("slo_adaptive", tenants=4, events=120)
        assert adaptive["p95_ms"] <= fixed["p95_ms"]


class TestRaggedMergeReference:
    def test_mixed_m_matches_ref_outputs(self):
        cache = SuperKernelCache(ScheduleConfig())
        key = jax.random.PRNGKey(3)
        problems = []
        for t, M in enumerate([5, 130, 32, 1]):
            kx, kw = jax.random.split(jax.random.fold_in(key, t))
            problems.append(GemmProblem(
                tenant_id=t,
                x=jax.random.normal(kx, (M, 32), jnp.float32),
                w=jax.random.normal(kw, (32, 24), jnp.float32)))
        outs = cache.execute_ragged(problems)
        for p, out in zip(problems, outs):
            want = ref.batched_gemm(p.x[None], p.w[None])[0]
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_group_count_is_pow2_bucketed(self):
        """Cache key no longer depends on the exact group count: 3 groups
        and 4 groups of the same row geometry share one compiled kernel."""
        cache = SuperKernelCache(ScheduleConfig(r_bucketing="pow2"))
        def run(n_groups):
            key = jax.random.PRNGKey(n_groups)
            probs = [GemmProblem(
                tenant_id=t,
                x=jax.random.normal(jax.random.fold_in(key, t), (16, 8), jnp.float32),
                w=jax.random.normal(jax.random.fold_in(key, 100 + t), (8, 8), jnp.float32))
                for t in range(n_groups)]
            return cache.execute_ragged(probs)
        run(3)   # groups pad 3 -> 4; 3 row-blocks pad to 4
        run(4)   # exactly 4 groups, 4 row-blocks: same key
        assert cache.stats.misses == 1
        assert cache.stats.hits == 1
        # correctness preserved under group padding
        outs = run(3)
        assert all(o.shape == (16, 8) for o in outs)


def _setup_engine(mode, R=2, slots=1, cache_len=32):
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")),
                              dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = [m.init(jax.random.fold_in(key, t)) for t in range(R)]
    eng = MultiTenantEngine(m, params, EngineConfig(
        num_tenants=R, slots_per_tenant=slots, cache_len=cache_len, mode=mode))
    return cfg, eng


class TestEngineThroughScheduler:
    def test_prefill_and_decode_route_through_shared_core(self):
        cfg, eng = _setup_engine("space_time")
        rng = np.random.RandomState(0)
        for t in range(2):
            eng.submit(InferenceRequest(
                tenant_id=t, prompt=list(rng.randint(1, cfg.vocab_size, 4)),
                max_new_tokens=3))
        eng.run_until_drained()
        assert len(eng.finished) == 2
        # every prefill + decode step went through the scheduler pump:
        # both same-length prefills MERGE into one dispatch, plus one
        # dispatch per decode step
        assert eng.scheduler.stats.dispatches == 3
        # the engine has no private monitor: it IS the scheduler's
        assert eng.monitor is eng.scheduler.monitor
        rep = eng.report()
        assert rep["scheduler_dispatches"] == 3.0
        # headline percentiles keep decode-step semantics; compile-heavy
        # prefill dispatches are reported under their own keys (no
        # ordering assertion: wall-clock latencies are load-dependent)
        assert rep["p95_s"] == eng.monitor.summary_for("decode")["p95_s"]
        assert "prefill_p95_s" in rep

    def test_space_time_and_time_only_identical_greedy_tokens(self):
        rng = np.random.RandomState(7)
        prompts = [list(rng.randint(1, 500, 5)) for _ in range(3)]
        results = {}
        for mode in ("space_time", "time_only"):
            cfg, eng = _setup_engine(mode, R=2)
            for i, p in enumerate(prompts):
                eng.submit(InferenceRequest(
                    tenant_id=i % 2, prompt=p, max_new_tokens=4))
            eng.run_until_drained()
            results[mode] = sorted(
                (r.tenant_id, tuple(r.prompt), tuple(r.generated))
                for r in eng.finished)
        assert results["space_time"] == results["time_only"]

    def test_cohort_split_by_size_cap_decodes_once_per_step(self):
        """Even with max_superkernel_size=1 (cohort workloads split across
        pump batches), caches must advance exactly once per step — tokens
        stay identical to the unconstrained run."""
        cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")),
                                  dtype="float32")
        m = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = [m.init(jax.random.fold_in(key, t)) for t in range(2)]
        rng = np.random.RandomState(5)
        prompts = [list(rng.randint(1, cfg.vocab_size, 4)) for _ in range(2)]
        results = {}
        for name, schedule in (
            ("default", None),
            ("split", ScheduleConfig(batching_window_s=0.0, max_superkernel_size=1)),
        ):
            eng = MultiTenantEngine(m, params, EngineConfig(
                num_tenants=2, slots_per_tenant=1, cache_len=32,
                mode="space_time", schedule=schedule))
            for t, p in enumerate(prompts):
                eng.submit(InferenceRequest(tenant_id=t, prompt=p, max_new_tokens=4))
            eng.run_until_drained()
            results[name] = sorted(
                (r.tenant_id, tuple(r.generated)) for r in eng.finished)
        assert results["default"] == results["split"]

    def test_admission_rejection_requeues_request(self):
        """A prefill pushed back by admission control must return its slot
        and retry on a later step — no request may be silently dropped."""
        cfg, eng_unused = _setup_engine("space_time")  # build model/config once
        m = eng_unused.model
        params = eng_unused._tenant_params
        eng = MultiTenantEngine(m, params, EngineConfig(
            num_tenants=2, slots_per_tenant=2, cache_len=32, mode="space_time",
            schedule=ScheduleConfig(batching_window_s=0.0,
                                    max_pending_per_tenant=1)))
        rng = np.random.RandomState(9)
        for _ in range(2):  # two same-tenant requests admitted in one pass
            eng.submit(InferenceRequest(
                tenant_id=0, prompt=list(rng.randint(1, cfg.vocab_size, 4)),
                max_new_tokens=3))
        eng.run_until_drained()
        assert len(eng.finished) == 2
        assert eng.scheduler.stats.rejected >= 1
        assert eng.slots.utilization() == 0.0

    def test_time_only_records_positional_latency_skew(self):
        """Sequential per-tenant dispatch: later tenants wait for earlier
        ones, so the shared monitor must see a nonzero spread; the merged
        cohort gives everyone the same completion time by construction."""
        cfg, eng = _setup_engine("time_only", R=3)
        rng = np.random.RandomState(1)
        for t in range(3):
            eng.submit(InferenceRequest(
                tenant_id=t, prompt=list(rng.randint(1, cfg.vocab_size, 4)),
                max_new_tokens=6))
        eng.run_until_drained()
        assert eng.monitor.predictability_spread() > 0.0
        assert len(eng.monitor.tenants) == 3
