"""Quickstart: the paper's claim through the repo's one front door.

Loads the committed ``examples/specs/paper_mix.json`` SystemSpec (the
paper's Table-1 SGEMM tenant mix under tiered SLOs), runs it under each
multiplexing strategy, and prints the throughput ordering the paper
measures — space_time > space_only > time_only. Everything flows through
``repro.api``: the same spec, ``replace()``d per strategy, picks the
right executor and returns the same ``RunReport`` shape the fleet and
live paths produce.

Equivalent CLI:

    PYTHONPATH=src python -m repro simulate --spec examples/specs/paper_mix.json
    PYTHONPATH=src python -m repro sweep    --spec examples/specs/paper_mix.json \
        --axis cost_model.strategy=time_only,space_only,space_time

For the live (real-kernel) versions of this demo see
``examples/spacetime_ablation.py`` and ``examples/multi_tenant_serving.py``.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

from repro.api import SystemSpec

SPEC = os.path.join(os.path.dirname(__file__), "specs", "paper_mix.json")


def main() -> None:
    spec = SystemSpec.load(SPEC)
    w = spec.workload
    print(f"spec: {SPEC}")
    print(f"{w.tenants} SGEMM tenants, {w.events} {w.process} arrivals "
          f"@ rho={w.rho} of space_time capacity, seed={w.seed}\n")

    print("strategy     tput cost/s    p95 ms   attain     util")
    tput = {}
    for strat in ("time_only", "space_only", "space_time"):
        report = spec.replace(**{"cost_model.strategy": strat}).run()
        s = report.summary
        tput[strat] = s["throughput_cost_per_s"]
        print(f"{strat:12s} {s['throughput_cost_per_s']:11.4g} "
              f"{s['p95_s']*1e3:9.3f} {s['slo_attainment']:8.3f} "
              f"{s['utilization']:8.3f}")

    print(f"\nspace_time / space_only: "
          f"{tput['space_time'] / tput['space_only']:.2f}x   "
          f"space_time / time_only: "
          f"{tput['space_time'] / tput['time_only']:.2f}x   "
          f"(paper: 3.23x / 7.73x)")

    # the same spec shape scales out: bump the fleet and reroute
    fleet = spec.replace(**{
        "fleet.replicas": 4,
        "router.policy": "least_cost",
        "cost_model.compile_us": 200.0,
    })
    s = fleet.run().summary
    print(f"\nsame spec, 4 replicas behind least_cost routing: "
          f"p95 {s['p95_s']*1e3:.3f}ms, attainment {s['slo_attainment']:.3f}, "
          f"cold-start fraction {s['cold_start_fraction']:.3f}")
    print("\nnext: python -m repro check --spec examples/specs/hetero_fleet.json")
    print("      python -m repro simulate --spec examples/specs/hetero_fleet.json")


if __name__ == "__main__":
    main()
