"""Discrete-event simulator over the real scheduling core.

NOT a model of the scheduler — the actual ``DynamicSpaceTimeScheduler``
(same queue, same batching policies, same admission control, same
straggler eviction) runs on a ``VirtualClock``, with a cost model pricing
each super-dispatch. Only the kernels are replaced: simulated workloads
carry a no-op executor, so a million-event policy sweep runs in seconds
on CPU with zero device work — and any policy conclusion transfers to the
live pump because it IS the live pump.

The event machinery lives in ``ReplicaPump`` (a ``VirtualClock`` binding
of ``repro.core.pump.PumpCore``): one scheduler on one virtual clock plus
the ripeness-instant drain loop. The solo
``Simulator`` wraps exactly one pump; the fleet simulator
(``repro.sim.fleet``) wraps N of them behind a router and merges their
ripeness instants into one global timeline — same pump, same event
semantics, so solo and fleet results are directly comparable.

Event ordering: between consecutive trace arrivals the loop advances the
virtual clock to each bucket's next ripeness instant and pumps there, so
batching-window dispatches happen at their exact modeled time rather than
being quantized to arrival times. Arrivals are stamped with their TRACE
time even when the (busy) virtual clock has run ahead — queueing delay
under overload is measured honestly.

Determinism: trace generation is seeded numpy, the clock is virtual, the
cost model is pure arithmetic — same seed in, byte-identical metrics JSON
out. That contract is what lets CI assert on simulated SLO orderings.

The drain machinery itself (ripeness calendar, EDF calendar, skip-pump
guard, routing signals) lives in the clock-agnostic ``PumpCore``
(``repro.core.pump``) — shared verbatim with the live fleet
(``repro.serving.fleet``), which runs it on a ``WallClock``.
"""

from __future__ import annotations

import math
from typing import Callable, Iterable, List, Optional, Sequence

from repro.config import ScheduleConfig
from repro.core.clock import VirtualClock
from repro.core.pump import PumpCore
from repro.sim.costmodel import RooflineCostModel
from repro.sim.metrics import MetricsAccumulator, SimMetrics
from repro.sim.traces import Arrival, Trace

def _noop_execute(batch: List) -> None:
    # None signals "no per-item results" to the scheduler's dispatch loop,
    # which then skips the result-assignment zip entirely
    return None


class SimWorkload:
    """Minimal object satisfying the scheduler's Workload protocol.

    Deliberately not the ``Workload`` dataclass: a ``__slots__`` class with
    a no-op executor keeps per-event cost low enough for million-event
    traces (the dataclass's default-factory fields roughly double intake
    time at that scale). Fields that are never written per-instance
    (``merge_family``, ``result``, ``execute``) are class attributes — a
    few fewer stores on a constructor that runs once per simulated event.

    ``est_s`` is the router's estimated solo dispatch seconds for this
    item (0.0 outside fleet runs) — the pump subtracts it back out of its
    backlog estimate on completion.
    """

    __slots__ = ("tenant_id", "bucket", "cost", "slo_s", "kind", "flops",
                 "bytes", "arrival_time", "completion_time", "est_s")

    merge_family = None           # ragged merge is a live-kernel concern
    result = None
    execute = staticmethod(_noop_execute)

    def __init__(self, spec, cost: float):
        self.tenant_id = spec.tenant_id
        self.bucket = spec.bucket
        self.cost = cost
        self.slo_s = spec.slo_s
        self.kind = spec.kind
        self.flops = spec.flops
        self.bytes = spec.bytes
        self.arrival_time = 0.0
        self.completion_time = None
        self.est_s = 0.0


class ReplicaPump(PumpCore):
    """One replica of the real scheduler on its own virtual clock, plus
    the ripeness-instant drain machinery — the unit both the solo
    ``Simulator`` and the fleet simulator are built from.

    A thin simulation binding of the clock-agnostic ``PumpCore``
    (``repro.core.pump``): same calendar, same drain loop, same routing
    signals — this subclass only supplies the sim defaults (a
    ``VirtualClock`` starting at ``start_s`` and a roofline cost model).
    The live fleet (``repro.serving.fleet``) runs the identical core on a
    ``WallClock``.
    """

    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        start_s: float = 0.0,
        clock: Optional[VirtualClock] = None,
        replica_id: Optional[int] = None,
    ):
        super().__init__(
            schedule=schedule,
            cost_model=cost_model or RooflineCostModel(),
            clock=clock if clock is not None else VirtualClock(start_s),
            replica_id=replica_id,
        )


class Simulator:
    """Drives the real scheduler over a trace on a virtual timeline."""

    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        start_s: float = 0.0,
        recorder=None,
    ):
        self.pump = ReplicaPump(schedule=schedule, cost_model=cost_model,
                                start_s=start_s)
        self.clock = self.pump.clock
        self.scheduler = self.pump.scheduler
        self._recorder = recorder

    def run(self, trace: Trace | Iterable[Arrival]) -> SimMetrics:
        pump = self.pump
        # attach lazily: callers (repro.api) may swap the cost model in
        # after construction, and the dispatch tap must capture the final
        # (cold-start-wrapped) model
        if self._recorder is not None and pump.recorder is None:
            pump.attach_recorder(self._recorder.shard(0))
        acc = MetricsAccumulator()
        pump.accs = [acc]
        t_start = pump.clock.now()

        # EDF stays on the per-event loop: its intake needs the real
        # scheduler.submit per event (feasibility pricing, min-update
        # calendar) — the chunked fast path's bypasses don't apply.
        if pump._use_calendar and pump._edf is None \
                and hasattr(trace, "iter_chunks"):
            self._run_chunked(trace)
        else:
            submit, drain_until = pump.submit, pump.drain_until
            for t_s, spec, cost in trace:
                drain_until(t_s)
                submit(SimWorkload(spec, cost), t_s)
        pump.drain_tail()

        return pump.freeze(acc, sim_duration_s=pump.clock.now() - t_start)

    def _run_chunked(self, trace: Trace) -> None:
        """Columnar intake: the same event sequence as the per-event loop
        (drain to each arrival, stamp, admit, pump) driven from numpy
        chunks with the per-event bookkeeping inlined.

        Two deviations from the naive loop, both unobservable:

        * the virtual clock is NOT advanced to arrivals that provably
          trigger no pump — the clock is only ever READ at pump instants
          and both paths advance to the same instants before pumping
          (``drain_tail`` entry re-syncs via one final ``advance_to``);
        * ``scheduler.submit`` is bypassed when no admission cap is set —
          its only effects then are the arrival stamp and the queue push,
          replicated here verbatim.
        """
        pump = self.pump
        clock = pump.clock
        sched = pump.scheduler
        queue = sched.queue
        drain_until = pump.drain_until
        sched_pump = sched.pump
        absorb = pump._absorb
        cal_note_push = pump._cal_note_push
        ripe_min = pump._ripe_min
        queue_push = queue.push
        inf = math.inf

        # any active admission control (pending cap OR feasibility pricing)
        # forces the real scheduler.submit path per event
        capped = (sched.schedule.max_pending_per_tenant is not None
                  or sched._feasibility)
        submit_slow = pump.submit
        # recorder hook hoisted out of the loop: recorder-off chunked
        # intake pays zero per-event cost for observability
        rec = pump.recorder
        rec_arr = rec.record_arrival if rec is not None else None

        cval = clock.now()            # tracks the real (virtual) clock
        m = ripe_min()
        if m is None:
            m = inf
        last_t = cval

        for times, idx, costs, table in trace.iter_chunks():
            # plain-Python lists iterate ~3x faster than numpy scalars,
            # and .tolist() round-trips float64 exactly
            ts = times.tolist()
            ws = [SimWorkload(table[i], c)
                  for i, c in zip(idx.tolist(), costs.tolist())]
            for k, t in enumerate(ts):
                if m < t and cval < t:
                    drain_until(t)
                    cval = clock.now()
                    m = ripe_min()
                    if m is None:
                        m = inf
                w = ws[k]
                if capped:
                    submit_slow(w, t)
                    cval = clock.now()
                    m = ripe_min()
                    if m is None:
                        m = inf
                    continue
                w.arrival_time = t
                depth = queue_push(w)
                if rec_arr is not None:
                    rec_arr(t, w.tenant_id, w.bucket, True)
                if depth >= pump._cap or depth == 1:
                    cal_note_push(w.bucket, t, depth)
                    v = pump._ripe_at[w.bucket]
                    if v < m:
                        m = v
                now_eff = cval if cval > t else t
                if m <= now_eff + (1e-9 + abs(now_eff) * 1e-12):
                    clock.advance_to(t)
                    done = sched_pump()
                    if done:
                        absorb(done)
                    cval = clock.now()
                    m = ripe_min()
                    if m is None:
                        m = inf
            if ts:
                last_t = ts[-1]

        # the per-event loop leaves the clock at max(last pump instant,
        # last arrival); drain_tail reads it — re-sync before returning
        clock.advance_to(last_t)


def simulate(
    trace: Trace | Iterable[Arrival],
    schedule: Optional[ScheduleConfig] = None,
    cost_model: Optional[Callable[[Sequence], float]] = None,
) -> SimMetrics:
    """One-shot convenience wrapper: fresh simulator, one trace, metrics."""
    return Simulator(schedule=schedule, cost_model=cost_model).run(trace)
