"""Super-kernel builder + compile cache.

"Space-time scheduling merges many concurrent small kernels from disjoint
DNN graphs into a small set of larger super-kernels that together fill the
GPU" — here, one ``batched_gemm`` pallas_call whose leading grid axis is the
problem index R.

Because arrivals are stochastic, R varies call-to-call; compiling one
super-kernel per exact R would thrash the compile cache. We pad R up to a
power-of-two bucket (zero problems are padded with zeros and discarded on
unstack), so the number of compiled variants per shape bucket is
log2(max_R). The paper observes "overheads gradually decrease if we cache
super-kernels as workloads stabilize" — the cache hit-rate statistic makes
that measurable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScheduleConfig
from repro.core.queue import GemmProblem, ShapeBucket
from repro.core.workload import round_pow2
from repro.kernels import ops
from repro.kernels.grouped_gemm import make_group_layout

# Backwards-compatible alias — the shared definition lives in
# ``repro.core.workload`` so cache keys and cost-model keys agree.
_round_pow2 = round_pow2


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    executions: int = 0
    problems_executed: int = 0
    padded_problems: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


class SuperKernelCache:
    """Compiled super-kernel store keyed on (bucket, R_bucket)."""

    def __init__(self, schedule: ScheduleConfig):
        self.schedule = schedule
        self._cache: Dict[Tuple[ShapeBucket, int], Callable] = {}
        self.stats = CacheStats()

    def _r_bucket(self, r: int) -> int:
        if self.schedule.r_bucketing == "exact":
            return r
        return round_pow2(r)

    def _build(self, bucket: ShapeBucket, r_bucket: int) -> Callable:
        def call(xs: jax.Array, ws: jax.Array) -> jax.Array:
            return ops.batched_gemm(xs, ws)

        return jax.jit(call)

    def get(self, bucket: ShapeBucket, r: int) -> Tuple[Callable, int]:
        r_bucket = self._r_bucket(r)
        key = (bucket, r_bucket)
        fn = self._cache.get(key)
        if fn is None:
            self.stats.misses += 1
            fn = self._build(bucket, r_bucket)
            self._cache[key] = fn
        else:
            self.stats.hits += 1
        return fn, r_bucket

    def execute_stacked(
        self, bucket: ShapeBucket, xs: jax.Array, ws: jax.Array, r: int
    ) -> jax.Array:
        """Run a super-kernel over ALREADY-STACKED device-resident slabs.

        This is the paper's measurement setting ("data is preallocated on
        the device as in a real-world DNN inference setting"): tenant
        weights live stacked in the TenantManager, so dispatch cost is pure
        kernel time. Returns the stacked (R, M, N) output.
        """
        fn, r_bucket = self.get(bucket, r)
        if r_bucket != xs.shape[0]:
            pad = r_bucket - xs.shape[0]
            xs = jnp.pad(xs, ((0, pad), (0, 0), (0, 0)))
            ws = jnp.pad(ws, ((0, pad), (0, 0), (0, 0)))
            self.stats.padded_problems += pad
        out = jax.block_until_ready(fn(xs, ws))
        self.stats.executions += 1
        self.stats.problems_executed += r
        return out if out.shape[0] == r else out[:r]

    def execute_ragged(self, problems: List[GemmProblem]) -> List[jax.Array]:
        """Variable-M merge (MAGMA-vbatched analogue, beyond-paper).

        Problems must share (K, N, dtype) but may have DIFFERENT row counts
        M — e.g. tenants with different live batch sizes. Rows are packed
        group-aligned and run through ONE grouped_gemm pallas_call; the
        cache key buckets BOTH the padded total row count and the group
        count (pow2 each — extra groups carry zero weights and own no row
        blocks), so the compiled-variant count stays bounded at
        log2(max_rows) * log2(max_groups) under stochastic M mixes.
        """
        if not problems:
            return []
        K = problems[0].x.shape[1]
        N = problems[0].w.shape[1]
        dt = problems[0].x.dtype
        assert all(
            p.x.shape[1] == K and p.w.shape[1] == N and p.x.dtype == dt
            for p in problems
        ), "ragged merge requires matching (K, N, dtype)"

        bm = 128
        sizes = np.array([p.x.shape[0] for p in problems])
        offsets, block_groups, T = make_group_layout(sizes, bm=bm)
        t_bucket = self._r_bucket(T // bm) * bm  # pow2-bucket padded rows
        nblocks = t_bucket // bm
        bg = np.zeros((nblocks,), np.int32)
        bg[: len(block_groups)] = block_groups

        xs = jnp.zeros((t_bucket, K), dt)
        for p, off in zip(problems, offsets):
            xs = jax.lax.dynamic_update_slice(xs, p.x.astype(dt), (int(off), 0))
        g_bucket = self._r_bucket(len(problems))
        ws = jnp.stack([p.w for p in problems])
        if g_bucket != len(problems):
            ws = jnp.pad(ws, ((0, g_bucket - len(problems)), (0, 0), (0, 0)))
            self.stats.padded_problems += g_bucket - len(problems)

        key = (ShapeBucket("grouped", t_bucket, K, N, str(dt)), g_bucket)
        fn = self._cache.get(key)
        if fn is None:
            self.stats.misses += 1
            fn = jax.jit(lambda x, w, g: ops.grouped_gemm(x, w, g, bm=bm))
            self._cache[key] = fn
        else:
            self.stats.hits += 1

        out = jax.block_until_ready(fn(xs, ws, jnp.asarray(bg)))
        self.stats.executions += 1
        self.stats.problems_executed += len(problems)
        return [
            out[int(off): int(off) + int(sz)]
            for off, sz in zip(offsets, sizes)
        ]

    def execute(self, problems: List[GemmProblem]) -> List[jax.Array]:
        """Merge problems (same bucket) into one super-kernel call."""
        if not problems:
            return []
        bucket = problems[0].bucket
        assert all(p.bucket == bucket for p in problems), "bucket mismatch"
        r = len(problems)
        fn, r_bucket = self.get(bucket, r)

        xs = jnp.stack([p.x for p in problems])
        ws = jnp.stack([p.w for p in problems])
        if r_bucket != r:
            pad = r_bucket - r
            xs = jnp.pad(xs, ((0, pad), (0, 0), (0, 0)))
            ws = jnp.pad(ws, ((0, pad), (0, 0), (0, 0)))
            self.stats.padded_problems += pad
        out = fn(xs, ws)
        out = jax.block_until_ready(out)
        self.stats.executions += 1
        self.stats.problems_executed += r
        return [out[i] for i in range(r)]
