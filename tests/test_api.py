"""The declarative front door (repro.api): SystemSpec round-trips,
validation errors, executor selection, the RunReport contract, and the
unified CLI.

The hypothesis round-trip property lives at the bottom behind the usual
importorskip guard; plain parametrized versions of the same properties
run everywhere.
"""

import dataclasses
import json
import os

import pytest

from repro.api import (
    AutoscaleSpec,
    CostModelSpec,
    FleetRun,
    FleetSpec,
    LiveRun,
    RouterSpec,
    RunReport,
    SCHEMA_VERSION,
    SchedulerSpec,
    SimRun,
    SystemSpec,
    WorkloadSpec,
    build_mix,
    resolve_rate_hz,
)
from repro.api.cli import main as cli_main
from repro.launch.roofline import HARDWARE_SPECS, TPU_V5E, resolve_spec
from repro.sim import SimMetrics, simulate


def tiny_spec(**workload_overrides) -> SystemSpec:
    wl = dict(mix="sgemm", tenants=4, events=1500, seed=0, rho=0.7)
    wl.update(workload_overrides)
    return SystemSpec(
        workload=WorkloadSpec(**wl),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32),
    )


def hetero_spec() -> SystemSpec:
    return SystemSpec(
        workload=WorkloadSpec(mix="fleet", tenants=6, process="mmpp",
                              events=1500, seed=3, rho=0.85),
        fleet=FleetSpec(replicas=2, specs=("v5e", "v5e_half"),
                        autoscale=AutoscaleSpec(
                            max_replicas=4, up_backlog_s=0.005,
                            down_backlog_s=0.001, interval_s=0.002,
                            spinup_s=1e-4)),
        router=RouterSpec(policy="least_cost"),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32),
        cost_model=CostModelSpec(compile_us=200.0),
    )


# ------------------------------------------------------------- round trips
class TestSpecRoundTrip:
    @pytest.mark.parametrize("spec", [
        SystemSpec(),
        tiny_spec(),
        hetero_spec(),
        SystemSpec(mode="live",
                   workload=WorkloadSpec(tenants=2, events=4)),
    ], ids=["defaults", "solo", "hetero_elastic", "live"])
    def test_from_dict_to_dict_idempotent(self, spec):
        d = spec.to_dict()
        again = SystemSpec.from_dict(d)
        assert again == spec
        assert again.to_dict() == d
        # and through an actual JSON string (what save/load do)
        assert SystemSpec.from_json(spec.to_json()) == spec

    def test_to_dict_is_json_serializable_and_versioned(self):
        d = hetero_spec().to_dict()
        doc = json.loads(json.dumps(d))
        assert doc["schema_version"] == SCHEMA_VERSION
        assert doc["fleet"]["specs"] == ["v5e", "v5e_half"]

    def test_save_load(self, tmp_path):
        path = str(tmp_path / "spec.json")
        spec = hetero_spec()
        spec.save(path)
        assert SystemSpec.load(path) == spec

    def test_partial_dict_fills_defaults(self):
        spec = SystemSpec.from_dict(
            {"workload": {"events": 123}, "router": {"policy": "affinity"}})
        assert spec.workload.events == 123
        assert spec.workload.mix == "sgemm"
        assert spec.router.policy == "affinity"
        assert spec.scheduler is None

    def test_newer_schema_version_rejected(self):
        with pytest.raises(ValueError, match="schema_version"):
            SystemSpec.from_dict({"schema_version": SCHEMA_VERSION + 1})

    def test_roundtrip_build_reproduces_metrics_bytes(self):
        spec = tiny_spec()
        a = spec.build().run_metrics().to_json()
        b = SystemSpec.from_dict(spec.to_dict()).build().run_metrics().to_json()
        assert a == b

    def test_roundtrip_build_reproduces_fleet_bytes(self):
        spec = hetero_spec()
        a = FleetRun(spec).run_metrics().to_json()
        b = FleetRun(SystemSpec.from_json(spec.to_json())).run_metrics().to_json()
        assert a == b

    def test_run_report_roundtrip(self, tmp_path):
        report = tiny_spec().run()
        path = str(tmp_path / "report.json")
        report.save(path)
        again = RunReport.load(path)
        assert again == report
        assert again.schema_version == SCHEMA_VERSION
        assert again.spec == tiny_spec().to_dict()


# -------------------------------------------------------------- validation
class TestValidation:
    def test_unknown_hardware_lists_registered_names(self):
        # the SAME actionable message everywhere: the registry's own
        # resolve_spec error is what spec validation surfaces
        for raiser in (
            lambda: resolve_spec("tpu_v9000"),
            lambda: CostModelSpec(hardware="tpu_v9000"),
            lambda: FleetSpec(replicas=2, specs=("v5e", "tpu_v9000")),
        ):
            with pytest.raises(ValueError) as e:
                raiser()
            for name in HARDWARE_SPECS:
                assert name in str(e.value)

    def test_resolve_spec_passthrough_and_alias(self):
        assert resolve_spec(TPU_V5E) is TPU_V5E
        from repro.sim import resolve_spec as sim_resolve
        assert sim_resolve is resolve_spec

    @pytest.mark.parametrize("bad,match", [
        (lambda: WorkloadSpec(mix="nope"), "unknown mix"),
        (lambda: WorkloadSpec(process="nope"), "unknown arrival process"),
        (lambda: WorkloadSpec(rho=None, rate_hz=None), "rho"),
        (lambda: WorkloadSpec(rho=-1.0), "rho"),
        (lambda: WorkloadSpec(process="replay"), "csv_path"),
        (lambda: WorkloadSpec(tenants=0), "tenants"),
        (lambda: RouterSpec(policy="nope"), "unknown router"),
        (lambda: CostModelSpec(kind="nope"), "unknown cost model kind"),
        (lambda: CostModelSpec(strategy="nope"), "unknown strategy"),
        (lambda: CostModelSpec(kind="calibrated"), "calibration_path"),
        (lambda: CostModelSpec(compile_us=-1.0), "compile_us"),
        (lambda: FleetSpec(replicas=0), "replicas"),
        (lambda: FleetSpec(replicas=2, specs=()), "non-empty"),
        (lambda: AutoscaleSpec(policy="nope"), "unknown autoscaler"),
        (lambda: AutoscaleSpec(min_replicas=5, max_replicas=2), "min_replicas"),
        (lambda: SchedulerSpec(batching_window_s=-1.0), "batching_window_s"),
        (lambda: SystemSpec(mode="nope"), "unknown mode"),
    ])
    def test_actionable_errors(self, bad, match):
        with pytest.raises(ValueError, match=match):
            bad()

    def test_unknown_field_rejected_with_known_list(self):
        with pytest.raises(ValueError, match="known"):
            SystemSpec.from_dict({"workload": {"evnts": 10}})
        with pytest.raises(ValueError, match="known"):
            SystemSpec.from_dict({"wrkload": {}})

    def test_live_fleet_builds(self):
        # live fleets are real now: N engines behind the sim routers
        run = SystemSpec(mode="live", fleet=FleetSpec(replicas=2)).build()
        assert run.executor == "live"

    def test_live_rejects_sharded_workers(self):
        with pytest.raises(ValueError, match="workers"):
            SystemSpec(mode="live",
                       fleet=FleetSpec(replicas=2, workers=2))

    def test_live_rejects_autoscale(self):
        from repro.api.spec import AutoscaleSpec
        with pytest.raises(ValueError, match="autoscale"):
            SystemSpec(mode="live",
                       fleet=FleetSpec(replicas=1,
                                       autoscale=AutoscaleSpec()))

    def test_calibrated_over_hetero_specs_rejected(self):
        # heterogeneous replicas price through per-hardware rooflines; a
        # fleet-wide calibrated table would be silently dropped, so the
        # combination must fail loudly at validation time
        with pytest.raises(ValueError, match="FleetCalibrator"):
            SystemSpec(
                fleet=FleetSpec(replicas=2, specs=("v5e", "v5e_half")),
                cost_model=CostModelSpec(kind="calibrated",
                                         calibration_path="x.json"))

    def test_non_integer_schema_version_rejected(self):
        with pytest.raises(ValueError, match="integer"):
            SystemSpec.from_dict({"schema_version": "2"})

    def test_missing_spec_file_actionable(self):
        with pytest.raises(ValueError, match="examples/specs"):
            SystemSpec.load("/nonexistent/spec.json")

    def test_missing_calibration_table_actionable(self):
        spec = tiny_spec()
        spec = spec.replace(**{
            "cost_model.kind": "calibrated",
            "cost_model.calibration_path": "/nonexistent/costs.json"})
        with pytest.raises(ValueError, match="calibrate"):
            spec.build().run_metrics()

    def test_replace_unknown_path_rejected(self):
        with pytest.raises(ValueError, match="unknown field"):
            tiny_spec().replace(**{"workload.evnts": 10})
        with pytest.raises(ValueError, match="not a spec section"):
            tiny_spec().replace(**{"workload.events.deep": 10})


# -------------------------------------------------------- executor choice
class TestBuild:
    def test_solo_executor(self):
        assert isinstance(tiny_spec().build(), SimRun)

    def test_replicas_pick_fleet(self):
        assert isinstance(
            tiny_spec().replace(**{"fleet.replicas": 2}).build(), FleetRun)

    def test_specs_pick_fleet_even_solo(self):
        spec = tiny_spec().replace(**{"fleet.specs": ["v5e_half"]})
        assert isinstance(spec.build(), FleetRun)

    def test_autoscale_picks_fleet(self):
        spec = SystemSpec(
            workload=tiny_spec().workload,
            fleet=FleetSpec(replicas=1, autoscale=AutoscaleSpec()))
        assert isinstance(spec.build(), FleetRun)

    def test_live_mode_picks_live_without_importing_jax(self):
        run = SystemSpec(mode="live").build()
        assert isinstance(run, LiveRun)  # jax only imported inside run()

    def test_reports_share_shape_across_executors(self):
        solo = tiny_spec().run()
        fleet = FleetRun(hetero_spec()).run()
        for report, executor in ((solo, "simulator"), (fleet, "fleet")):
            assert report.executor == executor
            assert report.schema_version == SCHEMA_VERSION
            assert report.spec["schema_version"] == SCHEMA_VERSION
            assert "p95_s" in report.summary
            assert report.metrics["schema_version"] == SCHEMA_VERSION
            # the echo rebuilds the producing spec
            assert SystemSpec.from_dict(report.spec).build() is not None

    def test_solo_cold_start_wrap(self):
        cold = tiny_spec().replace(**{"cost_model.compile_us": 500.0})
        m_cold = cold.build().run_metrics()
        m_warm = tiny_spec().build().run_metrics()
        # compiles push the makespan out for the same trace
        assert m_cold.sim_duration_s > m_warm.sim_duration_s

    def test_rate_hz_overrides_rho(self):
        spec = tiny_spec(rate_hz=1234.5)
        assert resolve_rate_hz(spec, build_mix(spec.workload)) == 1234.5

    def test_rho_anchors_scale_with_fleet(self):
        mix = build_mix(tiny_spec().workload)
        solo = resolve_rate_hz(tiny_spec(), mix)
        four = resolve_rate_hz(
            tiny_spec().replace(**{"fleet.replicas": 4}), mix)
        assert four == pytest.approx(4 * solo)

    def test_single_mix_matches_legacy_dynamic_trace(self):
        """The spec-built 'single' mix replay must equal the historical
        hand-wired dynamic_trace simulation path."""
        from repro.api import single_shape_mix
        from repro.config import ScheduleConfig
        from repro.sim import PoissonTrace, RooflineCostModel

        spec = SystemSpec(
            workload=WorkloadSpec(mix="single", tenants=5, events=600,
                                  seed=7, rate_hz=15000.0, slo_s=0.01),
            scheduler=SchedulerSpec(batching_window_s=0.0005,
                                    max_superkernel_size=32),
        )
        via_api = spec.build().run_metrics()
        legacy = simulate(
            PoissonTrace(single_shape_mix(5, 0.01), 15000.0, 600, seed=7),
            ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32),
            RooflineCostModel())
        assert via_api.to_json() == legacy.to_json()


# ------------------------------------------------------------ schema stamp
class TestSchemaVersion:
    def test_sim_metrics_json_versioned(self):
        m = tiny_spec().build().run_metrics()
        assert isinstance(m, SimMetrics)
        assert json.loads(m.to_json())["schema_version"] == SCHEMA_VERSION

    def test_bench_json_versioned(self):
        from repro.sim import to_bench_json

        doc = json.loads(to_bench_json(
            "t", {"cell": tiny_spec().build().run_metrics()}))
        assert doc["schema_version"] == SCHEMA_VERSION

    def test_check_regression_ignores_schema_version(self):
        from benchmarks.check_regression import _direction, compare

        rows = {"x/p95": 10.0, "x/goodput": 5.0}
        problems, gated = compare(rows, dict(rows), tolerance=0.10)
        assert problems == [] and gated == 2
        assert _direction("x/schema_version") == 0  # never gated


# --------------------------------------------------------------------- CLI
class TestCli:
    SPEC_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                            "examples", "specs")

    def test_specs_lists_registries(self, capsys):
        assert cli_main(["specs"]) == 0
        out = capsys.readouterr().out
        for name in HARDWARE_SPECS:
            assert name in out
        for router in ("round_robin", "jsq", "least_cost", "affinity"):
            assert router in out

    def test_specs_json(self, capsys):
        assert cli_main(["specs", "--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert doc["schema_version"] == SCHEMA_VERSION
        assert set(HARDWARE_SPECS) <= set(doc["hardware"])

    @pytest.mark.parametrize("name", ["paper_mix.json", "hetero_fleet.json",
                                      "deadline_fleet.json"])
    def test_committed_specs_check(self, name, capsys):
        path = os.path.join(self.SPEC_DIR, name)
        assert cli_main(["check", "--spec", path]) == 0
        assert "spec OK" in capsys.readouterr().out

    def test_simulate_tiny_with_check_and_out(self, tmp_path, capsys):
        out = str(tmp_path / "report.json")
        path = os.path.join(self.SPEC_DIR, "paper_mix.json")
        rc = cli_main(["simulate", "--spec", path, "--events", "800",
                       "--check", "--out", out])
        assert rc == 0
        assert "byte-identical: True" in capsys.readouterr().out
        report = RunReport.load(out)
        assert report.executor == "simulator"
        assert report.spec["workload"]["events"] == 800

    def test_sweep_dry_run(self, capsys):
        path = os.path.join(self.SPEC_DIR, "paper_mix.json")
        rc = cli_main(["sweep", "--spec", path, "--dry-run",
                       "--axis", "cost_model.strategy=time_only,space_time"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "2 cells" in out and "dry run" in out

    def test_sweep_executes_and_writes_bench_json(self, tmp_path, capsys):
        out = str(tmp_path / "bench.json")
        path = os.path.join(self.SPEC_DIR, "paper_mix.json")
        rc = cli_main(["sweep", "--spec", path, "--events", "500",
                       "--axis", "cost_model.strategy=time_only,space_time",
                       "--json", out])
        assert rc == 0
        doc = json.load(open(out))
        assert doc["schema_version"] == SCHEMA_VERSION
        assert set(doc["sections"]) == {"strategy=time_only",
                                        "strategy=space_time"}

    def test_bad_spec_is_a_clean_user_error(self, tmp_path, capsys):
        path = str(tmp_path / "bad.json")
        with open(path, "w") as fh:
            json.dump({"router": {"policy": "nope"}}, fh)
        rc = cli_main(["check", "--spec", path])
        assert rc == 2
        assert "unknown router" in capsys.readouterr().err

    def test_mistyped_value_is_a_clean_user_error(self, tmp_path, capsys):
        # "tenants": "8" raises TypeError inside __post_init__ comparisons;
        # the CLI must fold it into the one-line spec-error contract
        path = str(tmp_path / "typed.json")
        with open(path, "w") as fh:
            json.dump({"workload": {"tenants": "8"}}, fh)
        rc = cli_main(["check", "--spec", path])
        assert rc == 2
        assert "spec error" in capsys.readouterr().err

    def test_set_override(self, capsys):
        rc = cli_main(["check", "--set", "router.policy=affinity",
                       "--set", "fleet.replicas=3"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "router=affinity" in out and "3 replica(s)" in out


# ----------------------------------------------------- hypothesis property
try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("api_ci", max_examples=30, deadline=None)
    settings.load_profile("api_ci")

    spec_strategy = st.builds(
        SystemSpec,
        workload=st.builds(
            WorkloadSpec,
            mix=st.sampled_from(("sgemm", "fleet", "serving", "single")),
            tenants=st.integers(1, 16),
            process=st.sampled_from(("poisson", "mmpp", "diurnal", "flash")),
            events=st.integers(0, 5000),
            seed=st.integers(0, 2**31 - 1),
            rho=st.floats(0.05, 3.0, allow_nan=False),
            zipf_a=st.floats(0.0, 2.0, allow_nan=False),
        ),
        fleet=st.builds(
            FleetSpec,
            replicas=st.integers(1, 8),
            specs=st.one_of(
                st.none(),
                st.lists(st.sampled_from(sorted(HARDWARE_SPECS)),
                         min_size=1, max_size=4).map(tuple)),
            autoscale=st.one_of(st.none(), st.builds(
                AutoscaleSpec,
                max_replicas=st.integers(1, 8),
                spinup_s=st.floats(0.0, 1e-3, allow_nan=False))),
        ),
        router=st.builds(RouterSpec,
                         policy=st.sampled_from(
                             ("round_robin", "jsq", "least_cost", "affinity"))),
        scheduler=st.one_of(st.none(), st.builds(
            SchedulerSpec,
            batching_window_s=st.floats(0.0, 0.01, allow_nan=False),
            batching_policy=st.sampled_from(("fixed", "slo_adaptive")),
            max_superkernel_size=st.integers(1, 256),
        )),
        cost_model=st.builds(
            CostModelSpec,
            hardware=st.sampled_from(sorted(HARDWARE_SPECS)),
            strategy=st.sampled_from(
                ("time_only", "space_only", "space_time", "exclusive")),
            compile_us=st.floats(0.0, 1000.0, allow_nan=False),
        ),
    )

    class TestRoundTripProperty:
        @given(spec=spec_strategy)
        def test_from_dict_to_dict_idempotent(self, spec):
            d = spec.to_dict()
            again = SystemSpec.from_dict(d)
            assert again == spec
            assert again.to_dict() == d
            # and the dict is genuinely JSON-portable
            assert SystemSpec.from_dict(json.loads(json.dumps(d))) == spec

        @settings(max_examples=5, deadline=None)
        @given(seed=st.integers(0, 2**16), tenants=st.integers(1, 6),
               router=st.sampled_from(("jsq", "least_cost", "affinity")))
        def test_roundtripped_spec_rebuilds_identical_metrics(
                self, seed, tenants, router):
            spec = SystemSpec(
                workload=WorkloadSpec(mix="fleet", tenants=tenants,
                                      events=400, seed=seed, rho=0.9),
                fleet=FleetSpec(replicas=2),
                router=RouterSpec(policy=router),
                scheduler=SchedulerSpec(batching_window_s=0.0005,
                                        max_superkernel_size=32),
                cost_model=CostModelSpec(compile_us=100.0),
            )
            a = spec.build().run_metrics().to_json()
            b = SystemSpec.from_json(spec.to_json()).build() \
                .run_metrics().to_json()
            assert a == b
