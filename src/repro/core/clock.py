"""Injectable time sources for the scheduling core.

Every *policy* decision in the unified scheduler (batching-window
ripeness, SLO slack, latency accounting) reads time through a ``Clock``
object instead of calling ``time.perf_counter()`` directly. That makes
the event pump deterministic under test and lets benchmarks replay the
same arrival trace against different policies on a virtual timeline —
the property-based "batched == sequential" invariants and the Fig-4
fixed-vs-adaptive comparison both depend on this.

Two implementations:

    WallClock     -- real time (``time.perf_counter``); ``advance`` is a
                     no-op because wall time advances on its own.
    VirtualClock  -- a simulated timeline the caller (or the scheduler's
                     cost model) advances explicitly. Same trace in, same
                     latencies out, every run.
"""

from __future__ import annotations

import time


class Clock:
    """Minimal time-source protocol used by the scheduling core."""

    def now(self) -> float:
        raise NotImplementedError

    def advance(self, dt_s: float) -> None:
        raise NotImplementedError

    def advance_to(self, t_s: float) -> None:
        """Jump forward to an absolute time (never backwards)."""
        raise NotImplementedError


class WallClock(Clock):
    """Real host time. The production default."""

    def now(self) -> float:
        return time.perf_counter()

    def advance(self, dt_s: float) -> None:
        # wall time advances on its own; modeled time has nothing to add
        pass

    def advance_to(self, t_s: float) -> None:
        # same contract as advance: wall time cannot be pushed. This is
        # what makes the pump core clock-agnostic — the drain machinery
        # calls advance_to unconditionally, and only virtual timelines
        # actually move under it.
        pass


class VirtualClock(Clock):
    """Deterministic simulated timeline (starts at ``start_s``)."""

    def __init__(self, start_s: float = 0.0):
        self._t = float(start_s)

    def now(self) -> float:
        return self._t

    def advance(self, dt_s: float) -> None:
        if dt_s < 0.0:
            raise ValueError("virtual time cannot move backwards")
        self._t += dt_s

    def advance_to(self, t_s: float) -> None:
        """Jump forward to an absolute time (never backwards)."""
        self._t = max(self._t, float(t_s))
