"""zamba2-7b [arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Mamba2 backbone with shared attention blocks interleaved (1 shared-attn
block per 6 layers, Zamba2 style).
"""

from repro.config import BlockKind, ModelConfig, SSMConfig, register_config

_PATTERN = tuple(
    BlockKind.HYBRID_SHARED_ATTN if (i + 1) % 6 == 0 else BlockKind.MAMBA2
    for i in range(81)
)

CONFIG = register_config(
    ModelConfig(
        name="zamba2-7b",
        source="arXiv:2411.15242",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        vocab_size=32000,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=256),
        block_pattern=_PATTERN,
    )
)
