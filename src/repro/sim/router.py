"""Fleet routing policies: which replica does the next arrival go to?

A ``Router`` sees one arrival's ``TenantSpec`` plus the live per-replica
state (queue depth, estimated backlog seconds, warm compile caches) and
returns a replica index. Routers are deterministic pure functions of that
state — the fleet determinism contract (same seed, byte-identical
metrics) extends through routing.

The four policies span the classic trade-off surface:

    round_robin  -- load-oblivious; perfectly balanced COUNTS, blind to
                    cost heterogeneity and backlog (the baseline).
    jsq          -- join-shortest-queue on pending item count; the
                    textbook load balancer (Zhao et al.'s predictable-
                    latency setting).
    least_cost   -- join-least-estimated-WORK: residual busy time +
                    estimated backlog seconds + this item's estimated
                    cost on that replica, cold-start compile term
                    included. Sees both cost heterogeneity and warm-cache
                    affinity, so it lands hot shapes on replicas that
                    already compiled them unless the queue gap says
                    otherwise.
    affinity     -- tenant-sticky (session affinity): each tenant pins to
                    one replica via rendezvous (highest-random-weight)
                    hashing over the live replica ids, which maximizes
                    warm-cache reuse and per-tenant ordering, spilling
                    JSQ-style only when the pinned replica's queue is
                    badly out of line. The D-STACK-ish "keep a tenant's
                    state where it is" play — and because the pin is a
                    pure function of (tenant, replica id), an autoscale
                    event only remaps the tenants whose winning replica
                    actually appeared or vanished, not the whole fleet
                    (the old ``t mod N`` pinning remapped ~everyone on
                    every change of N, flushing every warm cache at once).

``route`` receives the list of ``ReplicaPump``s (``repro.sim.simulator``)
— the routing signals are methods on the pump: ``queue_depth()``,
``backlog_s(now)``, ``estimate_item_s(w)``.
"""

from __future__ import annotations

import math
from typing import Sequence

ROUTERS = ("round_robin", "jsq", "least_cost", "affinity")


class Router:
    """Chooses a replica for each arrival; stateful but deterministic."""

    name: str = "base"

    def route(self, w, replicas: Sequence, now: float) -> int:
        """Return the index in ``replicas`` this workload is routed to."""
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Cycle through replicas regardless of state."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def route(self, w, replicas, now) -> int:
        # mod at route time, not store time: the replica count is elastic
        # under autoscaling, and a stored index can outlive a scale-down
        idx = self._next % len(replicas)
        self._next = idx + 1
        return idx


class JoinShortestQueueRouter(Router):
    """Fewest pending + in-flight items wins; ties rotate round-robin.

    The rotating tie-break matters: always breaking to the lowest index
    herds every arrival that lands on an all-idle fleet onto replica 0,
    which concentrates micro-bursts and loses to plain round-robin. With
    rotation, JSQ degenerates to round-robin exactly when queues are even
    and only deviates when there is real imbalance to correct.
    """

    name = "jsq"

    def __init__(self) -> None:
        self._rr = 0

    def route(self, w, replicas, now) -> int:
        depths = [r.queue_depth(now) for r in replicas]
        shortest = min(depths)
        ties = [i for i, d in enumerate(depths) if d == shortest]
        idx = ties[self._rr % len(ties)]
        self._rr += 1
        return idx


class LeastEstimatedCostRouter(Router):
    """Least estimated finish time for THIS item: replica backlog seconds
    plus the item's estimated dispatch cost there (compile term included
    when the replica is cold for the item's bucket)."""

    name = "least_cost"

    def route(self, w, replicas, now) -> int:
        return min(
            range(len(replicas)),
            key=lambda i: (replicas[i].backlog_s(now)
                           + replicas[i].estimate_item_s(w), i),
        )


_HASH_MASK = (1 << 64) - 1


def _hrw_weight(tenant_id: int, replica_id: int) -> int:
    """Deterministic 64-bit mix of (tenant, replica) — the rendezvous
    score. splitmix64 finalizer over a golden-ratio combine: stable
    across runs, Python versions, and platforms (``hash()`` is not)."""
    x = (tenant_id * 0x9E3779B97F4A7C15
         + replica_id * 0xBF58476D1CE4E5B9 + 1) & _HASH_MASK
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _HASH_MASK
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _HASH_MASK
    return x ^ (x >> 31)


class TenantAffinityRouter(Router):
    """Session-sticky via weighted rendezvous hashing: tenant t pins to
    the live replica with the best capacity-weighted
    ``_hrw_weight(t, replica_id)`` score (maximal warm-cache reuse,
    minimal remapping when the replica set changes, and faster chips win
    proportionally more tenants on heterogeneous fleets), spilling to the
    shortest queue only when the pinned replica's queue exceeds
    ``spill_factor`` x the fleet's shortest queue (plus a small absolute
    grace so near-empty fleets never spill)."""

    name = "affinity"

    def __init__(self, spill_factor: float = 4.0, spill_grace: int = 8):
        if spill_factor < 1.0:
            raise ValueError("spill_factor must be >= 1")
        self.spill_factor = spill_factor
        self.spill_grace = spill_grace

    @staticmethod
    def pin(w, replicas) -> int:
        """Index of the tenant's rendezvous winner among ``replicas``.

        Keyed on each replica's stable ``replica_id`` (falling back to its
        position for bare sequences), so the pin survives the list
        reshuffling that scale events cause — only tenants whose winner
        joined or left the fleet move. Weighted a la Hash-Rendezvous-
        Weighted (``log(u)/capacity``): a replica advertising
        ``speed_factor`` 2.0 wins ~2x the tenants of a 1.0 replica, with
        equal speeds reducing to plain rendezvous hashing."""
        t = w.tenant_id

        def score(i: int) -> float:
            r = replicas[i]
            rid = getattr(r, "replica_id", None)
            u = (_hrw_weight(t, i if rid is None else rid) + 1) \
                / float(1 << 64)  # uniform draw in (0, 1]
            speed = getattr(r, "speed_factor", 1.0) or 1.0
            return math.log(u) / speed

        return max(range(len(replicas)), key=score)

    def route(self, w, replicas, now) -> int:
        pinned = self.pin(w, replicas)
        depth = replicas[pinned].queue_depth(now)
        shortest = min(range(len(replicas)),
                       key=lambda i: (replicas[i].queue_depth(now), i))
        if depth > self.spill_grace + self.spill_factor * \
                replicas[shortest].queue_depth(now):
            return shortest
        return pinned


def make_router(name: str, **kwargs) -> Router:
    """Name-keyed router factory (the CLI surface of this module)."""
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "jsq":
        return JoinShortestQueueRouter()
    if name == "least_cost":
        return LeastEstimatedCostRouter()
    if name == "affinity":
        return TenantAffinityRouter(**kwargs)
    raise ValueError(f"unknown router: {name!r} (have {ROUTERS})")
