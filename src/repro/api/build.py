"""Spec -> executor assembly: the imperative half of the API.

These builders translate each declarative sub-spec into the subsystem
object it wraps — trace generators from ``WorkloadSpec``, cost models
from ``CostModelSpec``, ``ScheduleConfig`` from ``SchedulerSpec`` — and
the three executors (``SimRun`` / ``FleetRun`` / ``LiveRun``) drive the
solo simulator, the fleet simulator, and the live engine fleet behind
one ``run() -> RunReport`` surface.

Construction happens per ``run()`` call, not per executor: cost models
and routers are stateful (compile caches, EWMA tables, cursors), so each
run starts from a fresh assembly and the determinism contract (same spec
+ same seed => byte-identical metrics JSON) holds across repeated runs
of one executor object.

The benchmark sweeps are thin callers of this module: they build a base
``SystemSpec``, ``replace()`` per grid cell, and call ``run_metrics()``
for the raw ``SimMetrics``/``FleetMetrics`` their BENCH exports freeze.
"""

from __future__ import annotations

import os
import time
from typing import Callable, List, Optional, Sequence

from repro.api.report import RunReport
from repro.api.spec import (
    CostModelSpec,
    SystemSpec,
    WorkloadSpec,
)
from repro.config import ScheduleConfig
from repro.launch.roofline import resolve_spec
from repro.sim.costmodel import (
    CalibratedCostModel,
    ColdStartCostModel,
    FleetCalibrator,
    RooflineCostModel,
    estimate_capacity_hz,
)
from repro.sim.fleet import FleetSimulator, fleet_capacity_hz
from repro.sim.simulator import Simulator
from repro.sim.traces import (
    CsvReplayTrace,
    TenantSpec,
    fleet_sgemm_mix,
    make_trace,
    paper_sgemm_mix,
    prefill_decode_mix,
)


# ------------------------------------------------------------ mix / trace
def build_mix(workload: WorkloadSpec) -> List[TenantSpec]:
    """Tenant mix named by ``WorkloadSpec.mix`` (repro.sim.traces)."""
    if workload.mix == "sgemm":
        return paper_sgemm_mix(workload.tenants)
    if workload.mix == "fleet":
        return fleet_sgemm_mix(workload.tenants, zipf_a=workload.zipf_a)
    if workload.mix == "serving":
        return prefill_decode_mix(workload.tenants)
    if workload.mix == "single":
        return single_shape_mix(workload.tenants, workload.slo_s)
    raise ValueError(f"unknown mix {workload.mix!r}")  # unreachable post-init


def single_shape_mix(tenants: int, slo_s: float) -> List[TenantSpec]:
    """All tenants launch the paper's ResNet-18 conv2_2 SGEMM geometry
    under one SLO — the historical ``dynamic_trace`` setting."""
    from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES
    from repro.core.queue import ShapeBucket

    g = PAPER_GEMM_SHAPES["resnet18_conv2_2"]
    bucket = ShapeBucket("gemm", g.M, g.K, g.N, "float32")
    return [
        TenantSpec(
            tenant_id=t, name=f"t{t}/{g.name}", bucket=bucket,
            cost=float(g.flops), flops=float(g.flops),
            bytes=float(4 * (g.M * g.K + g.K * g.N + g.M * g.N)),
            slo_s=slo_s, kind="kernel",
        )
        for t in range(tenants)
    ]


def resolve_rate_hz(spec: SystemSpec, mix: Sequence[TenantSpec]) -> float:
    """Absolute offered arrivals/s for the spec's workload.

    ``rate_hz`` passes through; ``rho`` is anchored to the configured
    fleet's aggregate space_time capacity — per-replica rooflines summed
    for heterogeneous fleets, N x the solo capacity otherwise, with an
    elastic fleet anchored at its autoscaler's maximum (the capacity it
    can grow into). That anchoring is what makes one rho mean the same
    pressure for any mix or fleet shape.
    """
    w = spec.workload
    if w.rate_hz is not None:
        return w.rate_hz
    cost = spec.cost_model
    n = spec.fleet.max_replicas
    # capacity is priced at one representative merged dispatch round, so
    # the merge width must be the scheduler's actual cap — anchoring a
    # wide-merge spec at the default width would understate what the
    # scheduler can reach
    merge = (spec.scheduler.max_superkernel_size if spec.scheduler
             else 32)
    if spec.fleet.specs is not None:
        cycled = [spec.fleet.specs[i % len(spec.fleet.specs)] for i in range(n)]
        return w.rho * fleet_capacity_hz(mix, cycled, merge_size=merge)
    return w.rho * n * estimate_capacity_hz(
        mix, RooflineCostModel(
            spec=resolve_spec(cost.hardware), strategy="space_time",
            small_kernel_efficiency=cost.small_kernel_efficiency),
        merge_size=merge)


def build_trace(spec: SystemSpec, mix: Sequence[TenantSpec]):
    """Seeded arrival trace for the spec's workload (re-iterable)."""
    w = spec.workload
    if w.process == "replay":
        return CsvReplayTrace(mix, w.csv_path)
    return make_trace(w.process, mix, resolve_rate_hz(spec, mix), w.events,
                      seed=w.seed)


# --------------------------------------------------------------- cost model
def build_cost_model(cost: CostModelSpec) -> Callable[[Sequence], float]:
    """Base (roofline or calibrated-over-roofline) pricing model.

    Cold-start wrapping (``compile_us``) is the executors' job — compile
    caches are per-replica state, so the fleet wraps one instance per
    replica while the solo simulator wraps exactly one.
    """
    prior = RooflineCostModel(
        spec=resolve_spec(cost.hardware), strategy=cost.strategy,
        small_kernel_efficiency=cost.small_kernel_efficiency)
    if cost.kind == "roofline":
        return prior
    try:
        # spec-level prior_strength > 0 wins; 0 (the default) defers to
        # whatever the saved table carries
        return CalibratedCostModel.load(
            cost.calibration_path, prior=prior,
            prior_strength=(cost.prior_strength
                            if cost.prior_strength > 0 else None))
    except FileNotFoundError:
        raise ValueError(
            f"calibration table not found: {cost.calibration_path!r} "
            f"(fit one with `python -m repro calibrate --spec ... --out "
            f"{cost.calibration_path}` or a live dynamic_trace "
            f"--calibrate run)") from None


def build_fleet_calibration(cost: CostModelSpec) -> Optional[FleetCalibrator]:
    """Per-replica measured-cost tables when the spec asks for them.

    Returns None unless ``fleet_calibration_path`` is set. An existing
    table file is LOADED (fresh replicas start from persisted EWMAs
    instead of cold ones); otherwise a fresh ``FleetCalibrator`` starts
    from the roofline prior. Persisting the fitted tables back is the
    LIVE executor's job — sim runs never write, so the byte-identical
    rerun contract cannot depend on how many times a spec has run.
    """
    if cost.fleet_calibration_path is None:
        return None
    prior = RooflineCostModel(
        spec=resolve_spec(cost.hardware), strategy=cost.strategy,
        small_kernel_efficiency=cost.small_kernel_efficiency)
    if os.path.exists(cost.fleet_calibration_path):
        return FleetCalibrator.load(cost.fleet_calibration_path, prior=prior)
    return FleetCalibrator(prior=prior, ewma_alpha=cost.ewma_alpha)


def build_schedule(spec: SystemSpec) -> Optional[ScheduleConfig]:
    return spec.scheduler.to_schedule_config() if spec.scheduler else None


# ---------------------------------------------------------------- partition
def build_partition(spec: SystemSpec, mix: Sequence[TenantSpec]):
    """``(plan, replanner)`` for a partitioned spec, ``(None, None)``
    otherwise.

    ``policy="explicit"`` maps ``shares`` verbatim to slices named
    ``p0..pN`` with tenants dealt round-robin. ``policy="knee"`` runs the
    deterministic planner (``repro.partition.planner``) over the mix —
    priced from the calibrated table when the spec's cost model is
    calibrated, the roofline otherwise. The returned ``replanner`` maps
    ``{group: observed_R} -> PartitionPlan`` and backs mid-run
    re-planning (``replan_interval_s > 0``).
    """
    p = spec.partition
    if p is None:
        return None, None
    from repro.partition import (
        DEFAULT_SHARE_GRID,
        PartitionPlan,
        PartitionShare,
        PlannerConfig,
        plan_partitions,
    )

    cost = spec.cost_model
    if p.policy == "explicit":
        shares = p.shares
        g = len(shares)
        plan = PartitionPlan(groups=tuple(
            PartitionShare(
                name=f"p{i}", share=s,
                tenants=tuple(t for t in range(spec.workload.tenants)
                              if t % g == i))
            for i, s in enumerate(shares)))
        return plan, None

    schedule = build_schedule(spec) or ScheduleConfig()
    cfg = PlannerConfig(
        share_grid=p.share_grid or DEFAULT_SHARE_GRID,
        knee_fraction=p.knee_fraction,
        min_share=p.min_share,
        base_window_s=schedule.batching_window_s,
        slack_fraction=p.slack_fraction,
        merge_size=schedule.max_superkernel_size,
        strategy=cost.strategy,
        small_kernel_efficiency=cost.small_kernel_efficiency,
    )
    hardware = resolve_spec(cost.hardware)
    model = build_cost_model(cost)
    calibrated = model if isinstance(model, CalibratedCostModel) else None

    def replanner(r_override):
        return plan_partitions(mix, hardware, cfg, calibrated=calibrated,
                               r_override=r_override)

    return replanner(None), replanner


# ------------------------------------------------------------ observability
def build_recorder(spec: SystemSpec):
    """A fresh ``FlightRecorder`` when the spec enables observability,
    else None (the executors thread None through and every hot path pays
    one is-None test)."""
    obs = spec.observability
    if not obs.enabled:
        return None
    from repro.obs.recorder import FlightRecorder

    return FlightRecorder(per_request=obs.per_request)


def scheduler_counters(m) -> dict:
    """``SchedulerStats`` surfaced as a diffable dict (the counters
    ``scheduler.report()`` buries inside the executor)."""
    return {
        "busy_time_s": float(m.busy_time_s),
        "completed": float(m.completed),
        "dispatches": float(m.dispatches),
        "evicted_tenants": float(m.evicted_tenants),
        "rejected": float(m.rejected),
        "ripe_nudges": float(m.ripe_nudges),
        "deadline_rejected": float(m.deadline_rejected),
        "oversubscribed": float(m.oversubscribed),
        "preemptions": float(m.preemptions),
        "total_cost": float(m.cost.sum()),
    }


def _augment_metrics(spec: SystemSpec, metrics_doc: dict, m,
                     recorder) -> dict:
    """Report-layer additions on top of the frozen metrics dict: the
    scheduler-counter section always, windowed telemetry + trace export
    when the recorder ran. The metrics dict itself (``to_dict()``) is
    untouched — recorder-off metrics JSON stays byte-identical to
    pre-recorder builds."""
    merged = getattr(m, "merged", m)
    counters = scheduler_counters(merged)
    per_rep = getattr(m, "per_replica", None)
    if per_rep is not None:
        counters["per_replica_ripe_nudges"] = [
            float(r.ripe_nudges) for r in per_rep]
    metrics_doc["scheduler"] = counters
    if recorder is not None:
        from repro.obs.telemetry import windowed_series
        from repro.obs.trace_export import export_chrome_trace

        obs = spec.observability
        metrics_doc["telemetry"] = windowed_series(recorder, obs.window_s)
        if obs.trace_path:
            with open(obs.trace_path, "w") as fh:
                fh.write(export_chrome_trace(recorder) + "\n")
    return metrics_doc


# ---------------------------------------------------------------- executors
class SimRun:
    """Solo executor: one replica of the real scheduler on a virtual
    clock (``repro.sim.simulator.Simulator``)."""

    executor = "simulator"

    def __init__(self, spec: SystemSpec):
        self.spec = spec
        # the flight recorder of the most recent run_metrics() call —
        # the CLI trace surface exports from it after the run
        self.last_recorder = None

    def run_metrics(self):
        """Fresh assembly, one trace, raw ``SimMetrics``."""
        spec = self.spec
        mix = build_mix(spec.workload)
        trace = build_trace(spec, mix)
        model = build_cost_model(spec.cost_model)
        rec = build_recorder(spec)
        sim = Simulator(schedule=build_schedule(spec), cost_model=model,
                        recorder=rec)
        if spec.cost_model.compile_us > 0.0:
            # before sim.run(): the recorder attaches lazily there and
            # its dispatch tap must see the cold-start wrapper
            cold = ColdStartCostModel(
                model, compile_s=spec.cost_model.compile_us * 1e-6,
                clock=sim.clock)
            sim.pump.cost_model = cold
            sim.scheduler.cost_model = cold
        metrics = sim.run(trace)
        self.last_recorder = rec
        return metrics

    def run(self) -> RunReport:
        m = self.run_metrics()
        doc = _augment_metrics(self.spec, m.to_dict(), m,
                               self.last_recorder)
        return RunReport(executor=self.executor, mode=self.spec.mode,
                         spec=self.spec.to_dict(), metrics=doc)


class FleetRun:
    """Fleet executor: N replicas behind a router, optionally
    heterogeneous and elastic (``repro.sim.fleet.FleetSimulator``)."""

    executor = "fleet"

    def __init__(self, spec: SystemSpec):
        self.spec = spec
        self.last_recorder = None

    def run_metrics(self):
        """Fresh fleet, one trace, raw ``FleetMetrics``."""
        spec = self.spec
        fleet, cost = spec.fleet, spec.cost_model
        mix = build_mix(spec.workload)
        trace = build_trace(spec, mix)
        rec = build_recorder(spec)
        plan, replanner = build_partition(spec, mix)
        sim = FleetSimulator(
            replicas=fleet.replicas,
            router=spec.router.policy,
            schedule=build_schedule(spec),
            cost_model=(None if (fleet.specs or plan is not None)
                        else build_cost_model(cost)),
            compile_s=cost.compile_us * 1e-6,
            specs=list(fleet.specs) if fleet.specs else None,
            strategy=cost.strategy,
            autoscaler=fleet.autoscale.build() if fleet.autoscale else None,
            calibration=build_fleet_calibration(cost),
            workers=fleet.workers,
            recorder=rec,
            partition=plan,
            partition_hardware=(resolve_spec(cost.hardware)
                                if plan is not None else None),
            small_kernel_efficiency=cost.small_kernel_efficiency,
            replanner=replanner,
            replan_interval_s=(spec.partition.replan_interval_s
                               if spec.partition else 0.0),
        )
        metrics = sim.run(trace)
        self.last_recorder = rec
        return metrics

    def run(self) -> RunReport:
        m = self.run_metrics()
        doc = _augment_metrics(self.spec, m.to_dict(), m,
                               self.last_recorder)
        return RunReport(executor=self.executor, mode=self.spec.mode,
                         spec=self.spec.to_dict(), metrics=doc)


class LiveRun:
    """Live executor: N real engines behind the simulator's routing layer
    (``repro.serving.fleet.LiveFleet``) — the same pump/router/admission
    core the fleet simulator runs, on the wall clock, executing real work.

    ``workload.arch`` picks the engine. The jax-free pseudo-archs
    ``"fake"`` (deterministic tokens) and ``"null"`` (no results — the
    sim-parity twin) serve CI and any CPU; every other name builds one
    real jitted ``MultiTenantEngine`` per replica over SHARED
    smoke-variant weights (N replicas space-multiplexing one host is the
    paper's story told at the cluster layer). jax imports happen at
    ``run()`` time so spec validation and sim-only workflows never pay
    them.

    Wall-clock latencies are real, so live reports are NOT covered by
    the byte-identical determinism contract — routing decisions,
    admission counters and (fake-engine) token streams are
    deterministic, latencies are not.
    """

    executor = "live"

    def __init__(self, spec: SystemSpec):
        self.spec = spec
        self.last_recorder = None
        # the fleet of the most recent run_metrics() call — the serving
        # loop keeps it alive to submit requests against
        self.last_fleet = None
        self.engine_name = None
        self.wall_s = 0.0

    def build_engine_factory(self):
        """``(engine_factory, engine_name, vocab)`` for ``workload.arch``.

        Only the real-arch branch imports jax; "fake"/"null" stay pure
        python so the live fleet path runs anywhere.
        """
        w = self.spec.workload
        if w.arch == "null":
            from repro.serving.fleet import NullEngine

            return NullEngine, "null", 32_000
        if w.arch == "fake":
            from repro.serving.fleet import FakeEngine

            return (lambda i: FakeEngine(i, max_new_tokens=w.max_new_tokens),
                    "fake", 32_000)

        import dataclasses as _dc

        import jax

        from repro.config import get_config, smoke_variant
        from repro.models import build_model
        from repro.serving import EngineConfig, MultiTenantEngine
        from repro.serving.fleet import EngineReplica

        spec = self.spec
        cfg = _dc.replace(smoke_variant(get_config(w.arch)), dtype="float32")
        model = build_model(cfg)
        key = jax.random.PRNGKey(w.seed)
        params = [model.init(jax.random.fold_in(key, t))
                  for t in range(w.tenants)]
        # the engine's contrast mode mirrors the cost-model strategy:
        # time_only gives each tenant its own bucket (sequential
        # dispatch), everything else rides the merged space-time path
        engine_mode = ("time_only" if spec.cost_model.strategy == "time_only"
                       else "space_time")
        schedule = build_schedule(spec)

        def factory(i: int) -> EngineReplica:
            engine = MultiTenantEngine(model, params, EngineConfig(
                num_tenants=w.tenants,
                slots_per_tenant=2,
                cache_len=max(32, w.prompt_tokens + w.max_new_tokens + 8),
                mode=engine_mode,
                seed=w.seed + i,
                schedule=schedule,
            ))
            return EngineReplica(engine, replica_id=i,
                                 max_new_tokens=w.max_new_tokens)

        return factory, "jax", cfg.vocab_size

    def build_fleet(self, recorder=None):
        """Assemble a fresh ``LiveFleet`` (engines included) for this
        spec — shared by ``run_metrics`` and the HTTP serving loop."""
        from repro.serving.fleet import LiveFleet

        spec = self.spec
        fleet_spec, cost = spec.fleet, spec.cost_model
        factory, engine_name, vocab = self.build_engine_factory()
        self.engine_name = engine_name
        calibration = build_fleet_calibration(cost)
        fleet = LiveFleet(
            replicas=fleet_spec.replicas,
            engine_factory=factory,
            router=spec.router.policy,
            schedule=build_schedule(spec),
            cost_model=None if fleet_spec.specs else build_cost_model(cost),
            compile_s=cost.compile_us * 1e-6,
            specs=list(fleet_spec.specs) if fleet_spec.specs else None,
            strategy=cost.strategy,
            calibration=calibration,
            recorder=recorder,
        )
        return fleet, vocab

    def save_calibration(self, fleet) -> None:
        """Persist the fleet's fitted per-replica tables (live runs only
        — the next run, or a sim pricing the same path, starts warm)."""
        path = self.spec.cost_model.fleet_calibration_path
        if path and fleet.calibration is not None:
            fleet.calibration.save(path)

    def run_metrics(self):
        """Fresh fleet over real engines, one trace, raw ``FleetMetrics``."""
        import numpy as np

        spec = self.spec
        w = spec.workload
        mix = build_mix(w)
        trace = build_trace(spec, mix)
        rec = build_recorder(spec)
        fleet, vocab = self.build_fleet(recorder=rec)
        rng = np.random.RandomState(w.seed)

        def payload_fn(tspec):
            return rng.randint(1, vocab, size=w.prompt_tokens).tolist()

        t0 = time.perf_counter()
        metrics = fleet.run(trace, payload_fn=payload_fn)
        self.wall_s = time.perf_counter() - t0
        self.save_calibration(fleet)
        self.last_recorder = rec
        self.last_fleet = fleet
        return metrics

    def run(self) -> RunReport:
        m = self.run_metrics()
        doc = _augment_metrics(self.spec, m.to_dict(), m,
                               self.last_recorder)
        # live extras on top of the shared FleetMetrics schema
        doc["arch"] = self.spec.workload.arch
        doc["engine"] = self.engine_name
        doc["wall_s"] = self.wall_s
        return RunReport(executor=self.executor, mode=self.spec.mode,
                         spec=self.spec.to_dict(), metrics=doc)
