"""repro.api — the declarative front door over every execution path.

One composable, JSON-round-trippable ``SystemSpec`` describes a complete
experiment; ``build()`` assembles the right executor (solo ``Simulator``,
``FleetSimulator``, or live ``MultiTenantEngine``) and every executor
returns the same ``RunReport``. ``python -m repro`` exposes the same
surface as a CLI (simulate / sweep / calibrate / check / specs); the
``benchmarks/`` sweeps are thin callers of this package.
"""

from repro.api.build import (  # noqa: F401
    FleetRun,
    LiveRun,
    SimRun,
    build_cost_model,
    build_mix,
    build_partition,
    build_schedule,
    build_trace,
    resolve_rate_hz,
    single_shape_mix,
)
from repro.api.report import RunReport  # noqa: F401
from repro.api.spec import (  # noqa: F401
    AUTOSCALERS,
    COST_KINDS,
    MIXES,
    MODES,
    PARTITION_POLICIES,
    PROCESSES,
    AutoscaleSpec,
    CostModelSpec,
    FleetSpec,
    ObservabilitySpec,
    PartitionSpec,
    RouterSpec,
    SchedulerSpec,
    SystemSpec,
    WorkloadSpec,
)
from repro.sim.metrics import SCHEMA_VERSION  # noqa: F401
