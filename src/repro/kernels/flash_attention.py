"""Blockwise online-softmax (flash) attention, causal + sliding-window.

Used for prefill. GQA is handled by indexing the KV head as
``q_head // q_per_kv`` inside the BlockSpec index maps, so K/V blocks are
fetched once per KV head and reused by its query-head group as the grid
walks query heads.

Grid: (B * Hq, Sq/bq, Skv/bkv) with the KV axis innermost; running max /
denominator / accumulator live in VMEM scratch across the KV steps of one
(bh, iq) tile. Sliding-window layers additionally mask positions further
than ``window`` behind the query (gemma3 local layers).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BKV = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int, q_offset: int,
    bq: int, bkv: int, kv_len: int,
):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, D)
    k = k_ref[0]  # (bkv, D)
    v = v_ref[0]  # (bkv, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bkv)

    # global positions for masking
    iq = pl.program_id(1)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + q_offset
    kv_pos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kv_pos < kv_len  # drop zero-padded keys
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked tiles: rows where m_new is still NEG_INF contribute 0
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _store():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bkv", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    bq: int = DEFAULT_BQ,
    bkv: int = DEFAULT_BKV,
    interpret: bool = False,
) -> jax.Array:
    """Multi-head attention with O(S) memory.

    Args:
        q: (B, Hq, Sq, D)
        k: (B, Hkv, Skv, D) -- Hq % Hkv == 0 (GQA)
        v: (B, Hkv, Skv, D)
        window: sliding-window size (0 = unbounded / full attention).
    Returns:
        (B, Hq, Sq, D)
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"GQA mismatch Hq={Hq} Hkv={Hkv}")
    q_per_kv = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    q_offset = Skv - Sq  # causal alignment when queries are a suffix

    bq_ = min(bq, Sq)
    bkv_ = min(bkv, Skv)
    Sqp = pl.cdiv(Sq, bq_) * bq_
    Skvp = pl.cdiv(Skv, bkv_) * bkv_
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        # padded kv positions are masked out by causal/window iff their
        # positions exceed every query position; enforce via causal mask on
        # padded region: kv_pos >= Skv is > every real q_pos + q_offset only
        # when causal. For non-causal, rely on explicit valid mask below.

    qf = q.reshape(B * Hq, Sqp, D)
    kf = k.reshape(B * Hkv, Skvp, D)
    vf = v.reshape(B * Hkv, Skvp, D)

    grid = (B * Hq, Sqp // bq_, Skvp // bkv_)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        bq=bq_,
        bkv=bkv_,
        kv_len=Skv,
    )

    def kv_index(bh, iq, jk):
        return (bh // q_per_kv, jk, 0)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, D), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bkv_, D), kv_index),
            pl.BlockSpec((1, bkv_, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq_, D), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sqp, D)[:, :, :Sq, :]
