"""Sharded fleet execution: replica pumps partitioned across processes.

Round-robin routing makes replicas independent: arrival ``j`` goes to
replica ``j mod N`` regardless of any replica's state, so each replica's
entire trajectory (admission, batching, dispatch instants, completions)
is a pure function of the trace subsequence it owns. ``run_sharded``
exploits that: it partitions the replica ids across ``workers`` forked
processes, runs every replica to completion independently against its
own slice of the (re-generated, seeded) trace, and merges the
per-replica completion streams back into the exact global absorb order
the single-process event loop would have produced — same seed, same
JSON bytes.

**Merge keys.** Each replica-local absorb is tagged at record time:

* ``(t_j, 0, j)``   — dispatch triggered by submitting global arrival
  ``j`` at trace time ``t_j``;
* ``(tau, 1, rid)`` — dispatch at ripeness instant ``tau`` during a
  drain phase;
* ``(inf, 2, rid)`` — the force-flush fallback at the very end.

The single-process fleet loop interleaves replicas as: drain every
instant strictly before each arrival (earliest instant first, lowest
replica id on ties), then run the submit itself; the tail drains
ascending instants and flushes in replica order. That interleaving is
exactly ascending order of the keys above (drain instants between
consecutive arrivals satisfy ``t_j <= tau < t_{j+1}`` with the phase
bit breaking the ``tau == t_j`` tie the right way), so one sort of the
recorded events reconstructs the global stream — including the merged
accumulator's float-accumulation order and its kind-interning order,
which is why the bytes match rather than just the statistics.

**Why the restrictions.** The independence argument needs routing and
pricing to never read cross-replica state: a fresh ``round_robin``
router (state-oblivious assignment), no autoscaler (scale decisions
read fleet-wide occupancy), no calibration (the shared table couples
replicas through observed dispatches), and a stable-window policy (the
ripeness calendar guarantees instant-pumps dispatch, so the
single-process stall/retry interleaving — which IS cross-replica —
never arises). ``run_sharded`` validates all of these up front and
raises with the fix rather than silently diverging.

Workers prefer the ``fork`` start method (the parent's built fleet and
trace are inherited by reference — nothing is pickled going in; only
the compact per-replica results come back). Where ``fork`` is
unavailable the shards run sequentially in-process: same bytes, no
parallelism.
"""

from __future__ import annotations

import math
import multiprocessing
import queue as queue_mod
import traceback
from typing import Dict, Iterator, List, Tuple

import numpy as np

from repro.sim.metrics import FleetMetrics, MetricsAccumulator
from repro.sim.router import RoundRobinRouter
from repro.sim.simulator import SimWorkload
from repro.sim.traces import Trace

_FLUSH_KEY = math.inf


def _validate(fleet, trace) -> None:
    """Reject configurations whose replicas are not provably independent."""
    if not isinstance(fleet.router, RoundRobinRouter):
        raise ValueError(
            f"workers>1 requires the 'round_robin' router — state-oblivious "
            f"assignment is what makes replicas independent; got "
            f"{fleet.router.name!r}. Use workers=1 for state-aware routing.")
    if fleet.router._next != 0:
        raise ValueError(
            "workers>1 needs a FRESH round-robin router (no prior route() "
            "calls); build a new FleetSimulator per run")
    if fleet.autoscaler is not None:
        raise ValueError(
            "workers>1 is incompatible with autoscaling: scale decisions "
            "read fleet-wide replica state. Use workers=1.")
    if fleet.calibration is not None:
        raise ValueError(
            "workers>1 is incompatible with fleet calibration: the shared "
            "table couples replicas through observed dispatches. "
            "Use workers=1.")
    if not fleet.pumps or not getattr(
            fleet.pumps[0].scheduler.policy, "stable_window", False):
        raise ValueError(
            "workers>1 requires a stable-window batching policy "
            "(policy='fixed'): slack-adaptive and deadline-aware policies "
            "need the merged single-process timeline. Use workers=1.")
    sched0 = fleet.pumps[0].scheduler.schedule
    if sched0.admission_policy != "cap":
        raise ValueError(
            "workers>1 requires admission_policy='cap': feasibility "
            "admission prices against a replica's committed horizon, which "
            "the stalled-replica interleaving couples across shards. "
            "Use workers=1.")
    if not isinstance(trace, Trace):
        raise ValueError(
            "workers>1 needs a re-iterable Trace (each worker regenerates "
            f"its shard from the seed); got {type(trace).__name__}. "
            "Use workers=1 for ad-hoc arrival iterables.")


def _owned_arrivals(trace: Trace, rid: int,
                    n_replicas: int) -> Iterator[Tuple[int, float, object, float]]:
    """Yield ``(j, t_s, spec, cost)`` for the arrivals round-robin routes
    to replica ``rid`` — the strided slice ``j % N == rid`` of the chunked
    columns, without materializing the other replicas' events."""
    offset = 0
    for times, idx, costs, table in trace.iter_chunks():
        n = len(times)
        k0 = (rid - offset) % n_replicas
        if k0 < n:
            ts = times[k0::n_replicas].tolist()
            ii = idx[k0::n_replicas].tolist()
            cs = costs[k0::n_replicas].tolist()
            for k, t, i, c in zip(range(offset + k0, offset + n, n_replicas),
                                  ts, ii, cs):
                yield k, t, table[i], c
        offset += n


def _run_replica(pump, rid: int, trace: Trace, n_replicas: int) -> Dict:
    """Drive one replica over its owned arrivals exactly as the merged
    loop would (drain instants strictly before each arrival, submit,
    drain-then-flush tail), recording a merge key per absorb."""
    acc = MetricsAccumulator()
    pump.accs = [acc]
    events: List[Tuple[float, int, int, int]] = []  # (t, phase, tiebreak, n)
    # (j, t, tenant) per owned arrival when recording — the merge replays
    # these in global arrival order to rebuild the fleet route timeline
    routes: List[Tuple[int, float, int]] = []
    recording = pump.recorder is not None
    routed = 0
    next_ripe = pump.next_ripe_time
    pump_at = pump.pump_at
    submit = pump.submit
    estimate = pump.estimate_item_s

    for j, t, spec, cost in _owned_arrivals(trace, rid, n_replicas):
        if recording:
            routes.append((j, t, spec.tenant_id))
        while True:
            tau = next_ripe()
            if tau is None or tau >= t:
                break
            done = pump_at(tau)
            if not done:
                break  # stalled until arrivals resume (merged-loop parity)
            events.append((tau, 1, rid, len(done)))
        w = SimWorkload(spec, cost)
        w.est_s = estimate(w)
        before = len(acc)
        if submit(w, t):
            routed += 1
        n_done = len(acc) - before
        if n_done:
            events.append((t, 0, j, n_done))

    sched = pump.scheduler
    while len(sched.queue):
        tau = next_ripe()
        done = pump_at(tau) if tau is not None else []
        if done:
            events.append((tau, 1, rid, len(done)))
        else:
            before = len(acc)
            pump._absorb(sched.flush())
            n_done = len(acc) - before
            if n_done:
                events.append((_FLUSH_KEY, 2, rid, n_done))
            break

    stats = sched.stats
    model = pump.cost_model
    cold_times = getattr(model, "dispatch_times", None)
    cold_flags = getattr(model, "dispatch_cold", None)
    kinds = acc._kinds
    return {
        "rid": rid,
        "events": events,
        "lat": acc._lat, "slo": acc._slo, "cost": acc._cost,
        "tenant": acc._tenant, "kind_idx": acc._kind_idx,
        "kinds": [k for k, _ in sorted(kinds.items(), key=lambda kv: kv[1])],
        "busy": stats.busy_time_s,
        "dispatches": stats.dispatches,
        "rejected": stats.rejected,
        "evicted": len(sched.evicted),
        "clock_end": pump.clock.now(),
        "routed": routed,
        "spec_name": pump.spec_name,
        "cold_times": cold_times,
        "cold_flags": cold_flags,
        "ripe_nudges": stats.ripe_nudges,
        "deadline_rejected": stats.deadline_rejected,
        "oversubscribed": stats.oversubscribed,
        "preemptions": stats.preemptions,
        "obs": pump.recorder.payload() if recording else None,
        "routes": routes,
    }


def _worker_main(fleet, trace, rids, n_replicas, wid, out_q) -> None:
    try:
        res = [_run_replica(fleet.pumps[rid], rid, trace, n_replicas)
               for rid in rids]
        out_q.put((wid, "ok", res))
    except BaseException:
        out_q.put((wid, "err", traceback.format_exc()))


def _collect(procs, out_q) -> List[Dict]:
    results: List[Dict] = []
    got: set = set()
    while len(got) < len(procs):
        try:
            wid, tag, payload = out_q.get(timeout=1.0)
        except queue_mod.Empty:
            dead = [p for i, p in enumerate(procs)
                    if i not in got and not p.is_alive()
                    and p.exitcode not in (0, None)]
            if dead:
                raise RuntimeError(
                    f"shard worker died without reporting "
                    f"(exitcode {dead[0].exitcode})")
            continue
        if tag == "err":
            for p in procs:
                if p.is_alive():
                    p.terminate()
            raise RuntimeError(f"shard worker {wid} failed:\n{payload}")
        got.add(wid)
        results.extend(payload)
    for p in procs:
        p.join()
    return results


def _merge(fleet, shards: List[Dict], t_start: float) -> FleetMetrics:
    """Rebuild the single-process ``FleetMetrics`` from per-replica
    shard payloads: per-replica sections verbatim, the merged section by
    replaying absorbs in sorted merge-key order (so float accumulation
    and kind interning match the merged accumulator byte-for-byte)."""
    shards = sorted(shards, key=lambda s: s["rid"])
    horizon = max((s["clock_end"] for s in shards if s["dispatches"] > 0),
                  default=t_start) - t_start

    per_replica = []
    for s in shards:
        acc = MetricsAccumulator()
        acc._lat, acc._slo, acc._cost = s["lat"], s["slo"], s["cost"]
        acc._tenant, acc._kind_idx = s["tenant"], s["kind_idx"]
        acc._kinds = {k: i for i, k in enumerate(s["kinds"])}
        per_replica.append(acc.freeze(
            sim_duration_s=horizon, busy_time_s=s["busy"],
            dispatches=s["dispatches"], rejected=s["rejected"],
            evicted_tenants=s["evicted"],
            ripe_nudges=s["ripe_nudges"],
            deadline_rejected=s["deadline_rejected"],
            oversubscribed=s["oversubscribed"],
            preemptions=s["preemptions"]))

    merged = MetricsAccumulator()
    mkinds = merged._kinds
    evs: List[Tuple[float, int, int, int, int]] = []
    for s in shards:
        rid = s["rid"]
        evs.extend((t, ph, tb, rid, n) for (t, ph, tb, n) in s["events"])
    evs.sort(key=lambda e: (e[0], e[1], e[2]))
    cursors = [0] * len(shards)
    remap: List[Dict[int, int]] = [{} for _ in shards]
    for t, ph, tb, rid, n in evs:
        s = shards[rid]
        i = cursors[rid]
        j = i + n
        cursors[rid] = j
        merged._lat.extend(s["lat"][i:j])
        merged._slo.extend(s["slo"][i:j])
        merged._cost.extend(s["cost"][i:j])
        merged._tenant.extend(s["tenant"][i:j])
        rmap = remap[rid]
        kinds_r = s["kinds"]
        out = []
        for ki in s["kind_idx"][i:j]:
            mi = rmap.get(ki)
            if mi is None:
                name = kinds_r[ki]
                mi = mkinds.get(name)
                if mi is None:
                    mi = len(mkinds)
                    mkinds[name] = mi
                rmap[ki] = mi
            out.append(mi)
        merged._kind_idx.extend(out)
    for s, cur in zip(shards, cursors):
        if cur != len(s["lat"]):
            raise RuntimeError(
                f"shard merge inconsistency: replica {s['rid']} recorded "
                f"{len(s['lat'])} completions but events account for {cur}")

    merged_metrics = merged.freeze(
        sim_duration_s=horizon,
        busy_time_s=sum(s["busy"] for s in shards),
        dispatches=sum(s["dispatches"] for s in shards),
        rejected=sum(s["rejected"] for s in shards),
        evicted_tenants=sum(s["evicted"] for s in shards),
        ripe_nudges=sum(s["ripe_nudges"] for s in shards),
        deadline_rejected=sum(s["deadline_rejected"] for s in shards),
        oversubscribed=sum(s["oversubscribed"] for s in shards),
        preemptions=sum(s["preemptions"] for s in shards),
    )

    if fleet.recorder is not None:
        _merge_recording(fleet.recorder, fleet.router.name, shards)

    times = [np.asarray(s["cold_times"], np.float64) for s in shards
             if s["cold_times"] is not None]
    flags = [np.asarray(s["cold_flags"], np.int64) for s in shards
             if s["cold_flags"] is not None]
    if times:
        t = np.concatenate(times)
        f = np.concatenate(flags)
        order = np.argsort(t, kind="stable")
        cold_times, cold_flags = t[order], f[order]
    else:
        cold_times = np.zeros(0, np.float64)
        cold_flags = np.zeros(0, np.int64)

    routed_counts = [s["routed"] for s in shards]
    fleet.routed_counts = list(routed_counts)
    return FleetMetrics(
        merged=merged_metrics,
        per_replica=per_replica,
        routed_counts=routed_counts,
        router=fleet.router.name,
        cold_times=cold_times,
        cold_flags=cold_flags,
        scale_events=fleet.scale_events,
        replica_specs=[s["spec_name"] for s in shards],
        final_active=len(shards),
    )


def _merge_recording(rec, router_name: str, shards: List[Dict]) -> None:
    """Reassemble the fleet's flight recording from worker payloads:
    per-replica shards verbatim (their trajectories are identical to the
    single-process run), fleet-level route rows replayed in global
    arrival order. Round-robin routing records empty price vectors by
    design, so the replay is byte-equal to live recording; scale events
    cannot occur (sharding forbids autoscaling)."""
    from repro.obs.recorder import ReplicaShard

    for s in shards:
        if s["obs"] is not None:
            rec.shards[s["rid"]] = ReplicaShard.from_payload(s["obs"])
    all_routes: List[Tuple[int, float, int, int]] = []
    for s in shards:
        all_routes.extend((j, t, tenant, s["rid"])
                          for (j, t, tenant) in s["routes"])
    all_routes.sort(key=lambda r: r[0])
    for _, t, tenant, rid in all_routes:
        rec.record_route(t, tenant, rid)
    rec.router_name = router_name


def run_sharded(fleet, trace) -> FleetMetrics:
    """Run ``fleet`` over ``trace`` with its replicas partitioned across
    ``fleet.workers`` processes; returns the same ``FleetMetrics`` (same
    JSON bytes) as the single-process event loop."""
    _validate(fleet, trace)
    n = len(fleet.pumps)
    k = min(fleet.workers, n)
    shards_rids = [[rid for rid in range(n) if rid % k == w] for w in range(k)]

    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:
        ctx = None

    if ctx is None or k == 1:
        results = [_run_replica(fleet.pumps[rid], rid, trace, n)
                   for rid in range(n)]
    else:
        out_q = ctx.Queue()
        procs = [ctx.Process(target=_worker_main,
                             args=(fleet, trace, rids, n, wid, out_q),
                             daemon=True)
                 for wid, rids in enumerate(shards_rids)]
        for p in procs:
            p.start()
        results = _collect(procs, out_q)

    return _merge(fleet, results, fleet.start_s)
