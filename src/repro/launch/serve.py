"""HTTP front door over the live fleet (``python -m repro serve``).

A thin stdlib serving loop — ``ThreadingHTTPServer``, no framework —
fanning requests out over the same ``LiveFleet`` a ``simulate`` run of
the spec would build: real ``DynamicSpaceTimeScheduler`` replicas behind
the sim routers, so capacity planning done in sim transfers to the
deployed shape unchanged.

Endpoints:

    GET  /healthz     liveness + fleet shape (replicas, engine, router)
    POST /v1/predict  {"tenant_id": 0, "prompt": [1,2,3]} — routed,
                      admission-controlled, blocks until the cohort the
                      request merged into completes; 429 with the
                      scheduler's reason code when admission rejects
    GET  /v1/report   the schema-versioned RunReport for traffic so far

Concurrency model: handler threads submit under one fleet lock; a single
pump thread wakes at ``min(next ripeness instant, poll_interval_s)`` and
drives dispatch. Completion is signalled per-request through the pump's
``on_complete`` hook (a ``threading.Event`` on each workload), so a
blocked handler costs one waiting thread, never a spin.

On SIGTERM/SIGINT (or server shutdown) the fleet drains and, when
``report_path`` is set, the final ``RunReport`` JSON lands there — the
serve-smoke CI contract.

    PYTHONPATH=src python -m repro serve --spec examples/specs/serve_smoke.json
"""

from __future__ import annotations

import argparse
import json
import signal
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.api.build import LiveRun, _augment_metrics, build_mix, build_recorder
from repro.api.report import RunReport
from repro.api.spec import ServeSpec

#: scheduler admission codes -> wire names (core.scheduler.admit_reason)
ADMIT_REASONS = {0: "admitted", 1: "oversubscribed", 2: "cap",
                 3: "infeasible"}


class _HttpServer(ThreadingHTTPServer):
    # socketserver's default listen backlog (5) resets connections under
    # concurrent load; predict calls block for a whole cohort, so bursts
    # of pending connects are the normal case here
    request_queue_size = 128
    daemon_threads = True


class FleetServer:
    """One live fleet + pump thread + HTTP server, owned together."""

    def __init__(self, spec: ServeSpec):
        self.spec = spec
        self.run = LiveRun(spec.system)
        self.recorder = build_recorder(spec.system)
        self.fleet, self.vocab = self.run.build_fleet(recorder=self.recorder)
        self.mix = build_mix(spec.system.workload)
        self.lock = threading.Lock()
        self.started_s = time.perf_counter()
        self.requests = 0
        self.rejected = 0
        self._stop = threading.Event()
        self._pump_thread = threading.Thread(
            target=self._pump_loop, name="fleet-pump", daemon=True)
        self.httpd = _HttpServer(
            (spec.host, spec.port), _make_handler(self))
        self.port = self.httpd.server_address[1]

    # ------------------------------------------------------------ serving
    def predict(self, tenant_id: int, prompt, max_new_tokens=None) -> dict:
        """Route one request through the fleet and wait for its cohort."""
        spec = self.mix[tenant_id % len(self.mix)]
        done = threading.Event()
        t0 = time.perf_counter()
        with self.lock:
            self.requests += 1
            w, replica_id, admitted, reason = self.fleet.submit_one(
                spec, cost=spec.cost, payload=list(prompt or ()), done=done)
        if not admitted:
            with self.lock:
                self.rejected += 1
            return {"status": 429,
                    "error": f"admission rejected: "
                             f"{ADMIT_REASONS.get(reason, reason)}",
                    "reason": ADMIT_REASONS.get(reason, str(reason)),
                    "replica": replica_id}
        if not done.wait(self.spec.request_timeout_s):
            return {"status": 504,
                    "error": f"request did not complete within "
                             f"{self.spec.request_timeout_s:g}s",
                    "replica": replica_id}
        return {"status": 200,
                "tenant_id": spec.tenant_id,
                "tokens": w.result,
                "replica": replica_id,
                "latency_s": time.perf_counter() - t0}

    def report(self) -> RunReport:
        """Freeze the traffic served so far into a RunReport."""
        with self.lock:
            horizon = self.fleet.now() - self.fleet.start_s
            m = self.fleet.freeze(horizon)
        doc = _augment_metrics(self.spec.system, m.to_dict(), m,
                               self.recorder)
        doc["arch"] = self.spec.system.workload.arch
        doc["engine"] = self.run.engine_name
        doc["wall_s"] = time.perf_counter() - self.started_s
        doc["http"] = {"requests": self.requests, "rejected": self.rejected}
        return RunReport(executor="serve", mode="live",
                         spec=self.spec.system.to_dict(), metrics=doc)

    # ---------------------------------------------------------- lifecycle
    def _pump_loop(self) -> None:
        interval = self.spec.poll_interval_s
        while not self._stop.is_set():
            with self.lock:
                self.fleet.poll()
                t_next = self.fleet.next_ripe_time()
            now = self.fleet.now()
            delay = interval if t_next is None else max(0.0, t_next - now)
            self._stop.wait(min(delay, interval))

    def start(self) -> None:
        self._pump_thread.start()

    def serve_forever(self) -> None:
        self.start()
        try:
            self.httpd.serve_forever(poll_interval=0.2)
        finally:
            self.shutdown()

    def shutdown(self) -> None:
        """Stop pumping, drain the fleet, persist the final report."""
        if self._stop.is_set():
            return
        self._stop.set()
        if self._pump_thread.is_alive():
            self._pump_thread.join(timeout=5.0)
        with self.lock:
            self.fleet._drain_wall_tail(
                timeout_s=self.spec.request_timeout_s)
            self.run.save_calibration(self.fleet)
        if self.spec.report_path:
            self.report().save(self.spec.report_path)
        self.httpd.server_close()


def _make_handler(server: FleetServer):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):  # stay quiet; CI parses stdout
            pass

        def _send(self, code: int, doc: dict) -> None:
            body = (json.dumps(doc, sort_keys=True) + "\n").encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self) -> None:
            if self.path == "/healthz":
                self._send(200, {
                    "status": "ok",
                    "replicas": len(server.fleet.active),
                    "engine": server.run.engine_name,
                    "router": server.fleet.router.name,
                    "requests": server.requests,
                })
                return
            if self.path == "/v1/report":
                self._send(200, server.report().to_dict())
                return
            self._send(404, {"error": f"no route {self.path!r} (have "
                                      "/healthz, /v1/predict, /v1/report)"})

        def do_POST(self) -> None:
            if self.path != "/v1/predict":
                self._send(404, {"error": f"no route {self.path!r}"})
                return
            try:
                n = int(self.headers.get("Content-Length", 0))
                doc = json.loads(self.rfile.read(n) or b"{}")
                tenant_id = int(doc.get("tenant_id", 0))
                prompt = doc.get("prompt", [])
                if not isinstance(prompt, list):
                    raise ValueError("prompt must be a list of token ids")
            except (ValueError, json.JSONDecodeError) as e:
                self._send(400, {"error": f"bad request: {e}"})
                return
            out = server.predict(tenant_id, prompt)
            self._send(out.pop("status"), out)

    return Handler


def run_server(spec: ServeSpec, ready: Optional[threading.Event] = None,
               ) -> FleetServer:
    """Build the fleet, install signal handlers, serve until stopped."""
    server = FleetServer(spec)
    if threading.current_thread() is threading.main_thread():
        # httpd.shutdown() blocks until serve_forever exits, and the
        # handler runs ON the serve_forever thread — hand it off or the
        # process deadlocks on its own signal
        def stop(signum, frame):
            threading.Thread(target=server.httpd.shutdown,
                             daemon=True).start()

        signal.signal(signal.SIGTERM, stop)
        signal.signal(signal.SIGINT, stop)
    w = spec.system.workload
    print(f"serving {spec.system.fleet.replicas} replica(s) of "
          f"arch={w.arch} behind router={spec.system.router.policy} "
          f"on http://{spec.host}:{server.port}", flush=True)
    if ready is not None:
        ready.set()
    server.serve_forever()
    if spec.report_path:
        print(f"wrote {spec.report_path}", flush=True)
    return server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="HTTP serving loop over a live fleet (ServeSpec JSON)")
    ap.add_argument("--spec", required=True, help="ServeSpec JSON file")
    ap.add_argument("--port", type=int, default=None,
                    help="override serve.port")
    args = ap.parse_args(argv)
    spec = ServeSpec.load(args.spec)
    if args.port is not None:
        spec = ServeSpec.from_dict({**spec.to_dict(), "port": args.port})
    run_server(spec)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
