"""rwkv6-1.6b "Finch" [arXiv:2404.05892].

24L d_model=2048 (attention-free) d_ff=7168 vocab=65536. Data-dependent
decay linear-attention recurrence (WKV6); O(1) decode state makes every
decode shape (incl. long_500k) eligible.
"""

from repro.config import AttentionKind, BlockKind, ModelConfig, SSMConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="rwkv6-1.6b",
        source="arXiv:2404.05892",
        family="ssm",
        num_layers=24,
        d_model=2048,
        vocab_size=65536,
        num_heads=0,
        attention_kind=AttentionKind.NONE,
        d_ff=7168,
        ssm=SSMConfig(state_dim=64, head_dim=64, chunk_size=256),
        block_pattern=tuple(BlockKind.RWKV6 for _ in range(24)),
    )
)
