"""Render the §Roofline markdown table from dry-run records.

    PYTHONPATH=src python experiments/render_roofline.py [records_dir]
"""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))
from benchmarks.roofline_report import load, variant  # noqa: E402

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def main(records_dir: str = "experiments/dryrun") -> None:
    recs = load(records_dir)
    for mesh in ("pod1", "pod2"):
        rows = [r for r in recs if r.get("mesh") == mesh]
        if not rows:
            continue
        print(f"\n### Roofline — mesh {mesh} "
              f"({'256 chips' if mesh == 'pod1' else '512 chips, 2 pods'})\n")
        print("| arch | shape | variant | t_compute | t_memory | t_collective | bound "
              "| useful FLOPs | GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            v = variant(r)
            if r["status"] == "skipped":
                print(f"| {r['arch']} | {r['shape']} | {v} | — | — | — | skip | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | {v} | ERROR {r.get('error','')[:40]} |")
                continue
            mem = r.get("memory_analysis", {}).get("approx_total_per_device_gib", 0.0)
            print(f"| {r['arch']} | {r['shape']} | {v} | {r['t_compute_s']:.2e} s "
                  f"| {r['t_memory_s']:.2e} s | {r['t_collective_s']:.2e} s "
                  f"| {r['bottleneck']} | {r['useful_flops_ratio']:.3f} | {mem:.1f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
