"""Benchmark driver: one registry entry per paper table/figure.

Spec-driven: ``BENCHMARKS`` below is the single source of truth — each
entry names a section and a thunk that runs it (full or ``--quick``
arguments), so adding a benchmark is one registry line and ``--only``
/ ``--list`` derive their vocabulary from the registry instead of a
hand-maintained if-chain.

Prints human-readable sections followed by a machine-readable CSV block
(``name,us_per_call,derived``). Usage:

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run --quick      # reduced sweeps
    PYTHONPATH=src python -m benchmarks.run --list       # registry
    PYTHONPATH=src python -m benchmarks.run --only partition,fleet
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Callable, List, Tuple

# name -> (description, runner(quick, csv_rows)); registration order is
# execution order
BENCHMARKS: List[Tuple[str, str, Callable]] = []


def _register(name: str, description: str):
    def deco(fn):
        BENCHMARKS.append((name, description, fn))
        return fn
    return deco


@_register("table1", "SGEMM merge speedups (paper Table 1)")
def _table1(quick: bool, csv_rows: list) -> None:
    from benchmarks import table1_sgemm
    r_sweep = (2, 8, 32) if quick else (2, 4, 8, 16, 32)
    table1_sgemm.run(r_sweep=r_sweep, reps=3 if quick else 5,
                     csv_rows=csv_rows)


@_register("fig2", "batch-size sweep (paper Fig. 2)")
def _fig2(quick: bool, csv_rows: list) -> None:
    from benchmarks import fig2_batch_sweep
    fig2_batch_sweep.run(csv_rows=csv_rows)


@_register("fig3", "latency distributions (paper Fig. 3)")
def _fig3(quick: bool, csv_rows: list) -> None:
    from benchmarks import fig3_latency
    fig3_latency.run(csv_rows=csv_rows)


@_register("fig4", "predictability (paper Fig. 4)")
def _fig4(quick: bool, csv_rows: list) -> None:
    from benchmarks import fig4_predictability
    fig4_predictability.run(csv_rows=csv_rows)


@_register("fig5", "replica packing (paper Fig. 5)")
def _fig5(quick: bool, csv_rows: list) -> None:
    from benchmarks import fig5_replicas
    fig5_replicas.run(csv_rows=csv_rows)


@_register("trace", "dynamic trace scheduling policies")
def _trace(quick: bool, csv_rows: list) -> None:
    from benchmarks import dynamic_trace
    dynamic_trace.run_all_policies(num_events=80 if quick else 200,
                                   csv_rows=csv_rows)


@_register("sim", "solo simulator strategy sweep")
def _sim(quick: bool, csv_rows: list) -> None:
    from benchmarks import sim_sweep
    sim_sweep.run(events=20_000 if quick else 200_000, csv_rows=csv_rows)


@_register("fleet", "fleet router sweep")
def _fleet(quick: bool, csv_rows: list) -> None:
    from benchmarks import fleet_sweep
    fleet_sweep.run(events=5_000 if quick else 20_000, csv_rows=csv_rows)


@_register("hetero", "heterogeneous + autoscaled fleets")
def _hetero(quick: bool, csv_rows: list) -> None:
    from benchmarks import fleet_sweep
    fleet_sweep.run_hetero(events=5_000 if quick else 20_000,
                           autoscale=True, csv_rows=csv_rows)


@_register("deadline", "EDF vs fixed vs slo_adaptive under overload")
def _deadline(quick: bool, csv_rows: list) -> None:
    from benchmarks import deadline_sweep
    sections = deadline_sweep.run(events=30_000 if quick else 1_000_000)
    for name, m in sections.items():
        csv_rows.extend(m.bench_rows(f"deadline/{name}"))


@_register("partition", "knee-planned fractional shares vs whole chip")
def _partition(quick: bool, csv_rows: list) -> None:
    from benchmarks import partition_sweep
    sections = partition_sweep.run(events=30_000 if quick else 200_000)
    for name, m in sections.items():
        csv_rows.extend(m.bench_rows(f"partition/{name}"))


@_register("speed", "simulator events/sec throughput")
def _speed(quick: bool, csv_rows: list) -> None:
    from benchmarks import sim_speed
    sim_speed.run(events=100_000 if quick else 1_000_000,
                  fleet_events=100_000 if quick else 2_000_000,
                  repeats=1 if quick else 3, csv_rows=csv_rows)


@_register("roofline", "hardware roofline report")
def _roofline(quick: bool, csv_rows: list) -> None:
    from benchmarks import roofline_report
    roofline_report.run(csv_rows=csv_rows)
    roofline_report.run(mesh="pod2", csv_rows=csv_rows)


def main() -> None:
    names = [name for name, _, _ in BENCHMARKS]
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true", help="reduced sweeps")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(names))
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    args = ap.parse_args()

    if args.list:
        for name, description, _ in BENCHMARKS:
            print(f"{name:12s} {description}")
        return

    only = None
    if args.only:
        only = set(args.only.split(","))
        unknown = sorted(only - set(names))
        if unknown:
            print(f"unknown benchmark(s) {unknown} (have: {names})",
                  file=sys.stderr)
            sys.exit(2)

    csv_rows: list = []
    t0 = time.time()
    for name, _, runner in BENCHMARKS:
        if only is None or name in only:
            runner(args.quick, csv_rows)

    print(f"\n=== CSV (name,us_per_call,derived) — total {time.time()-t0:.0f}s ===")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
