"""Shape-bucketed kernel arrival queue.

Interactive inference queries arrive stochastically; each query decomposes
into a stream of kernel launches (mostly GEMMs). The queue groups pending
kernels by *shape bucket* — problems in the same bucket are mergeable into
one super-kernel. This mirrors the paper's dynamic scheduler front-end.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Dict, List, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ShapeBucket:
    """Super-kernel mergeability key."""

    op: str                       # "gemm" (others pluggable)
    M: int
    K: int
    N: int
    dtype: str

    @staticmethod
    def for_gemm(x: jax.Array, w: jax.Array) -> "ShapeBucket":
        M, K = x.shape
        _, N = w.shape
        return ShapeBucket("gemm", M, K, N, str(x.dtype))


_seq = itertools.count()


@dataclasses.dataclass
class GemmProblem:
    """One pending kernel from one tenant's model."""

    tenant_id: int
    x: jax.Array                  # (M, K) activation
    w: jax.Array                  # (K, N) this tenant's weights
    arrival_time: float = 0.0
    slo_s: float = 0.100
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    # filled by the scheduler on completion:
    result: Optional[jax.Array] = None
    completion_time: Optional[float] = None

    @property
    def bucket(self) -> ShapeBucket:
        return ShapeBucket.for_gemm(self.x, self.w)

    @property
    def flops(self) -> int:
        M, K = self.x.shape
        N = self.w.shape[1]
        return 2 * M * K * N


class KernelQueue:
    """FIFO-per-bucket pending-kernel store."""

    def __init__(self) -> None:
        self._buckets: Dict[ShapeBucket, Deque[GemmProblem]] = collections.defaultdict(
            collections.deque
        )

    def push(self, problem: GemmProblem) -> None:
        self._buckets[problem.bucket].append(problem)

    def __len__(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def buckets(self) -> List[Tuple[ShapeBucket, int]]:
        return [(b, len(q)) for b, q in self._buckets.items() if q]

    def oldest_arrival(self, bucket: ShapeBucket) -> Optional[float]:
        q = self._buckets.get(bucket)
        return q[0].arrival_time if q else None

    def pop_batch(self, bucket: ShapeBucket, max_n: int) -> List[GemmProblem]:
        """Pop up to max_n problems from a bucket, FIFO order."""
        q = self._buckets[bucket]
        out = []
        while q and len(out) < max_n:
            out.append(q.popleft())
        return out

    def drain(self) -> List[GemmProblem]:
        out = []
        for q in self._buckets.values():
            out.extend(q)
            q.clear()
        return out
