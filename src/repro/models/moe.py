"""Mixture-of-experts FFN: top-k router + capacity-based dense dispatch.

Dispatch is the einsum/one-hot (Switch-style) formulation with capacity
computed PER SEQUENCE, keeping the batch dim intact: dispatch tensor is
(B, S, E, C) with C = cf * S * k / E, so under batch-sharded SPMD each
device builds only its local slab and no global cumsum/sort crosses device
boundaries. Experts shard over the `model` mesh axis. The grouped Pallas
kernel (repro.kernels.grouped_gemm) provides the sorted-rows alternative
used by the space-time scheduler's ragged super-kernels on TPU.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.constraints import constrain
from repro.models import layers

Params = Dict[str, jax.Array]


def moe_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    keys = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts

    def stack_init(k, d_in, d_out):
        ks = jax.random.split(k, e)
        return jnp.stack([layers.dense_init(ki, d_in, d_out, dtype) for ki in ks])

    p: Params = {
        "router": layers.dense_init(keys[0], d, e, jnp.float32),
        "w_gate": stack_init(keys[1], d, f),   # (E, d, f)
        "w_up": stack_init(keys[2], d, f),     # (E, d, f)
        "w_down": stack_init(keys[3], f, d),   # (E, f, d)
    }
    if m.num_shared_experts:
        p["shared"] = layers.mlp_init(
            keys[4], d, m.num_shared_experts * f, cfg.mlp_gated, dtype
        )
    return p


def moe_forward(
    params: Params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Route tokens through top-k experts.

    x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar load-balance loss).
    """
    assert cfg.moe is not None
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.experts_per_token

    logits = x.astype(jnp.float32) @ params["router"]        # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)          # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)  # (B, S, K, E)
    # load-balance auxiliary loss (Switch-style), averaged over batch+seq
    tokens_per_expert = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))  # (E,)
    prob_per_expert = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(tokens_per_expert * prob_per_expert) * m.router_aux_loss_weight

    # per-sequence expert capacity (cumsum stays local to each sequence)
    capacity = int(max(1, m.capacity_factor * S * K / E))
    flat = onehot.reshape(B, S * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (B, S, K)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos, capacity).astype(jnp.int32), capacity, dtype=x.dtype
    )                                                        # (B, S, K, C)
    disp = jnp.einsum("bske,bskc->bsec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum(
        "bske,bskc,bsk->bsec",
        onehot,
        pos_oh.astype(jnp.float32),
        gate_vals.astype(jnp.float32),
    ).astype(x.dtype)
    disp = constrain(disp, "batch", None, "model", None, force=True)
    comb = constrain(comb, "batch", None, "model", None, force=True)

    xe = jnp.einsum("bsec,bsd->becd", disp, x)               # (B, E, C, d)
    xe = constrain(xe, "batch", "model", None, None, force=True)
    if cfg.mlp_gated:
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, params["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", xe, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xe, params["w_up"]))
    h = constrain(h, "batch", "model", None, None, force=True)
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"])   # (B, E, C, d)
    y = jnp.einsum("bsec,becd->bsd", comb, ye)

    if m.num_shared_experts:
        y = y + layers.mlp(params["shared"], x, cfg.mlp_gated)
    return y, aux
