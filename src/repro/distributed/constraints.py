"""Activation sharding constraints (MaxText-style logical annotations).

Model code calls ``constrain(x, "batch", None, "model")`` with LOGICAL axis
names; launchers activate a mesh via ``use_mesh``. When no mesh is active
(CPU smoke tests) constraints are no-ops. Axes that don't divide the
corresponding dim are dropped rather than producing uneven shardings.

Logical -> physical:
    "batch"  -> ("pod", "data") when present, else ("data",)
    "model"  -> ("model",)
    "data"   -> ("data",)   (sequence sharding for batch=1 long-context)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def tp_activations_enabled() -> bool:
    return getattr(_state, "tp_acts", True)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, tp_activations: bool = True):
    """Activate a mesh for activation constraints.

    tp_activations: when False, "model"-axis constraints on activations
    become no-ops (weights may still be model/data-sharded for storage —
    GSPMD then gathers WEIGHTS per layer, ZeRO-3 style, instead of
    planting tensor-parallel activation collectives). Measured on zamba2
    train_4k: TP-activation all-reduces scale with B*S*d and dominate at
    training batch sizes, while weight gathers are 5x smaller; for decode
    the inequality flips. Expert-parallel constraints (MoE) pass
    force=True and are unaffected.
    """
    prev = active_mesh()
    prev_tp = tp_activations_enabled()
    _state.mesh = mesh
    _state.tp_acts = tp_activations
    try:
        yield mesh
    finally:
        _state.mesh = prev
        _state.tp_acts = prev_tp


def _physical(mesh: Mesh, logical) -> Optional[tuple]:
    if logical is None:
        return None
    if logical == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes or None
    if logical in mesh.axis_names:
        return (logical,)
    return None


def constrain(x: jax.Array, *logical_axes, force: bool = False) -> jax.Array:
    """Apply with_sharding_constraint if a mesh is active and dims divide.

    force=True keeps "model"-axis constraints even when tp_activations is
    off (expert-parallel MoE dims must stay sharded or expert compute
    replicates).
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"spec rank {len(logical_axes)} != array rank {x.ndim}")
    if not force and not tp_activations_enabled():
        logical_axes = tuple(None if a == "model" else a for a in logical_axes)
    spec = []
    used: set = set()
    for dim, logical in zip(x.shape, logical_axes):
        axes = _physical(mesh, logical)
        if axes is None or any(a in used for a in axes):
            spec.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size == 0 and dim >= size:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        elif len(axes) > 1:
            # try the trailing axis alone (e.g. "data" without "pod")
            a = axes[-1]
            if dim % mesh.shape[a] == 0 and dim >= mesh.shape[a]:
                spec.append(a)
                used.add(a)
            else:
                spec.append(None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
