"""Multi-replica fleet simulation: N real schedulers behind a router.

The replica-scaling half of the paper's story (Fig. 5 counts how many
replicas FIT; this answers what a fleet of them DOES under load): each
replica is a full ``ReplicaPump`` — the real ``DynamicSpaceTimeScheduler``
on its own ``VirtualClock`` with its own compile-cache cold-start state —
and a pluggable ``Router`` (``repro.sim.router``) assigns every arrival
to one of them.

The fleet event loop merges per-replica ripeness instants into ONE global
timeline: between trace arrivals it repeatedly finds the replica with the
earliest next ripeness instant and pumps exactly that replica there, so
cross-replica event ordering is exact, not quantized per replica. Routing
decisions therefore observe every replica's true state as of the
arrival's trace time.

Cold starts are what couple routing to scheduling: each replica wraps its
base cost model in its own ``ColdStartCostModel``, so the first dispatch
of a (bucket, pow2-R) variant on a given replica pays a compile term —
spreading a tenant across the fleet multiplies compiles, pinning it
concentrates load. That is the JSQ-vs-affinity trade the routers and
``benchmarks/fleet_sweep.py`` measure.

Three fleet-scale axes beyond PR 3's identical-replica grid:

* **Heterogeneity** — ``specs`` gives each replica its own
  ``HardwareSpec`` (cycled: ``["v5e", "v5e_half"]`` alternates fast and
  half-speed chips), so each replica prices work through its OWN roofline
  and speed-aware routers (``least_cost``) see the difference. A
  load-oblivious router wastes the fast chips exactly as D-STACK predicts.
* **Elasticity** — an ``Autoscaler`` (``repro.sim.autoscale``) is polled
  at fixed simulated-time ticks; scale-up spawns a FRESH replica (new id,
  EMPTY compile cache — the full cold-start bill — and an optional
  ``spinup_s`` before it takes work), scale-down retires the replica
  with the lowest drain cost (ties: the newest), which drains what it
  already owns but receives nothing new.
  Every decision lands in ``scale_events`` and the metrics JSON.
* **Per-replica calibration** — a ``FleetCalibrator`` taps every
  replica's ``on_dispatch`` (the scheduler forwards ``replica_id``) into
  per-replica ``CalibratedCostModel`` tables, and each replica routes
  through its own table (``ReplicaPump.route_model``): even with a wrong
  shared prior, routing converges toward each chip's measured costs.

Determinism: routers and autoscalers are pure functions of replica state,
replica state is driven by seeded traces and virtual clocks — one seed,
byte-identical fleet metrics JSON, scale events included; same contract
as the solo simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.config import ScheduleConfig
from repro.core.clock import VirtualClock
from repro.core.pump import drain_fleet_tail, drain_merged
from repro.launch.roofline import TPU_V5E, HardwareSpec
from repro.sim.autoscale import (
    Autoscaler,
    ScaleEvent,
    make_autoscaler,
    pick_scale_down,
)
from repro.sim.costmodel import (
    ColdStartCostModel,
    FleetCalibrator,
    RooflineCostModel,
    estimate_capacity_hz,
    resolve_spec,
)
from repro.obs.recorder import route_price_vector
from repro.partition.shares import PartitionPlan
from repro.sim.metrics import FleetMetrics, MetricsAccumulator
from repro.sim.router import Router, make_router
from repro.sim.simulator import ReplicaPump, SimWorkload
from repro.sim.traces import Arrival, Trace


def _arrival_stream(trace):
    """Flatten a trace to ``(t_s, spec, cost)`` triples, preferring the
    columnar chunk iterator: plain-float columns and an interned spec
    table instead of one ``Arrival`` namedtuple (plus numpy-scalar
    unboxing) per event. Values are bit-identical either way — the chunk
    contract — so the fleet loop's timeline does not depend on which
    path fed it."""
    iter_chunks = getattr(trace, "iter_chunks", None)
    if iter_chunks is None:
        yield from trace
        return
    for times, idx, costs, table in iter_chunks():
        for t, i, c in zip(times.tolist(), idx.tolist(), costs.tolist()):
            yield t, table[i], c


def calibration_tap(calibration: FleetCalibrator, model):
    """Dispatch tap that fits WARM costs: a cold dispatch's measured
    seconds include the one-off compile term, and folding that into
    the table would make a replica price a key HIGHER right after
    compiling it (inverting warm-cache affinity — the first
    observation per key is by construction the cold one). The
    cold-start wrapper knows which dispatches were cold, so the tap
    subtracts its compile term before the calibrator sees them.

    Shared by the fleet simulator and the live fleet: in the simulator
    ``seconds`` is the modeled dispatch cost; live it is REAL measured
    wall seconds (``t1 - t0`` around the actual kernel execution) — same
    tap, same tables, which is what makes live-calibrated tables loadable
    back into sim runs."""
    if not isinstance(model, ColdStartCostModel):
        return calibration.observe

    def tap(batch, seconds, replica_id):
        if model.dispatch_cold and model.dispatch_cold[-1]:
            seconds -= model.compile_s
        calibration.observe(batch, seconds, replica_id)

    return tap


def fleet_capacity_hz(
    mix: Sequence,
    specs: Sequence[Union[str, HardwareSpec]],
    strategy: str = "space_time",
    merge_size: int = 32,
) -> float:
    """Aggregate sustainable arrivals/s of a heterogeneous fleet: the sum
    of each replica's ``estimate_capacity_hz`` under its own spec — the
    anchor hetero sweeps use so a mixed fleet and its equal-aggregate
    homogeneous twin see the same offered load."""
    return sum(
        estimate_capacity_hz(
            mix, RooflineCostModel(spec=resolve_spec(s), strategy=strategy),
            merge_size=merge_size)
        for s in specs)


class FleetSimulator:
    """N replicas of the real scheduler behind a router, one timeline.

    Replica pricing, pick ONE of:

    * ``cost_model`` — a SHARED stateless base (roofline or calibrated)
      every replica wraps: the homogeneous fleet.
    * ``specs`` — per-replica hardware (``HardwareSpec`` instances or
      ``HARDWARE_SPECS`` names, cycled over the fleet); replica ``i``
      prices through ``RooflineCostModel(spec=specs[i % len], strategy)``:
      the heterogeneous fleet.

    When ``compile_s > 0`` each replica additionally wraps its base in
    its own ``ColdStartCostModel`` — per-replica warm caches
    (``compile_s=0`` turns cold-start modeling off).

    ``autoscaler`` (an ``Autoscaler`` or factory name) makes the fleet
    elastic: ``replicas`` then sets the INITIAL size and the policy's
    min/max bound the rest. ``calibration`` (a ``FleetCalibrator``) wires
    every replica's dispatch tap into per-replica measured-cost tables
    that routing then prices through.

    ``workers > 1`` shards the replica pumps across forked worker
    processes (``repro.sim.shard``): byte-identical metrics to
    ``workers=1``, but restricted to configurations where replicas are
    provably independent — a fresh round-robin router, no autoscaler, no
    calibration, and a stable-window policy. ``run`` raises an
    actionable error otherwise.

    One-shot: state (routed counts, scale events, calibration tables)
    accumulates across ``run`` — build a fresh instance per trace, or use
    ``simulate_fleet``.
    """

    def __init__(
        self,
        replicas: int,
        router: Union[Router, str] = "jsq",
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        compile_s: float = 1e-3,
        start_s: float = 0.0,
        specs: Optional[Sequence[Union[str, HardwareSpec]]] = None,
        strategy: str = "space_time",
        autoscaler: Optional[Union[Autoscaler, str]] = None,
        calibration: Optional[FleetCalibrator] = None,
        workers: int = 1,
        recorder=None,
        partition: Optional[PartitionPlan] = None,
        partition_hardware: Optional[HardwareSpec] = None,
        small_kernel_efficiency: float = 0.45,
        replanner: Optional[Callable[[Optional[Dict[str, int]]],
                                     PartitionPlan]] = None,
        replan_interval_s: float = 0.0,
    ):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if specs is not None and cost_model is not None:
            raise ValueError(
                "pass per-replica specs OR a shared cost_model, not both")
        if specs is not None and not specs:
            raise ValueError("specs must be non-empty when given")
        if partition is not None:
            # co-located slice pumps share one chip's timeline: per-chip
            # state the shard merge / autoscaler / hetero specs cannot
            # reason about — same rules SystemSpec enforces at load time
            if workers > 1:
                raise ValueError(
                    "partition requires workers=1 (co-located slice pumps "
                    "share per-chip state the shard merge does not replay)")
            if autoscaler is not None:
                raise ValueError(
                    "partition cannot combine with an autoscaler: the plan "
                    "carves a fixed replica set; drop one")
            if specs is not None:
                raise ValueError(
                    "partition cannot combine with per-replica specs: "
                    "slices are carved from ONE base hardware "
                    "(partition_hardware)")
            if cost_model is not None:
                raise ValueError(
                    "partition builds each slice's own sliced-roofline "
                    "model; drop the shared cost_model")
        if replan_interval_s < 0.0:
            raise ValueError(
                f"replan_interval_s must be >= 0, got {replan_interval_s}")
        self.workers = int(workers)
        self.router = make_router(router) if isinstance(router, str) else router
        self.schedule = schedule
        self.start_s = float(start_s)
        self.compile_s = float(compile_s)
        self.strategy = strategy
        self.specs = [resolve_spec(s) for s in specs] if specs else None
        self._shared_base = cost_model
        self.autoscaler = (make_autoscaler(autoscaler)
                           if isinstance(autoscaler, str) else autoscaler)
        self.calibration = calibration
        # optional FlightRecorder (repro.obs); set before the initial
        # spawn loop so every replica — initial or autoscaled — attaches
        self.recorder = recorder
        self.partition = partition
        self.partition_hardware = partition_hardware or TPU_V5E
        self.small_kernel_efficiency = float(small_kernel_efficiency)
        self.replanner = replanner
        self.replan_interval_s = float(replan_interval_s)
        self.partition_events: List[Dict] = []
        # group index -> that group's slice pumps, one per physical chip
        self._group_pumps: List[List[ReplicaPump]] = (
            [[] for _ in partition.groups] if partition is not None else [])
        # replica_id -> the mutable RooflineCostModel a slice prices
        # through (re-planning swaps its .spec in place)
        self._partition_bases: Dict[int, RooflineCostModel] = {}

        self.pumps: List[ReplicaPump] = []       # every replica ever live
        self.active: List[ReplicaPump] = []      # currently routable
        self._retired: List[ReplicaPump] = []    # scaled down, may drain
        self.routed_counts: List[int] = []       # indexed by replica_id
        self.scale_events: List[ScaleEvent] = []
        self._fleet_acc = MetricsAccumulator()
        self._replica_accs: List[MetricsAccumulator] = []
        self._next_id = 0
        groups = len(partition.groups) if partition is not None else 1
        for _ in range(replicas * groups):
            self._spawn(self.start_s)
        if partition is not None:
            for g in partition.groups:
                self.partition_events.append({
                    "t_s": self.start_s, "action": "assign",
                    "group": g.name, "share": g.share,
                    "tenants": list(g.tenants), "window_s": g.window_s})

    # -------------------------------------------------------- replica pool
    def _slice_spec(self, group) -> HardwareSpec:
        hw = self.partition_hardware
        return hw.sliced(group.share,
                         name=f"{hw.name}@{group.name}:{group.share:g}")

    def _base_model(self, replica_id: int) -> Callable[[Sequence], float]:
        if self.partition is not None:
            group = self.partition.groups[
                replica_id % len(self.partition.groups)]
            base = RooflineCostModel(
                spec=self._slice_spec(group), strategy=self.strategy,
                small_kernel_efficiency=self.small_kernel_efficiency)
            self._partition_bases[replica_id] = base
            return base
        if self.specs is not None:
            return RooflineCostModel(
                spec=self.specs[replica_id % len(self.specs)],
                strategy=self.strategy)
        return self._shared_base or RooflineCostModel()

    def _spawn(self, t_s: float) -> ReplicaPump:
        """Bring up one replica whose clock starts at ``t_s`` — at init
        that is the fleet epoch; mid-run it is the scale-up instant (plus
        spin-up), and the fresh ``ColdStartCostModel`` means every variant
        recompiles on it: spinning up pays the full cold cache."""
        i = self._next_id
        self._next_id += 1
        base = self._base_model(i)
        clock = VirtualClock(t_s)
        model: Callable[[Sequence], float] = base
        if self.compile_s > 0.0:
            model = ColdStartCostModel(base, compile_s=self.compile_s,
                                       clock=clock)
        schedule = self.schedule
        if self.partition is not None:
            # the planner co-optimized a batching window per slice: a
            # slice with deadline slack batches wider, a tight one leaner
            group = self.partition.groups[i % len(self.partition.groups)]
            if group.window_s is not None:
                schedule = dataclasses.replace(
                    schedule or ScheduleConfig(),
                    batching_window_s=group.window_s)
        pump = ReplicaPump(schedule=schedule, cost_model=model,
                           clock=clock, replica_id=i)
        pump.track_inflight = True  # routers read occupancy in fleet time
        spec = getattr(base, "spec", None)
        if spec is not None:
            pump.spec_name = spec.name
            # relative chip speed: the weighted-affinity routing signal
            pump.speed_factor = spec.peak_flops / TPU_V5E.peak_flops
        if self.calibration is not None:
            pump.scheduler.on_dispatch = self._calibration_tap(model)
            pump.route_model = self.calibration.for_replica(i)
        if self.recorder is not None:
            # after calibration wiring: the recorder tap composes over it
            pump.attach_recorder(self.recorder.shard(i))
        if self.partition is not None:
            self._group_pumps[i % len(self.partition.groups)].append(pump)
        acc = MetricsAccumulator()
        pump.accs = [self._fleet_acc, acc]
        self.pumps.append(pump)
        self.active.append(pump)
        self.routed_counts.append(0)
        self._replica_accs.append(acc)
        return pump

    def _calibration_tap(self, model):
        return calibration_tap(self.calibration, model)

    def _apply_autoscale(self, now: float) -> None:
        scaler = self.autoscaler
        target = scaler.decide(self.active, now)
        signal = float(getattr(scaler, "last_signal", 0.0))
        while len(self.active) < target:
            p = self._spawn(now + scaler.spinup_s)
            self.scale_events.append(ScaleEvent(
                t_s=now, action="up", replica_id=p.replica_id,
                active=len(self.active), signal=signal))
        while len(self.active) > max(target, 1):
            # retire the cheapest-to-drain replica (backlog seconds priced
            # via its own table); ties retire the newest, keeping the
            # longest-warmed caches alive — deterministic either way
            p = self.active.pop(pick_scale_down(self.active, now))
            self._retired.append(p)
            self.scale_events.append(ScaleEvent(
                t_s=now, action="down", replica_id=p.replica_id,
                active=len(self.active), signal=signal))

    def _apply_replan(self, now: float) -> None:
        """Re-run the planner from each slice's OBSERVED mean merged
        batch size and swap slice sizes in place.

        Only SHARES move: each affected pump's base ``RooflineCostModel``
        gets the new sliced spec (pricing, feasibility admission and
        routing all read it from there), while batching windows stay at
        their planned values — the pump's calendar queue is built around
        a fixed window. Group membership never changes (the planner is
        deterministic in the mix), so routing stays stable too.
        """
        plan = self.partition
        r_obs: Dict[str, int] = {}
        for gi, g in enumerate(plan.groups):
            stats = [p.scheduler.stats for p in self._group_pumps[gi]]
            dispatches = sum(s.dispatches for s in stats)
            if dispatches > 0:
                completed = sum(s.problems_completed for s in stats)
                r_obs[g.name] = max(1, round(completed / dispatches))
        new_plan = self.replanner(r_obs or None)
        applied = []
        for gi, (old, new) in enumerate(zip(plan.groups, new_plan.groups)):
            applied.append(dataclasses.replace(new, window_s=old.window_s))
            if abs(new.share - old.share) <= 1e-12:
                continue
            spec = self._slice_spec(new)
            for p in self._group_pumps[gi]:
                self._partition_bases[p.replica_id].spec = spec
                p.spec_name = spec.name
                p.speed_factor = spec.peak_flops / TPU_V5E.peak_flops
            self.partition_events.append({
                "t_s": now, "action": "replan", "group": new.name,
                "share": new.share, "prev_share": old.share,
                "observed_r": r_obs.get(new.name, 0)})
        self.partition = PartitionPlan(groups=tuple(applied))

    # ------------------------------------------------------------ event loop
    def _drain_until(self, t_limit: float) -> None:
        """Merged global timeline (``repro.core.pump.drain_merged``) over
        ALL replicas that can still ripen — a scaled-down replica no
        longer receives arrivals but still drains what it owns."""
        # a retired replica with a dry queue can never ripen again; skip
        # it so heavy autoscale cycling doesn't grow the per-event scan
        pumps = self.active
        if self._retired:
            pumps = pumps + [p for p in self._retired
                             if len(p.scheduler.queue)]
        drain_merged(pumps, t_limit)

    def run(self, trace: Union[Trace, Iterable[Arrival]]) -> FleetMetrics:
        if self.workers > 1:
            # deferred import: shard imports this module
            from repro.sim.shard import run_sharded
            return run_sharded(self, trace)
        router, scaler = self.router, self.autoscaler
        rec = self.recorder
        t_start = self.start_s
        next_tick = t_start + scaler.interval_s if scaler is not None else None
        next_replan = None
        if (self.partition is not None and self.replanner is not None
                and self.replan_interval_s > 0.0):
            next_replan = t_start + self.replan_interval_s

        for t_s, spec, cost in _arrival_stream(trace):
            while next_tick is not None and t_s >= next_tick:
                self._drain_until(next_tick)
                self._apply_autoscale(next_tick)
                next_tick += scaler.interval_s
            while next_replan is not None and t_s >= next_replan:
                self._drain_until(next_replan)
                self._apply_replan(next_replan)
                next_replan += self.replan_interval_s
            self._drain_until(t_s)
            # a partitioned fleet routes WITHIN the tenant's slice group:
            # the candidates are that slice's pumps across chips, so the
            # router load-balances chips while the plan owns placement
            candidates = self.active
            if self.partition is not None:
                candidates = self._group_pumps[
                    self.partition.group_of(spec.tenant_id)]
            idx = router.route(spec, candidates, t_s)
            pump = candidates[idx]
            if rec is not None:
                # recompute the (idempotent) price vector the router just
                # read — recorded before submit so the decision context is
                # the pre-admission state it was actually made against
                rids, prices = route_price_vector(
                    router, spec, candidates, t_s)
                rec.record_route(t_s, spec.tenant_id, pump.replica_id,
                                 rids, prices)
            w = SimWorkload(spec, cost)
            w.est_s = pump.estimate_item_s(w)
            if pump.submit(w, t_s):
                self.routed_counts[pump.replica_id] += 1

        # tail: keep merging ripeness instants until every queue is dry,
        # then force-flush whatever the estimates could not ripen
        pumps = self.pumps
        drain_fleet_tail(pumps, self._drain_until)

        # fleet horizon: the makespan across replicas that actually
        # dispatched; every replica's utilization is reported against it
        # so the spread is meaningful. A spun-up replica that never took
        # work keeps its future-dated (spawn + spin-up) clock and must
        # not stretch the horizon — that would deflate every per-second
        # metric for work the fleet finished long before.
        horizon = max((p.clock.now() for p in pumps
                       if p.scheduler.stats.dispatches > 0),
                      default=t_start) - t_start
        if rec is not None:
            rec.router_name = self.router.name
            rec.record_scale_events(self.scale_events)
            if self.partition is not None:
                rec.record_partition_events(self.partition_events)
        partition_doc = None
        if self.partition is not None:
            partition_doc = {
                "plan": self.partition.to_dict(),
                "events": [dict(e) for e in self.partition_events],
                "groups_per_replica": len(self.partition.groups),
            }
        merged = self._freeze_merged(self._fleet_acc, horizon)
        per_replica = [p.freeze(acc, sim_duration_s=horizon)
                       for p, acc in zip(pumps, self._replica_accs)]
        cold_times, cold_flags = self._cold_series()
        return FleetMetrics(
            merged=merged,
            per_replica=per_replica,
            routed_counts=list(self.routed_counts),
            router=self.router.name,
            cold_times=cold_times,
            cold_flags=cold_flags,
            scale_events=self.scale_events,
            replica_specs=[p.spec_name for p in pumps],
            final_active=len(self.active),
            partition=partition_doc,
        )

    # ------------------------------------------------------------- internals
    def _freeze_merged(self, acc: MetricsAccumulator,
                       horizon: float):
        stats = [p.scheduler.stats for p in self.pumps]
        return acc.freeze(
            sim_duration_s=horizon,
            busy_time_s=sum(s.busy_time_s for s in stats),
            dispatches=sum(s.dispatches for s in stats),
            rejected=sum(s.rejected for s in stats),
            evicted_tenants=sum(len(p.scheduler.evicted) for p in self.pumps),
            ripe_nudges=sum(s.ripe_nudges for s in stats),
            deadline_rejected=sum(s.deadline_rejected for s in stats),
            oversubscribed=sum(s.oversubscribed for s in stats),
            preemptions=sum(s.preemptions for s in stats),
        )

    def _cold_series(self):
        """Concatenated (time, was_cold) dispatch series across replicas,
        sorted by time (stable, so equal instants keep replica order —
        deterministic)."""
        times: List[np.ndarray] = []
        flags: List[np.ndarray] = []
        for p in self.pumps:
            m = p.cost_model
            if isinstance(m, ColdStartCostModel):
                times.append(np.asarray(m.dispatch_times, np.float64))
                flags.append(np.asarray(m.dispatch_cold, np.int64))
        if not times:
            return np.zeros(0, np.float64), np.zeros(0, np.int64)
        t = np.concatenate(times)
        f = np.concatenate(flags)
        order = np.argsort(t, kind="stable")
        return t[order], f[order]


def simulate_fleet(
    trace: Union[Trace, Iterable[Arrival]],
    replicas: int,
    router: Union[Router, str] = "jsq",
    schedule: Optional[ScheduleConfig] = None,
    cost_model: Optional[Callable[[Sequence], float]] = None,
    compile_s: float = 1e-3,
    specs: Optional[Sequence[Union[str, HardwareSpec]]] = None,
    strategy: str = "space_time",
    autoscaler: Optional[Union[Autoscaler, str]] = None,
    calibration: Optional[FleetCalibrator] = None,
    workers: int = 1,
) -> FleetMetrics:
    """One-shot convenience wrapper: fresh fleet, one trace, metrics."""
    return FleetSimulator(
        replicas, router=router, schedule=schedule, cost_model=cost_model,
        compile_s=compile_s, specs=specs, strategy=strategy,
        autoscaler=autoscaler, calibration=calibration, workers=workers,
    ).run(trace)
