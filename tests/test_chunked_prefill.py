"""Chunked (continuation) prefill: processing a prompt in chunks with
state carry must equal single-shot prefill — for attention caches (linear),
Mamba2 conv+SSM state, RWKV wkv+shift state, and MoE routing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, smoke_variant
from repro.models import build_model

ARCHS = ["stablelm-1.6b", "rwkv6-1.6b", "zamba2-7b", "granite-moe-1b-a400m"]


def _setup(arch, rng_key):
    cfg = dataclasses.replace(smoke_variant(get_config(arch)), dtype="float32")
    m = build_model(cfg)
    return cfg, m, m.init(rng_key)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("splits", [[(0, 10), (10, 18), (18, 24)], [(0, 1), (1, 24)]],
                         ids=["3chunks", "tiny_first"])
def test_chunked_equals_single(arch, splits, rng_key):
    cfg, m, params = _setup(arch, rng_key)
    B, S = 2, 24
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    want_logits, want_caches = m.forward_prefill(params, toks, cache_len=S + 4)

    caches = None
    logits = None
    for a, b in splits:
        if caches is None:
            logits, caches = m.forward_prefill(params, toks[:, a:b], cache_len=S + 4)
        else:
            logits, caches = m.forward_prefill(
                params, toks[:, a:b], cache_len=S + 4,
                caches=caches, start=jnp.int32(a),
            )
    scale = max(float(jnp.max(jnp.abs(want_logits))), 1.0)
    np.testing.assert_allclose(
        np.asarray(logits) / scale, np.asarray(want_logits) / scale, atol=3e-4
    )
    # the carried caches must also support identical decode
    tok = jnp.argmax(want_logits, -1).astype(jnp.int32)
    lens = jnp.full((B,), S, jnp.int32)
    l1, _ = m.forward_decode(params, tok, caches, lens)
    l2, _ = m.forward_decode(params, tok, want_caches, lens)
    np.testing.assert_allclose(
        np.asarray(l1) / scale, np.asarray(l2) / scale, atol=3e-4
    )


def test_sliding_window_rejects_continuation(rng_key):
    cfg, m, params = _setup("gemma3-27b", rng_key)
    toks = jax.random.randint(rng_key, (1, 8), 0, cfg.vocab_size)
    _, caches = m.forward_prefill(params, toks, cache_len=32)
    with pytest.raises(NotImplementedError):
        m.forward_prefill(params, toks, cache_len=32, caches=caches, start=jnp.int32(8))
