"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: tests assert_allclose each kernel
against these across shape/dtype sweeps, and ``ops.py`` routes to them on
CPU (where Pallas interpret mode would be orders of magnitude slower than
XLA:CPU) and inside the 512-device dry-run (where interpret-mode grids
would unroll into enormous HLO).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def batched_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """out[r] = x[r] @ w[r]; x (R,M,K), w (R,K,N)."""
    return jnp.einsum("rmk,rkn->rmn", x, w)


def grouped_gemm(x: jax.Array, w: jax.Array, block_groups: jax.Array, bm: int) -> jax.Array:
    """out[i-th row block] = x_block @ w[block_groups[i]]."""
    T, K = x.shape
    nblk = T // bm
    xb = x.reshape(nblk, bm, K)
    wb = w[block_groups]  # (nblk, K, N)
    return jnp.einsum("bmk,bkn->bmn", xb, wb).reshape(T, -1)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    logit_softcap: float = 0.0,
    q_offset=None,
) -> jax.Array:
    """Dense reference attention. q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D).

    q_offset: absolute position of the first query (default Skv - Sq —
    queries are the suffix). May be a traced scalar (chunked prefill).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    q_per_kv = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, q_per_kv, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s *= scale
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if q_offset is None:
        q_offset = Skv - Sq
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    logit_softcap: float = 0.0,
    kv_chunk: int = 1024,
    q_offset=None,
) -> jax.Array:
    """O(S)-memory attention: lax.scan over KV chunks with online softmax.

    This is "flash attention in XLA" — the pure-jnp path for long sequences
    (the dense reference would materialize a (B,H,Sq,Skv) score tensor,
    which at 32k-500k context is unlowerable). Semantics identical to
    ``attention``.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    q_per_kv = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    C = min(kv_chunk, Skv)
    nc = -(-Skv // C)
    pad = nc * C - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qg = q.reshape(B, Hkv, q_per_kv, Sq, D).astype(jnp.float32) * scale
    kc = k.reshape(B, Hkv, nc, C, D).transpose(2, 0, 1, 3, 4)  # (nc,B,Hkv,C,D)
    vc = v.reshape(B, Hkv, nc, C, D).transpose(2, 0, 1, 3, 4)
    if q_offset is None:
        q_offset = Skv - Sq
    q_pos = jnp.arange(Sq) + q_offset

    def step(carry, inp):
        m, l, acc, jc = carry
        kb, vb = inp
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qg, kb.astype(jnp.float32))
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        kv_pos = jc * C + jnp.arange(C)
        mask = kv_pos[None, :] < Skv
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqc,bhcd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc, jc + 1), None

    m0 = jnp.full((B, Hkv, q_per_kv, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, q_per_kv, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, q_per_kv, Sq, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (kc, vc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(B, Hq, Sq, D)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode. q (B,Hq,D), caches (B,Hkv,S,D), lengths (B,)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    q_per_kv = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, q_per_kv, D)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32))
    s *= scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def wkv6_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    init_state: Optional[jax.Array] = None,
) -> jax.Array:
    """Sequential-scan oracle for the WKV6 recurrence.

    r/k/w: (BH, T, N); v: (BH, T, V); u: (BH, N); optional init_state
    (BH, N, V) for continuation prefill -> (BH, T, V).
    """
    BH, T, N = r.shape
    V = v.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((BH, N, V), jnp.float32)

    def head(r_h, k_h, v_h, w_h, u_h, s0):
        def step(state, inputs):
            r_t, k_t, v_t, w_t = inputs
            decay = jnp.exp(-jnp.exp(w_t.astype(jnp.float32)))
            kv = jnp.outer(k_t, v_t).astype(jnp.float32)
            out = r_t.astype(jnp.float32) @ (state + u_h[:, None] * kv)
            new_state = decay[:, None] * state + kv
            return new_state, out

        _, outs = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return outs

    out = jax.vmap(head)(r, k, v, w, u.astype(jnp.float32),
                         init_state.astype(jnp.float32))
    return out.astype(r.dtype)


def wkv6_step(
    state: jax.Array,
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
):
    """One decode step of WKV6. state (BH,N,V); r/k/w (BH,N); v (BH,V); u (BH,N)."""
    decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))
    kv = jnp.einsum("bn,bv->bnv", k.astype(jnp.float32), v.astype(jnp.float32))
    out = jnp.einsum(
        "bn,bnv->bv", r.astype(jnp.float32), state + u[..., None] * kv
    )
    new_state = decay[..., None] * state + kv
    return new_state, out.astype(r.dtype)
