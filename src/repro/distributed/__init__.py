"""Sharding rules and mesh utilities for the production (multi-)pod mesh."""

from repro.distributed.sharding import (  # noqa: F401
    cache_specs,
    choose_spec,
    data_axes,
    input_specs_shardings,
    param_specs,
)
