"""AdamW with decoupled weight decay + warmup-cosine schedule (pure JAX)."""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


def adamw_init(params: Params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def lr_schedule(
    step: jax.Array,
    base_lr: float,
    warmup_steps: int,
    total_steps: int,
    min_ratio: float = 0.1,
) -> jax.Array:
    """Linear warmup then cosine decay to min_ratio * base_lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def adamw_update(
    grads: Params,
    state: AdamWState,
    params: Params,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping. Returns (params, state, metrics)."""
    gflat = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in gflat))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
