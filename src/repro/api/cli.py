"""``python -m repro`` — the unified CLI over live, sim, and fleet runs.

Subcommands:

    simulate   run one spec end to end, print the headline summary,
               optionally write the RunReport JSON (--out) and gate
               determinism (--check: run twice, byte-identical metrics;
               live specs check report schema/shape invariants instead —
               wall-clock runs are not byte-reproducible)
    serve      HTTP front door over a live fleet (ServeSpec JSON):
               GET /healthz, POST /v1/predict, GET /v1/report
    sweep      cross-product grid over spec fields (--axis a.b=v1,v2),
               BENCH-style JSON export, --dry-run lists the cells
    trace      run one spec with the flight recorder forced on and export
               the Chrome trace_event JSON (open in Perfetto) plus
               optional windowed telemetry; --check gates byte-identical
               trace export across a same-seed rerun
    report     inspect a saved RunReport JSON: the headline summary,
               scheduler counters, and (--timeline) the windowed
               telemetry series recorded by an observability-enabled run
    calibrate  fit a CalibratedCostModel from LIVE dispatches of the
               spec's kernel mix and save the table for simulated replay
    check      validate a spec file and print the resolved plan without
               running anything
    specs      list every registered name a spec can reference
               (hardware, mixes, processes, routers, autoscalers,
               strategies)

All subcommands speak the same declarative ``SystemSpec`` JSON
(``repro.api.spec``); ``--set section.field=value`` overrides any field
from the command line, so a committed spec file plus a couple of --set
flags replaces each of the old per-benchmark argparse forests.
"""

from __future__ import annotations

import argparse
import itertools
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.api.spec import (
    MIXES,
    MODES,
    PROCESSES,
    SystemSpec,
)
from repro.sim.costmodel import STRATEGIES
from repro.sim.metrics import SCHEMA_VERSION, to_bench_json
from repro.sim.router import ROUTERS


def _parse_value(text: str):
    """CLI value -> JSON value: try JSON first (numbers, booleans, null,
    lists), fall back to the bare string."""
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return text


def _parse_sets(pairs: Sequence[str]) -> Dict[str, object]:
    out: Dict[str, object] = {}
    for pair in pairs or ():
        if "=" not in pair:
            raise SystemExit(
                f"--set/--axis needs section.field=value, got {pair!r}")
        key, _, value = pair.partition("=")
        out[key.strip()] = _parse_value(value.strip())
    return out


def _load_spec(args, extra_sets: Optional[Dict[str, object]] = None) -> SystemSpec:
    spec = SystemSpec.load(args.spec) if args.spec else SystemSpec()
    overrides: Dict[str, object] = {}
    if getattr(args, "events", None) is not None:
        overrides["workload.events"] = args.events
    if getattr(args, "seed", None) is not None:
        overrides["workload.seed"] = args.seed
    overrides.update(_parse_sets(getattr(args, "set", None) or []))
    overrides.update(extra_sets or {})
    return spec.replace(**overrides) if overrides else spec


def _print_summary(report) -> None:
    s = report.summary
    print(f"executor={report.executor} mode={report.mode} "
          f"schema_version={report.schema_version}")
    keys = ("completed", "requests", "dispatches", "p50_s", "p95_s", "p99_s",
            "slo_attainment", "goodput_cost_per_s", "utilization",
            "replicas", "final_active", "cold_start_fraction", "wall_s")
    for k in keys:
        if k in s:
            v = s[k]
            if k in ("p50_s", "p95_s", "p99_s"):
                print(f"  {k:22s} {v * 1e3:12.3f} ms")
            elif k == "wall_s":
                print(f"  {k:22s} {v:12.3f} s")
            else:
                print(f"  {k:22s} {v:12.4g}")


# ------------------------------------------------------------------ simulate
def _check_live_report(report, spec) -> List[str]:
    """Schema/shape invariants for live reports — the wall clock makes
    byte equality meaningless, but the report contract is still checkable:
    versioned schema, the shared summary keys, and request accounting
    that adds up."""
    problems: List[str] = []
    m = report.metrics
    if report.schema_version != SCHEMA_VERSION:
        problems.append(f"schema_version {report.schema_version!r} != "
                        f"{SCHEMA_VERSION}")
    summary = m.get("summary")
    if not isinstance(summary, dict):
        problems.append("metrics.summary missing")
    else:
        for k in ("completed", "p50_s", "p95_s", "slo_attainment"):
            if k not in summary:
                problems.append(f"summary.{k} missing")
    sched = m.get("scheduler")
    if not isinstance(sched, dict):
        problems.append("metrics.scheduler missing")
    routed = m.get("routed_counts")
    if not isinstance(routed, list) or \
            len(routed) != spec.fleet.replicas:
        problems.append(f"routed_counts should list {spec.fleet.replicas} "
                        f"replicas, got {routed!r}")
    elif isinstance(sched, dict):
        admitted = sum(routed)
        # scheduler `rejected` counts every refusal (cap + infeasible)
        rejected = sched.get("rejected", 0)
        if admitted + rejected != spec.workload.events:
            problems.append(
                f"request accounting: routed {admitted} + rejected "
                f"{rejected} != {spec.workload.events} events offered")
        if sched.get("completed", 0) > admitted:
            problems.append(f"completed {sched['completed']} > admitted "
                            f"{admitted}")
    for k in ("arch", "engine", "wall_s"):
        if k not in m:
            problems.append(f"metrics.{k} missing")
    return problems


def cmd_simulate(args) -> int:
    spec = _load_spec(args)
    executor = spec.build()
    report = executor.run()
    _print_summary(report)
    if args.check:
        if spec.mode == "live":
            problems = _check_live_report(report, spec)
            print("live --check verifies report schema/shape invariants "
                  "(wall-clock runs are not byte-reproducible): "
                  f"{'OK' if not problems else 'FAILED'}")
            if problems:
                for p in problems:
                    print(f"CHECK FAILED: {p}", file=sys.stderr)
                return 1
        else:
            rerun = spec.build().run()
            identical = rerun.to_json() == report.to_json()
            print(f"same-seed rerun byte-identical: {identical}")
            if not identical:
                print("CHECK FAILED: rerun JSON differs (nondeterminism)",
                      file=sys.stderr)
                return 1
    if args.out:
        report.save(args.out)
        print(f"wrote {args.out}")
    return 0


# --------------------------------------------------------------------- serve
def cmd_serve(args) -> int:
    import dataclasses

    from repro.api.spec import ServeSpec
    from repro.launch.serve import run_server

    spec = ServeSpec.load(args.spec)
    overrides = {}
    if args.port is not None:
        overrides["port"] = args.port
    if args.report is not None:
        overrides["report_path"] = args.report
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    run_server(spec)
    return 0


# --------------------------------------------------------------------- trace
def cmd_trace(args) -> int:
    from repro.obs.trace_export import export_chrome_trace

    extra: Dict[str, object] = {"observability.enabled": True}
    if args.window is not None:
        extra["observability.window_s"] = args.window
    spec = _load_spec(args, extra_sets=extra)
    if spec.mode == "live":
        raise SystemExit(
            "trace drives the simulated executors (live runs can enable "
            "the recorder via observability.trace_path on the spec); "
            "set mode='sim'")
    executor = spec.build()
    executor.run_metrics()
    rec = executor.last_recorder
    text = export_chrome_trace(rec) + "\n"
    with open(args.out, "w") as fh:
        fh.write(text)
    print(f"wrote {args.out} ({rec.total_events()} recorded events) — "
          f"open it at ui.perfetto.dev or chrome://tracing")
    if args.telemetry:
        from repro.obs.telemetry import windowed_series

        series = windowed_series(rec, spec.observability.window_s)
        with open(args.telemetry, "w") as fh:
            fh.write(json.dumps(series, indent=2, sort_keys=True) + "\n")
        print(f"wrote {args.telemetry} ({series['windows']} windows of "
              f"{spec.observability.window_s * 1e3:g} ms)")
    if args.check:
        rerun = spec.build()
        rerun.run_metrics()
        identical = export_chrome_trace(rerun.last_recorder) + "\n" == text
        print(f"same-seed rerun trace byte-identical: {identical}")
        if not identical:
            print("CHECK FAILED: rerun trace differs (nondeterminism)",
                  file=sys.stderr)
            return 1
    return 0


# -------------------------------------------------------------------- report
def cmd_report(args) -> int:
    from repro.api.report import RunReport

    rep = RunReport.load(args.report)
    _print_summary(rep)
    sched = rep.metrics.get("scheduler")
    if isinstance(sched, dict):
        print("scheduler counters:")
        for k in sorted(sched):
            v = sched[k]
            if isinstance(v, list):
                print(f"  {k:22s} {v}")
            else:
                print(f"  {k:22s} {v:12.4g}")
    if not args.timeline:
        return 0
    t = rep.metrics.get("telemetry")
    if not isinstance(t, dict) or not t.get("windows"):
        raise SystemExit(
            "no telemetry in this report: re-run its spec with "
            "observability.enabled=true (e.g. `python -m repro simulate "
            "--spec ... --set observability.enabled=true --out ...`)")
    w_ms = t["window_s"] * 1e3
    print(f"timeline: {t['windows']} windows of {w_ms:g} ms "
          f"(t0 = {t['t0_s']:g} s)")
    print(f"{'win':>5s} {'arrive':>7s} {'reject':>7s} {'done':>7s} "
          f"{'p50 ms':>9s} {'p95 ms':>9s} {'attain':>7s} {'backlog':>8s} "
          f"{'util':>6s}")
    for k in range(t["windows"]):
        print(f"{k:5d} {t['arrivals'][k]:7d} {t['rejected'][k]:7d} "
              f"{t['completed'][k]:7d} {t['p50_ms'][k]:9.3f} "
              f"{t['p95_ms'][k]:9.3f} {t['slo_attainment'][k]:7.3f} "
              f"{t['backlog'][k]:8d} {t['utilization'][k]:6.2f}")
    return 0


# --------------------------------------------------------------------- sweep
def _cells(axes: List[Tuple[str, List[object]]]):
    names = [a[0] for a in axes]
    for combo in itertools.product(*(a[1] for a in axes)):
        label = "_".join(f"{n.split('.')[-1]}={v}" for n, v in zip(names, combo))
        yield label, dict(zip(names, combo))


def cmd_sweep(args) -> int:
    axes: List[Tuple[str, List[object]]] = []
    for pair in args.axis or ():
        key, _, values = pair.partition("=")
        if not values:
            raise SystemExit(f"--axis needs section.field=v1,v2,..., got {pair!r}")
        axes.append((key.strip(),
                     [_parse_value(v) for v in values.split(",") if v != ""]))
    if not axes:
        raise SystemExit("sweep needs at least one --axis section.field=v1,v2")

    base = _load_spec(args)
    cells = list(_cells(axes))
    print(f"sweep over {' x '.join(f'{k}[{len(v)}]' for k, v in axes)}: "
          f"{len(cells)} cells")
    if args.dry_run:
        for label, overrides in cells:
            base.replace(**overrides)  # validate every cell
            print(f"  {label}")
        print("dry run: all cells validate; re-run without --dry-run to "
              "execute")
        return 0

    sections = {}
    print(f"{'cell':>40s} {'p95 ms':>9s} {'attain':>7s} {'goodput':>10s}")
    for label, overrides in cells:
        spec = base.replace(**overrides)
        if spec.mode == "live":
            raise SystemExit("sweep drives the simulated executors; run "
                             "live cells one at a time with `simulate`")
        m = spec.build().run_metrics()
        sections[label] = m
        s = m.summary()
        print(f"{label:>40s} {s['p95_s'] * 1e3:9.3f} "
              f"{s['slo_attainment']:7.3f} {s['goodput_cost_per_s']:10.4g}")
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(to_bench_json(
                args.name, sections,
                extra={"spec": base.to_dict(),
                       "axes": {k: v for k, v in axes}}))
        print(f"wrote {args.json}")
    return 0


# ----------------------------------------------------------------- calibrate
def cmd_calibrate(args) -> int:
    from repro.api.build import build_mix, build_trace
    from repro.sim.costmodel import CalibratedCostModel

    spec = _load_spec(args)
    mix = build_mix(spec.workload)
    non_kernel = sorted({s.kind for s in mix} - {"kernel"})
    if non_kernel:
        raise SystemExit(
            f"calibrate drives real GEMM dispatches, so it needs a kernel "
            f"mix (sgemm / fleet / single); {spec.workload.mix!r} contains "
            f"{non_kernel} workloads")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DynamicSpaceTimeScheduler, GemmProblem

    model = CalibratedCostModel(ewma_alpha=spec.cost_model.ewma_alpha)
    sched = DynamicSpaceTimeScheduler(
        spec.scheduler.to_schedule_config() if spec.scheduler else None,
        on_dispatch=model.observe)

    # device-resident operands per (tenant, bucket): weights per tenant,
    # a small rotation of activations per bucket shape
    key = jax.random.PRNGKey(spec.workload.seed)
    rng = np.random.default_rng(spec.workload.seed)
    xs: Dict[object, List] = {}
    ws: Dict[Tuple[int, object], object] = {}
    for i, t in enumerate(mix):
        b = t.bucket
        if b not in xs:
            xs[b] = [jax.random.normal(jax.random.fold_in(key, 1000 + 8 * i + j),
                                       (b.M, b.K), jnp.float32)
                     for j in range(4)]
        ws[(t.tenant_id, b)] = jax.random.normal(
            jax.random.fold_in(key, i), (b.K, b.N), jnp.float32)

    submitted = 0
    for ev in build_trace(spec, mix):
        t = ev.spec
        sched.submit(GemmProblem(
            tenant_id=t.tenant_id,
            x=xs[t.bucket][int(rng.integers(len(xs[t.bucket])))],
            w=ws[(t.tenant_id, t.bucket)],
            slo_s=t.slo_s))
        sched.pump()
        submitted += 1
    sched.flush()

    model.save(args.out)
    print(f"calibrated {len(model.table)} (bucket, pow2-R) keys from "
          f"{submitted} live arrivals -> {args.out}")
    print(f"replay them with: cost_model.kind=calibrated "
          f"cost_model.calibration_path={args.out}")
    return 0


# --------------------------------------------------------------------- check
def cmd_check(args) -> int:
    from repro.api.build import build_mix, resolve_rate_hz

    spec = _load_spec(args)
    print(f"spec OK (schema_version {SCHEMA_VERSION}): "
          f"{args.spec or '<defaults>'}")
    executor = spec.build()
    w, f = spec.workload, spec.fleet
    print(f"  mode={spec.mode} -> executor: {executor.executor}")
    line = f"  workload: mix={w.mix} tenants={w.tenants} process={w.process}"
    if w.process == "replay":
        line += f" csv={w.csv_path}"
    else:
        line += f" events={w.events} seed={w.seed}"
    print(line)
    if spec.mode != "live" and w.process != "replay":
        rate = resolve_rate_hz(spec, build_mix(w))
        anchor = (f"rho={w.rho}" if w.rate_hz is None
                  else "explicit rate_hz")
        print(f"  offered load: ~{rate:,.0f} arrivals/s ({anchor})")
    if f.is_fleet:
        hw = ",".join(f.specs) if f.specs else spec.cost_model.hardware
        scale = (f", autoscale {f.autoscale.policy} "
                 f"{f.autoscale.min_replicas}..{f.autoscale.max_replicas}"
                 if f.autoscale else "")
        print(f"  fleet: {f.replicas} replica(s) of [{hw}], "
              f"router={spec.router.policy}{scale}")
    elif spec.mode == "live":
        print(f"  live engine: arch={w.arch} tenants={w.tenants} "
              f"requests={w.events} (prompt {w.prompt_tokens}, "
              f"decode {w.max_new_tokens})")
    else:
        print(f"  solo replica on {spec.cost_model.hardware}")
    cm = spec.cost_model
    cold = f", cold-start compile {cm.compile_us:g}us" if cm.compile_us else ""
    table = (f", table={cm.calibration_path}" if cm.kind == "calibrated"
             else "")
    print(f"  cost model: {cm.kind} on {cm.hardware}, "
          f"strategy={cm.strategy}{cold}{table}")
    sched = spec.scheduler
    if sched is None:
        print("  scheduler: executor defaults")
    else:
        print(f"  scheduler: window={sched.batching_window_s * 1e3:g}ms "
              f"({sched.batching_policy}), "
              f"max_superkernel_size={sched.max_superkernel_size}")
    if spec.partition is not None:
        from repro.api.build import build_partition

        plan, _ = build_partition(spec, build_mix(w))
        replan = (f", replan every {spec.partition.replan_interval_s:g}s"
                  if spec.partition.replan_interval_s > 0 else "")
        print(f"  partition: policy={spec.partition.policy}, "
              f"{len(plan.groups)} slice(s) per replica{replan}")
        for g in plan.groups:
            win = (f", window={g.window_s * 1e3:g}ms"
                   if g.window_s is not None else "")
            print(f"    {g.name}: share={g.share:.4g} "
                  f"tenants={list(g.tenants)}{win}")
    return 0


# --------------------------------------------------------------------- specs
def cmd_specs(args) -> int:
    from repro.launch.roofline import HARDWARE_SPECS
    from repro.api.spec import AUTOSCALERS, PARTITION_POLICIES

    doc = {
        "schema_version": SCHEMA_VERSION,
        "hardware": {
            name: {"peak_tflops": hw.peak_flops / 1e12,
                   "hbm_gb_s": hw.hbm_bw / 1e9}
            for name, hw in sorted(HARDWARE_SPECS.items())},
        "mixes": list(MIXES),
        "processes": list(PROCESSES),
        "routers": list(ROUTERS),
        "autoscalers": list(AUTOSCALERS),
        "strategies": list(STRATEGIES),
        "partition_policies": list(PARTITION_POLICIES),
        "modes": list(MODES),
    }
    if args.json:
        print(json.dumps(doc, indent=2, sort_keys=True))
        return 0
    print(f"spec schema_version: {SCHEMA_VERSION}")
    print("hardware (cost_model.hardware / fleet.specs):")
    for name, hw in doc["hardware"].items():
        print(f"  {name:12s} {hw['peak_tflops']:8.1f} TFLOP/s "
              f"{hw['hbm_gb_s']:8.0f} GB/s HBM")
    for label, key in (("mixes (workload.mix)", "mixes"),
                       ("processes (workload.process)", "processes"),
                       ("routers (router.policy)", "routers"),
                       ("autoscalers (fleet.autoscale.policy)", "autoscalers"),
                       ("strategies (cost_model.strategy)", "strategies"),
                       ("partition policies (partition.policy)",
                        "partition_policies"),
                       ("modes (mode)", "modes")):
        print(f"{label}: {', '.join(doc[key])}")
    return 0


# ---------------------------------------------------------------------- main
def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="One front door over the repo's live, sim, and fleet "
                    "execution paths, driven by declarative SystemSpec JSON "
                    "(see examples/specs/).")
    sub = ap.add_subparsers(dest="command", required=True)

    def add_spec_args(p, events_help="override workload.events"):
        p.add_argument("--spec", default=None,
                       help="SystemSpec JSON file (default: built-in defaults)")
        p.add_argument("--set", action="append", metavar="FIELD=VALUE",
                       help="override any spec field by dotted path, e.g. "
                            "--set router.policy=least_cost")
        p.add_argument("--events", type=int, default=None, help=events_help)
        p.add_argument("--seed", type=int, default=None,
                       help="override workload.seed")

    p = sub.add_parser("simulate", help="run one spec, print the summary")
    add_spec_args(p)
    p.add_argument("--out", default=None, help="write the RunReport JSON here")
    p.add_argument("--check", action="store_true",
                   help="run twice and fail unless metrics JSON is "
                        "byte-identical (sim determinism gate)")
    p.set_defaults(func=cmd_simulate)

    p = sub.add_parser("serve",
                       help="HTTP front door over a live fleet "
                            "(/healthz, /v1/predict, /v1/report)")
    p.add_argument("--spec", required=True, help="ServeSpec JSON file")
    p.add_argument("--port", type=int, default=None,
                   help="override serve.port (0 picks a free port)")
    p.add_argument("--report", default=None,
                   help="override serve.report_path (RunReport JSON "
                        "written on graceful shutdown)")
    p.set_defaults(func=cmd_serve)

    p = sub.add_parser("trace",
                       help="run with the flight recorder on, export a "
                            "Perfetto-loadable Chrome trace")
    add_spec_args(p)
    p.add_argument("--out", default="trace.json",
                   help="write the Chrome trace_event JSON here "
                        "(default: trace.json)")
    p.add_argument("--telemetry", default=None,
                   help="also write the windowed telemetry series JSON here")
    p.add_argument("--window", type=float, default=None,
                   help="telemetry window in seconds "
                        "(override observability.window_s)")
    p.add_argument("--check", action="store_true",
                   help="re-run same-seed and fail unless the exported "
                        "trace is byte-identical")
    p.set_defaults(func=cmd_trace)

    p = sub.add_parser("report",
                       help="inspect a saved RunReport (summary, scheduler "
                            "counters, --timeline telemetry)")
    p.add_argument("report", help="RunReport JSON file (simulate --out)")
    p.add_argument("--timeline", action="store_true",
                   help="print the windowed telemetry table")
    p.set_defaults(func=cmd_report)

    p = sub.add_parser("sweep", help="grid over spec fields")
    add_spec_args(p)
    p.add_argument("--axis", action="append", metavar="FIELD=V1,V2,...",
                   help="sweep axis by dotted path (repeatable; cells are "
                        "the cross product)")
    p.add_argument("--json", default=None, help="write BENCH-style JSON here")
    p.add_argument("--name", default="repro_sweep",
                   help="benchmark name in the JSON document")
    p.add_argument("--dry-run", action="store_true",
                   help="validate and list the cells without running")
    p.set_defaults(func=cmd_sweep)

    p = sub.add_parser("calibrate",
                       help="fit a measured-cost table from live dispatches")
    add_spec_args(p, events_help="live arrivals to fit from")
    p.add_argument("--out", required=True,
                   help="write the CalibratedCostModel JSON here")
    p.set_defaults(func=cmd_calibrate)

    p = sub.add_parser("check", help="validate a spec and print the plan")
    add_spec_args(p)
    p.set_defaults(func=cmd_check)

    p = sub.add_parser("specs", help="list registered names specs can use")
    p.add_argument("--json", action="store_true", help="machine-readable")
    p.set_defaults(func=cmd_specs)
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.func(args)
    except (TypeError, ValueError) as e:
        # spec validation errors are user errors: one actionable line, no
        # traceback. TypeError covers mistyped JSON values ("tenants":
        # "8") surfacing from dataclass __post_init__ comparisons.
        print(f"spec error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
