"""Jit'd dispatch layer over the Pallas kernels.

Backend policy:
    * On TPU: compile the Pallas kernels (interpret=False).
    * On CPU: route to the pure-jnp reference by default — XLA:CPU executes
      the fused einsum far faster than interpret-mode grid emulation, and
      the 512-device dry-run must not unroll interpret grids into HLO.
    * ``REPRO_FORCE_PALLAS=1`` (or force_pallas=True) forces interpret-mode
      Pallas on CPU — used by the kernel-vs-oracle test sweeps.

Every public op takes/returns plain arrays so the scheduler, models and
serving engine never branch on backend themselves.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import batched_gemm as _bg
from repro.kernels import grouped_gemm as _gg
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import wkv6_scan as _wkv


def _use_pallas(force_pallas: Optional[bool]) -> bool:
    if force_pallas is not None:
        return force_pallas
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def batched_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = _bg.DEFAULT_BM,
    bn: int = _bg.DEFAULT_BN,
    bk: int = _bg.DEFAULT_BK,
    force_pallas: Optional[bool] = None,
) -> jax.Array:
    """Space-time super-kernel: out[r] = x[r] @ w[r]."""
    if _use_pallas(force_pallas):
        return _bg.batched_gemm(x, w, bm=bm, bn=bn, bk=bk, interpret=_interpret())
    return ref.batched_gemm(x, w)


def grouped_gemm(
    x: jax.Array,
    w: jax.Array,
    block_groups: jax.Array,
    *,
    bm: int = _gg.DEFAULT_BM,
    bn: int = _gg.DEFAULT_BN,
    bk: int = _gg.DEFAULT_BK,
    force_pallas: Optional[bool] = None,
) -> jax.Array:
    """Ragged super-kernel / MoE expert GEMM."""
    if _use_pallas(force_pallas):
        return _gg.grouped_gemm(
            x, w, block_groups, bm=bm, bn=bn, bk=bk, interpret=_interpret()
        )
    return ref.grouped_gemm(x, w, block_groups, bm)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    logit_softcap: float = 0.0,
    force_pallas: Optional[bool] = None,
) -> jax.Array:
    """Prefill attention (GQA, causal, optional sliding window)."""
    # softcap only implemented on the reference path; gemma3 uses it on
    # logits — the Pallas kernel handles the common no-softcap fast path.
    if logit_softcap == 0.0 and _use_pallas(force_pallas):
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=_interpret(),
        )
    # XLA path: dense reference for short sequences (fast, exact tests),
    # chunked online-softmax beyond — a (B,H,S,S) score tensor at 32k+ ctx
    # is unlowerable.
    if k.shape[2] > 2048:
        return ref.attention_chunked(
            q, k, v, causal=causal, window=window, scale=scale,
            logit_softcap=logit_softcap,
        )
    return ref.attention(
        q, k, v, causal=causal, window=window, scale=scale,
        logit_softcap=logit_softcap,
    )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    force_pallas: Optional[bool] = None,
) -> jax.Array:
    """One-token decode against a KV cache."""
    if _use_pallas(force_pallas):
        return _da.decode_attention(
            q, k_cache, v_cache, lengths, scale=scale, interpret=_interpret()
        )
    return ref.decode_attention(q, k_cache, v_cache, lengths, scale=scale)


def wkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = _wkv.DEFAULT_CHUNK,
    force_pallas: Optional[bool] = None,
) -> jax.Array:
    """RWKV6 recurrence over a full sequence."""
    if _use_pallas(force_pallas):
        return _wkv.wkv6_scan(r, k, v, w, u, chunk=chunk, interpret=_interpret())
    return ref.wkv6_scan(r, k, v, w, u)


wkv6_step = ref.wkv6_step  # decode step is a handful of VPU ops; jnp is fine
