"""Slot-based multi-tenant cache management.

Each tenant owns ``num_slots`` sequence slots inside the stacked cache
pytree (leading axes: [tenant, ..., batch=slot, ...]). The manager tracks
slot occupancy and per-slot live lengths; freeing a slot just zeroes its
length (the decode kernels mask by length, so stale data is never read).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class SlotState:
    request_id: Optional[int] = None
    length: int = 0

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    def __init__(self, num_tenants: int, slots_per_tenant: int):
        self.slots: Dict[Tuple[int, int], SlotState] = {
            (t, s): SlotState()
            for t in range(num_tenants)
            for s in range(slots_per_tenant)
        }
        self.num_tenants = num_tenants
        self.slots_per_tenant = slots_per_tenant

    def acquire(self, tenant: int, request_id: int) -> Optional[int]:
        for s in range(self.slots_per_tenant):
            st = self.slots[(tenant, s)]
            if st.free:
                st.request_id = request_id
                st.length = 0
                return s
        return None

    def release(self, tenant: int, slot: int) -> None:
        self.slots[(tenant, slot)] = SlotState()

    def set_length(self, tenant: int, slot: int, length: int) -> None:
        self.slots[(tenant, slot)].length = length

    def lengths(self, tenant: int) -> List[int]:
        return [self.slots[(tenant, s)].length for s in range(self.slots_per_tenant)]

    def active(self, tenant: int) -> List[int]:
        return [
            s for s in range(self.slots_per_tenant) if not self.slots[(tenant, s)].free
        ]

    def utilization(self) -> float:
        busy = sum(0 if s.free else 1 for s in self.slots.values())
        return busy / len(self.slots)
