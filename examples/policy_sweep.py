"""Policy sweep on the trace-driven simulator — no device work, seconds
on CPU, deterministic per seed.

Demonstrates the declarative `repro.api` workflow end-to-end: ONE base
``SystemSpec`` per tenant mix, ``replace()``d across arrival processes
(steady Poisson, bursty MMPP, diurnal, flash crowd) and batching
policies, every cell replayed through the REAL DynamicSpaceTimeScheduler
on a virtual clock and priced by the roofline cost model.

The point the sweep makes: neither window policy dominates. On the
serving mix (tight decode SLOs against a wide window) the adaptive
window buys large attainment gains; on the kernel mix under saturating
bursts it can LOSE throughput by giving up merging exactly when merging
matters most. Latency predictability is a policy property — which is why
these sweeps run in simulation, where the whole surface costs seconds.

Equivalent CLI for one row of this grid:

    PYTHONPATH=src python -m repro sweep --spec examples/specs/paper_mix.json \
        --axis workload.process=poisson,mmpp,diurnal,flash \
        --axis scheduler.batching_policy=fixed,slo_adaptive

    PYTHONPATH=src python examples/policy_sweep.py
"""

from repro.api import SchedulerSpec, SystemSpec, WorkloadSpec, build_mix

EVENTS = 30_000
SEED = 0


def sweep(mix_name: str, tenants: int, rho: float) -> None:
    # offered load anchored to the mix's merged-roofline capacity (the
    # spec's rho semantics), so one rho means the same pressure for
    # FLOP-priced GEMMs and byte-priced decode cohorts alike
    base = SystemSpec(
        workload=WorkloadSpec(mix=mix_name, tenants=tenants, events=EVENTS,
                              seed=SEED, rho=rho),
        scheduler=SchedulerSpec(max_superkernel_size=64),
    )
    mix = build_mix(base.workload)
    # a window wide enough to threaten the tightest SLO tier, so the
    # adaptive policy has a violation budget to win back
    base = base.replace(**{
        "scheduler.batching_window_s": 0.5 * min(s.slo_s for s in mix)})
    print(f"\n=== mix={mix_name} @ rho={rho:.2f}, {EVENTS} events/cell ===")
    print(f"{'process':>9s} {'policy':>13s} {'p50 ms':>8s} {'p95 ms':>8s} "
          f"{'attain':>7s} {'goodput':>10s}")
    for process in ("poisson", "mmpp", "diurnal", "flash"):
        for policy in ("fixed", "slo_adaptive"):
            m = base.replace(**{
                "workload.process": process,
                "scheduler.batching_policy": policy,
            }).build().run_metrics()
            s = m.summary()
            print(f"{process:>9s} {policy:>13s} {s['p50_s']*1e3:8.3f} "
                  f"{s['p95_s']*1e3:8.3f} {s['slo_attainment']:7.3f} "
                  f"{s['goodput_cost_per_s']:10.3g}")


def main() -> None:
    # kernel-level tenants: steady load leaves slack, only bursts bite
    sweep("sgemm", tenants=8, rho=0.6)
    # engine-shaped cohorts: decode steps dominate arrivals, prefills are
    # rare and heavy — the realistic serving mix
    sweep("serving", tenants=4, rho=0.6)


if __name__ == "__main__":
    main()
