"""Grouped (variable-size batched) GEMM — the MAGMA-vbatched analogue.

The paper notes that ``cublasSgemmBatched`` requires uniform problem shapes
and points at MAGMA's variable-size batched SGEMM as the generalization.
On TPU we express the ragged batch as a *group-aligned row layout*:

    x:   (T, K)   rows sorted by group, each group zero-padded to a multiple
                  of the row-block size bm
    w:   (G, K, N) one weight matrix per group
    block_groups: (T/bm,) int32 — which group each row-block belongs to

One pallas_call then computes ``out[t] = x[t] @ w[group_of(t)]`` with the
group id scalar-prefetched so the weight BlockSpec can index it. This is
also exactly the MoE expert-FFN compute pattern (groups = experts), so the
same kernel serves both the scheduler's ragged super-kernels and MoE layers.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _grouped_kernel(block_groups_ref, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def grouped_gemm(
    x: jax.Array,
    w: jax.Array,
    block_groups: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    out_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """out[i*bm:(i+1)*bm] = x[i*bm:(i+1)*bm] @ w[block_groups[i]].

    Args:
        x: (T, K) group-sorted, group-aligned rows (T % bm == 0).
        w: (G, K, N) per-group weights.
        block_groups: (T // bm,) int32 group index per row block.
    Returns:
        (T, N).
    """
    T, K = x.shape
    G, Kw, N = w.shape
    if Kw != K:
        raise ValueError(f"K mismatch: x {x.shape} vs w {w.shape}")
    out_dtype = out_dtype or x.dtype

    bm_ = min(bm, T)
    bn_ = min(bn, N)
    bk_ = min(bk, K)
    if T % bm_ != 0:
        raise ValueError(f"rows T={T} must be a multiple of the row block {bm_}")
    Np = pl.cdiv(N, bn_) * bn_
    Kp = pl.cdiv(K, bk_) * bk_
    if (Np, Kp) != (N, K):
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))

    num_blocks = T // bm_
    grid = (num_blocks, Np // bn_, Kp // bk_)

    out = pl.pallas_call(
        _grouped_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, k, gids: (i, k)),
                pl.BlockSpec((1, bk_, bn_), lambda i, j, k, gids: (gids[i], k, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k, gids: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((T, Np), out_dtype),
        interpret=interpret,
    )(block_groups.astype(jnp.int32), x, w)
    return out[:, :N]


def make_group_layout(
    group_sizes: np.ndarray, bm: int = DEFAULT_BM
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host-side helper: padded row offsets + per-block group ids.

    Given per-group row counts, returns (row_offsets, block_groups, T_padded)
    where each group's rows are padded up to a multiple of ``bm`` so blocks
    never straddle a group boundary.
    """
    group_sizes = np.asarray(group_sizes, dtype=np.int64)
    padded = ((group_sizes + bm - 1) // bm) * bm
    offsets = np.concatenate([[0], np.cumsum(padded)])
    block_groups = np.repeat(np.arange(len(group_sizes)), padded // bm).astype(np.int32)
    return offsets.astype(np.int64), block_groups, int(offsets[-1])
