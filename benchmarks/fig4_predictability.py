"""Figure 4: inter-tenant latency predictability.

Paper: under MPS space-only sharing, co-located tenants diverge by up to
25% (worse with odd tenant counts) — unpredictability caused by the device
scheduler. Claim for space-time: a merged super-kernel gives every tenant
the SAME step latency by construction; the residual spread comes only from
the queueing layer.

Measured here: per-tenant mean step latency spread under (a) the engine's
time_only mode (each tenant dispatched separately — spread reflects
dispatch jitter and model-order position) vs (b) space_time mode (one
merged program).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def run(r: int = 5, steps: int = 16, csv_rows=None):
    # odd tenant count on purpose — the paper's worst case for MPS
    print(f"\n=== Fig 4: inter-tenant latency spread (R={r}, odd) ===")
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")), dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    rng = np.random.RandomState(0)
    params = [m.init(jax.random.fold_in(key, t)) for t in range(r)]

    for mode in ("time_only", "space_time"):
        eng = MultiTenantEngine(
            m, params,
            EngineConfig(num_tenants=r, slots_per_tenant=1, cache_len=64, mode=mode),
        )
        # per-tenant wall-clock accounting for time_only needs separate timing;
        # reuse the engine's monitor which records per-step latency per tenant.
        for t in range(r):
            eng.submit(InferenceRequest(
                tenant_id=t, prompt=list(rng.randint(1, cfg.vocab_size, 8)),
                max_new_tokens=steps))
        eng.run_until_drained()
        spread = eng.monitor.predictability_spread()
        rep = eng.report()
        print(f"{mode:11s}: spread={spread:7.2%}  p95/p50="
              f"{rep['p95_s']/max(rep['p50_s'],1e-12):5.2f}")
        if csv_rows is not None:
            csv_rows.append((f"fig4/{mode}/spread", spread * 100, "pct (paper MPS: 25%)"))


if __name__ == "__main__":
    run()
