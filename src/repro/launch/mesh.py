"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the 512-device
XLA_FLAGS override belongs exclusively to dryrun.py's first two lines.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Degenerate 1-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, model), ("data", "model"))
