"""batched_gemm (the space-time super-kernel) vs the jnp oracle:
shape x dtype sweep in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.batched_gemm import batched_gemm

SHAPES = [
    # (R, M, K, N) — includes the paper's Table-1 geometries
    (2, 512, 512, 1),        # RNN matvec
    (4, 256, 1152, 128),     # ResNet-18 conv2_2 im2col
    (3, 256, 256, 256),      # square
    (1, 128, 128, 128),      # single problem degenerates to plain GEMM
    (5, 100, 70, 33),        # ragged, forces padding in every dim
    (8, 16, 512, 16),        # tiny M/N, deep K
]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(shape, dtype, rng_key):
    R, M, K, N = shape
    k1, k2 = jax.random.split(rng_key)
    x = jax.random.normal(k1, (R, M, K), dtype)
    w = jax.random.normal(k2, (R, K, N), dtype)
    got = batched_gemm(x, w, interpret=True)
    want = ref.batched_gemm(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * K ** 0.5,
    )
    assert got.dtype == x.dtype


@pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 128, 512), (32, 16, 256)])
def test_block_shape_invariance(blocks, rng_key):
    """Output must not depend on the BlockSpec tiling."""
    bm, bn, bk = blocks
    x = jax.random.normal(rng_key, (3, 200, 300), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(rng_key, 1), (3, 300, 96), jnp.float32)
    got = batched_gemm(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.batched_gemm(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


def test_problem_independence(rng_key):
    """Each sub-problem's output depends only on its own tenant's data —
    the isolation property of the merged super-kernel."""
    x = jax.random.normal(rng_key, (4, 64, 64), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(rng_key, 1), (4, 64, 64), jnp.float32)
    base = batched_gemm(x, w, interpret=True)
    x2 = x.at[2].set(jax.random.normal(jax.random.fold_in(rng_key, 7), (64, 64)))
    pert = batched_gemm(x2, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(pert[0]))
    np.testing.assert_array_equal(np.asarray(base[1]), np.asarray(pert[1]))
    np.testing.assert_array_equal(np.asarray(base[3]), np.asarray(pert[3]))
    assert not np.allclose(np.asarray(base[2]), np.asarray(pert[2]))
