"""Deadline-aware scheduling: EDF drain order, feasibility admission with
bounded oversubscription, budget-bounded preemption, and their recorder
integration.

The SystemSpec-level tests drive the full simulator (the EDF-beats-the-
baselines ordering the CI ``deadline-gate`` pins at benchmark scale); the
preemption tests drive the scheduler directly on a ``VirtualClock`` with
a constant cost model so the at-risk predicate is exact arithmetic, not
an emergent property of a trace."""

import pytest

from repro.api import SchedulerSpec, SystemSpec, WorkloadSpec
from repro.config import ScheduleConfig
from repro.core import DynamicSpaceTimeScheduler, VirtualClock, Workload
from repro.obs.recorder import ReplicaShard
from repro.sim import (
    MarkovModulatedTrace,
    RooflineCostModel,
    estimate_capacity_hz,
    prefill_decode_mix,
    simulate,
)

EST_S = 0.002  # constant priced service time for the direct-drive tests


def _overload_spec(events=6000, seed=0, rho=1.15, **sched):
    return SystemSpec(
        workload=WorkloadSpec(mix="serving", tenants=6, process="mmpp",
                              events=events, seed=seed, rho=rho),
        scheduler=SchedulerSpec(batching_window_s=0.002,
                                max_superkernel_size=64, **sched),
    )


def _edf_sched(slo_s=None, **overrides):
    """Scheduler wired for exact preemption arithmetic: 10ms window,
    lead 0 (items ripen a full window after arrival), constant 2ms cost."""
    cfg = dict(batching_window_s=0.010, batching_policy="edf",
               deadline_lead_fraction=0.0, preemption=True,
               preemption_budget_s=0.010)
    cfg.update(overrides)
    clock = VirtualClock()
    sched = DynamicSpaceTimeScheduler(
        ScheduleConfig(**cfg), clock=clock,
        cost_model=lambda batch: EST_S * len(batch))
    return sched, clock


def _item(tenant=0, slo_s=0.008, bucket=("b",)):
    return Workload(tenant_id=tenant, bucket=bucket, slo_s=slo_s,
                    execute=lambda batch: [None] * len(batch))


class TestEDFOrdering:
    def test_edf_beats_fixed_and_adaptive_under_mmpp_overload(self):
        """The tentpole ordering, at test scale: under MMPP overload the
        full deadline stack (EDF drain + feasibility admission) attains
        strictly more SLOs than either blind-cap policy."""
        attain = {}
        for policy in ("fixed", "slo_adaptive"):
            m = _overload_spec(batching_policy=policy).build().run_metrics()
            attain[policy] = m.slo_attainment
        edf = _overload_spec(batching_policy="edf",
                             admission_policy="feasibility",
                             oversubscription=1.25).build().run_metrics()
        assert edf.slo_attainment > attain["fixed"]
        assert edf.slo_attainment > attain["slo_adaptive"]
        # the machinery actually engaged: infeasible work was turned away
        # and some late-but-within-budget work rode the oversubscription
        assert edf.deadline_rejected > 0
        assert edf.oversubscribed > 0

    def test_same_seed_edf_byte_identical(self):
        spec = _overload_spec(events=3000, batching_policy="edf",
                              admission_policy="feasibility")
        a = spec.build().run_metrics().to_json()
        b = spec.build().run_metrics().to_json()
        assert a == b

    def test_edf_drains_earliest_deadline_first(self):
        """Two ripe buckets must dispatch in deadline order regardless of
        submit order."""
        sched, clock = _edf_sched(preemption=False)
        order = []
        loose = Workload(tenant_id=0, bucket=("loose",), slo_s=0.050,
                         execute=lambda b: order.append("loose") or [None])
        tight = Workload(tenant_id=1, bucket=("tight",), slo_s=0.020,
                         execute=lambda b: order.append("tight") or [None])
        sched.submit(loose)
        sched.submit(tight)
        clock.advance(0.011)  # both past the 10ms ripen point
        sched.pump()
        assert order == ["tight", "loose"]


class TestFeasibilityAdmission:
    def test_infeasible_deadline_rejected(self):
        """An item whose priced completion cannot make its deadline (even
        with zero queue) is rejected, with the dedicated counter + reason
        code; a feasible one is admitted."""
        sched, _ = _edf_sched(admission_policy="feasibility",
                              preemption=False)
        assert not sched.submit(_item(slo_s=0.001))   # est 2ms > slo 1ms
        assert sched.stats.rejected == 1
        assert sched.stats.deadline_rejected == 1
        assert sched.admit_reason == 3
        assert sched.submit(_item(slo_s=0.050))
        assert sched.admit_reason == 0

    def test_oversubscription_admits_bounded_lateness(self):
        """With oversubscription 2.0, predicted lateness up to one extra
        SLO is admitted (and counted); beyond that, rejected."""
        sched, _ = _edf_sched(admission_policy="feasibility",
                              oversubscription=2.0, preemption=False)
        # est 2ms, slo 1.5ms: predicted 2ms > dl 1.5ms but <= 3ms budget
        assert sched.submit(_item(slo_s=0.0015))
        assert sched.admit_reason == 1
        assert sched.stats.oversubscribed == 1
        # committed horizon now 2ms; another 1.5ms-SLO item predicts 4ms
        # > 3ms budget -> rejected
        assert not sched.submit(_item(slo_s=0.0015))
        assert sched.stats.deadline_rejected == 1

    def test_feasibility_requires_cost_model(self):
        with pytest.raises(ValueError, match="cost_model"):
            DynamicSpaceTimeScheduler(
                ScheduleConfig(admission_policy="feasibility"))

    def test_rejections_land_in_recorder(self):
        """Every admission decision is a recorder row: rejected arrivals
        carry reason 3, oversubscribed admits reason 1, and the column
        counts reconcile with the scheduler counters."""
        spec = _overload_spec(events=2500, batching_policy="edf",
                              admission_policy="feasibility",
                              oversubscription=1.25)
        spec = spec.replace(**{"observability.enabled": True})
        r = spec.build()
        m = r.run_metrics()
        assert m.deadline_rejected > 0 and m.oversubscribed > 0
        shard = r.last_recorder.shards[0]
        rejected = [i for i, adm in enumerate(shard._arr_admitted) if not adm]
        assert len(rejected) == m.deadline_rejected
        assert all(shard._arr_reason[i] == 3 for i in rejected)
        oversub = [i for i, reason in enumerate(shard._arr_reason)
                   if reason == 1]
        assert len(oversub) == m.oversubscribed
        assert all(shard._arr_admitted[i] for i in oversub)


class TestPreemption:
    def test_fires_only_when_deadline_infeasible(self):
        """slo 8ms < ripen point 10ms: waiting out the window guarantees a
        miss, so the unripe cohort force-dispatches now. A relaxed twin
        (slo 100ms) stays queued until ripe."""
        sched, clock = _edf_sched()
        sched.submit(_item(slo_s=0.008))
        done = sched.pump()  # now=0: ripe_at+est=12ms > dl=8ms, now+est ok
        assert len(done) == 1
        assert sched.stats.preemptions == 1

        sched.submit(_item(slo_s=0.100))  # dl 100ms >> ripen 10ms: feasible
        assert sched.pump() == []
        assert sched.stats.preemptions == 1
        clock.advance(0.011)
        assert len(sched.pump()) == 1  # normal ripe dispatch, no preempt
        assert sched.stats.preemptions == 1

    def test_no_preemption_when_already_hopeless(self):
        """Force-dispatch only helps if the deadline is still makeable:
        once now + est > deadline the item waits for its window like any
        other (no interference spent on a lost cause)."""
        sched, clock = _edf_sched()
        sched.submit(_item(slo_s=0.008))
        clock.advance(0.007)  # now+est = 9ms > dl 8ms, and not yet ripe
        assert sched.pump() == []
        assert sched.stats.preemptions == 0

    def test_budget_bounds_interference(self):
        """Each preemption charges its priced service time against the
        tenant's lifetime budget; once exhausted, no further preemptions
        for that tenant — but other tenants keep their own budget."""
        sched, clock = _edf_sched(preemption_budget_s=2 * EST_S)
        for k in range(3):
            clock.advance(0.0001)
            sched.submit(_item(tenant=0, slo_s=0.008))
            sched.pump()
        assert sched.stats.preemptions == 2  # third exceeded the budget
        clock.advance(0.0001)
        sched.submit(_item(tenant=1, slo_s=0.008, bucket=("b2",)))
        sched.pump()
        assert sched.stats.preemptions == 3

    def test_preemptions_land_in_recorder(self):
        sched, _ = _edf_sched()
        shard = ReplicaShard(0)
        sched.recorder = shard
        sched.submit(_item(slo_s=0.008))
        sched.pump()
        assert sched.stats.preemptions == 1
        assert shard.n_preemptions == 1
        assert list(shard._pre_tenant) == [0]
        assert shard._pre_est[0] == pytest.approx(EST_S)


class TestConfigValidation:
    def test_preemption_requires_edf(self):
        with pytest.raises(ValueError, match="preemption"):
            ScheduleConfig(preemption=True)

    def test_edf_incompatible_with_ragged_merge(self):
        with pytest.raises(ValueError, match="allow_ragged_merge"):
            ScheduleConfig(batching_policy="edf", allow_ragged_merge=True)

    def test_oversubscription_floor(self):
        with pytest.raises(ValueError, match="oversubscription"):
            ScheduleConfig(oversubscription=0.9)

    def test_live_mode_accepts_feasibility(self):
        # live replicas run the same scheduler core as the simulator, so
        # feasibility admission is now valid there (it needs the spec's
        # cost model, which the live fleet builds per replica)
        run = SystemSpec(mode="live",
                         scheduler=SchedulerSpec(
                             admission_policy="feasibility")).build()
        assert run.executor == "live"

    def test_sharded_fleet_rejects_feasibility(self):
        from repro.api.spec import FleetSpec
        with pytest.raises(ValueError, match="workers"):
            SystemSpec(fleet=FleetSpec(replicas=2, workers=2),
                       scheduler=SchedulerSpec(
                           batching_policy="fixed",
                           admission_policy="feasibility")).build()


class TestMonotoneAttainment:
    def test_attainment_monotone_in_offered_load(self):
        """Property: on one fixed MMPP arrival trace, scaling every priced
        and simulated service time by ``scale`` (i.e. raising offered load
        rho = lambda * E[S]) never raises SLO attainment under the full
        EDF + feasibility stack. Identical trace per pair, so this is the
        scheduler's monotonicity, not sampling noise."""
        hypothesis = pytest.importorskip("hypothesis")
        given = hypothesis.given
        st = hypothesis.strategies
        hypothesis.settings.register_profile(
            "deadline", max_examples=12, deadline=None)
        hypothesis.settings.load_profile("deadline")

        mix = prefill_decode_mix(4)
        base = RooflineCostModel(strategy="space_time")
        rate = 0.9 * estimate_capacity_hz(mix, base)
        cache = {}

        def attainment(scale):
            if scale not in cache:
                m = simulate(
                    MarkovModulatedTrace(mix, calm_hz=0.5 * rate,
                                         burst_hz=2.0 * rate, events=1500,
                                         seed=5),
                    ScheduleConfig(batching_window_s=0.002,
                                   batching_policy="edf",
                                   admission_policy="feasibility",
                                   oversubscription=1.25,
                                   max_superkernel_size=64),
                    lambda b: scale * base(b),
                )
                cache[scale] = m.slo_attainment
            return cache[scale]

        scales = st.sampled_from([0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 5.0])

        @given(lo=scales, hi=scales)
        def check(lo, hi):
            if lo > hi:
                lo, hi = hi, lo
            assert attainment(hi) <= attainment(lo) + 1e-12

        check()
