"""Configuration system: typed dataclasses + registry + CLI overrides."""

from repro.config.model import (
    AttentionKind,
    BlockKind,
    Modality,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.config.registry import (
    get_config,
    list_configs,
    register_config,
    smoke_variant,
)
from repro.config.shapes import INPUT_SHAPES, InputShape, get_shape
from repro.config.runtime import MeshConfig, RuntimeConfig, ScheduleConfig

__all__ = [
    "AttentionKind",
    "BlockKind",
    "Modality",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "MeshConfig",
    "RuntimeConfig",
    "ScheduleConfig",
    "InputShape",
    "INPUT_SHAPES",
    "get_shape",
    "get_config",
    "list_configs",
    "register_config",
    "smoke_variant",
]
