"""Section 4 claim: "overheads gradually decrease if we cache super-kernels
as workloads stabilize over time."

Stochastic (Poisson) kernel arrivals from R tenants drive the dynamic
scheduler; we report per-quarter mean latency, dispatch count and cache
hit-rate. Expected: hit-rate -> ~1 and latency anneals after the first
quarter (compiles amortized), demonstrating the super-kernel cache doing
its job under non-stationary R.

Arrivals come from the ``repro.sim`` trace generator replayed against the
wall clock — the SAME seeded ``PoissonTrace`` the simulator consumes, so
a live run and ``--simulate`` (virtual clock + roofline cost model, no
device work) see bit-identical arrival sequences through one code path.
A live run can additionally fit a ``CalibratedCostModel`` from its own
measured dispatches (``--calibrate PATH``) for later simulated replay.

The ``policy`` knob selects the batching-window policy of the unified
core ("fixed" or "slo_adaptive"); the trace runs under both by default so
the SLO-aware window's latency win shows up on live (wall-clock)
arrivals, not just in the Fig-4 virtual-clock replay.
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import numpy as np

from repro.config import ScheduleConfig
from repro.configs.paper_sgemm import PAPER_GEMM_SHAPES
from repro.core.queue import ShapeBucket
from repro.sim import (
    CalibratedCostModel,
    PoissonTrace,
    RooflineCostModel,
    TenantSpec,
    simulate,
)

# historical pacing: ~3 arrivals per 0.2ms tick of the old sleep loop
RATE_HZ = 15_000.0
ARRIVALS_PER_EVENT = 3


def build_mix(tenants: int, slo_s: float) -> List[TenantSpec]:
    """All tenants launch the paper's ResNet-18 conv2_2 SGEMM geometry
    (the original trace's single-shape setting) under one tight SLO."""
    g = PAPER_GEMM_SHAPES["resnet18_conv2_2"]
    bucket = ShapeBucket("gemm", g.M, g.K, g.N, "float32")
    return [
        TenantSpec(
            tenant_id=t, name=f"t{t}/{g.name}", bucket=bucket,
            cost=float(g.flops), flops=float(g.flops),
            bytes=float(4 * (g.M * g.K + g.K * g.N + g.M * g.N)),
            slo_s=slo_s, kind="kernel",
        )
        for t in range(tenants)
    ]


def _schedule(policy: str) -> ScheduleConfig:
    return ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32,
                          batching_policy=policy)


def _print_quarters(lat: List[float], hit_marks: Optional[List[float]],
                    policy: str, csv_rows) -> None:
    q = max(1, len(lat) // 4)
    print(f"{'quarter':>8s} {'mean lat ms':>12s} {'hit rate':>9s}")
    for qi in range(4):
        seg = lat[qi * q:(qi + 1) * q]
        if not seg:
            continue
        hit = hit_marks[min((qi + 1) * q, len(hit_marks)) - 1] if hit_marks else float("nan")
        print(f"{qi+1:8d} {np.mean(seg)*1e3:12.3f} {hit:9.2f}")
        if csv_rows is not None:
            csv_rows.append((f"dynamic_trace/{policy}/q{qi+1}",
                             float(np.mean(seg) * 1e6),
                             f"hit_rate={hit:.2f}"))


def run(num_events: int = 200, tenants: int = 12, seed: int = 0, csv_rows=None,
        policy: str = "fixed", slo_s: float = 0.010,
        simulate_only: bool = False, calibrate_path: Optional[str] = None):
    mix = build_mix(tenants, slo_s)
    trace = PoissonTrace(mix, RATE_HZ, events=ARRIVALS_PER_EVENT * num_events,
                         seed=seed)

    if simulate_only:
        print(f"\n=== Dynamic trace (SIMULATED): policy={policy} ===")
        m = simulate(trace, _schedule(policy), RooflineCostModel())
        _print_quarters(list(m.lat), None, f"sim/{policy}", csv_rows)
        s = m.summary()
        print(f"final: dispatches={s['dispatches']:.0f} "
              f"problems={s['completed']:.0f} "
              f"attainment={s['slo_attainment']:.2f} "
              f"p95={s['p95_s']*1e3:.3f}ms")
        return s

    import jax
    import jax.numpy as jnp

    from repro.core import DynamicSpaceTimeScheduler, GemmProblem

    print(f"\n=== Dynamic trace: cache warm-up under stochastic arrivals "
          f"(policy={policy}) ===")
    g = PAPER_GEMM_SHAPES["resnet18_conv2_2"]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    # device-resident per-tenant weights; fresh activations per query
    ws = [jax.random.normal(jax.random.fold_in(key, t), (g.K, g.N), jnp.float32)
          for t in range(tenants)]
    xs = [jax.random.normal(jax.random.fold_in(key, 1000 + i), (g.M, g.K), jnp.float32)
          for i in range(8)]

    calibrated = CalibratedCostModel() if calibrate_path else None
    sched = DynamicSpaceTimeScheduler(
        _schedule(policy),
        on_dispatch=calibrated.observe if calibrated else None,
    )
    lat: List[float] = []
    hit_marks: List[float] = []

    def collect(done):
        for p in done:
            lat.append(p.completion_time - p.arrival_time)
            hit_marks.append(sched.cache.stats.hit_rate)

    t0 = time.perf_counter()
    for ev in trace:
        # replay the trace's timeline against the wall clock
        delay = (t0 + ev.t_s) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t = ev.spec.tenant_id
        sched.submit(GemmProblem(tenant_id=t,
                                 x=xs[int(rng.integers(len(xs)))],
                                 w=ws[t], slo_s=ev.spec.slo_s))
        collect(sched.pump())
    collect(sched.flush())

    _print_quarters(lat, hit_marks, policy, csv_rows)
    rep = sched.report()
    print(f"final: dispatches={rep['dispatches']:.0f} problems={rep['problems']:.0f} "
          f"hit_rate={rep['cache_hit_rate']:.2f} spread={rep.get('spread', 0):.2%} "
          f"p95={rep.get('p95_s', 0)*1e3:.3f}ms")
    if calibrated is not None:
        calibrated.save(calibrate_path)
        print(f"calibrated cost model ({len(calibrated.table)} keys) "
              f"-> {calibrate_path}")
    return rep


def run_all_policies(num_events: int = 200, tenants: int = 12, seed: int = 0,
                     csv_rows=None, simulate_only: bool = False):
    """Same trace parameters under both batching-window policies."""
    for policy in ("fixed", "slo_adaptive"):
        run(num_events=num_events, tenants=tenants, seed=seed,
            csv_rows=csv_rows, policy=policy, simulate_only=simulate_only)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=200)
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="both",
                    choices=("fixed", "slo_adaptive", "both"))
    ap.add_argument("--simulate", action="store_true",
                    help="replay the same trace on the virtual-clock simulator")
    ap.add_argument("--calibrate", default=None, metavar="PATH",
                    help="fit+save a CalibratedCostModel from live dispatches")
    args = ap.parse_args()
    if args.calibrate and (args.simulate or args.policy == "both"):
        # calibration fits from LIVE dispatches of one scheduler; a
        # simulated run has no measurements and "both" would overwrite
        # the file with whichever policy ran last
        ap.error("--calibrate requires a live run with a single --policy "
                 "(fixed or slo_adaptive), not --simulate or --policy both")
    if args.policy == "both":
        run_all_policies(num_events=args.events, tenants=args.tenants,
                         seed=args.seed, simulate_only=args.simulate)
    else:
        run(num_events=args.events, tenants=args.tenants, seed=args.seed,
            policy=args.policy, simulate_only=args.simulate,
            calibrate_path=args.calibrate)
