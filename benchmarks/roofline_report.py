"""§Roofline: render the (arch x shape x mesh) table from dry-run records.

Reads experiments/dryrun/*.json written by repro.launch.dryrun and prints
the three roofline terms, dominant bottleneck and useful-FLOPs ratio.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def variant(rec: Dict) -> str:
    parts = []
    if rec.get("policy", "fsdp") != "fsdp":
        parts.append(rec["policy"])
    if rec.get("tenants", 1) > 1:
        parts.append(f"R{rec['tenants']}")
    if rec.get("microbatch", 1) > 1:
        parts.append(f"mb{rec['microbatch']}")
    return "+".join(parts) or "base"


def load(records_dir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for path in glob.glob(os.path.join(records_dir, "*.json")):
        with open(path) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r["mesh"],
                             variant(r)))
    return recs


def run(records_dir: str = "experiments/dryrun", mesh: str = "pod1", csv_rows=None):
    recs = [r for r in load(records_dir) if r.get("mesh") == mesh]
    if not recs:
        print(f"(no dry-run records under {records_dir} for mesh={mesh} — run "
              f"`python -m repro.launch.dryrun --all --mesh {mesh} --out {records_dir}`)")
        return
    print(f"\n=== Roofline table (mesh={mesh}) ===")
    print(f"{'arch':26s} {'shape':12s} {'variant':>9s} {'t_comp':>9s} {'t_mem':>9s} "
          f"{'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'HBM GiB/dev':>12s}")
    for r in recs:
        v = variant(r)
        if r["status"] == "skipped":
            print(f"{r['arch']:26s} {r['shape']:12s} {v:>9s} {'—':>9s} {'—':>9s} {'—':>9s} "
                  f"{'skip':>10s}   ({r['reason'][:40]})")
            continue
        if r["status"] != "ok":
            print(f"{r['arch']:26s} {r['shape']:12s} {v:>9s} ERROR: {r.get('error','?')[:60]}")
            continue
        mem = r.get("memory_analysis", {}).get("approx_total_per_device_gib", 0.0)
        print(f"{r['arch']:26s} {r['shape']:12s} {v:>9s} {r['t_compute_s']:9.2e} "
              f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
              f"{r['bottleneck']:>10s} {r['useful_flops_ratio']:7.3f} {mem:12.2f}")
        if csv_rows is not None:
            csv_rows.append((
                f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
                f"bound={r['bottleneck']},useful={r['useful_flops_ratio']:.3f}",
            ))


if __name__ == "__main__":
    run()
    run(mesh="pod2")
