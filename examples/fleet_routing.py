"""Fleet routing walkthrough — the JSQ-vs-affinity trade-off, end to end.

Four replicas of the REAL scheduler (each on its own virtual clock, each
with its own cold compile cache) serve one bursty Zipf-weighted tenant
stream under every routing policy. No device work, deterministic per
seed, seconds on CPU.

The point this example makes: load balancing and cache affinity pull in
opposite directions. `jsq` equalizes queues but sprays every tenant's
shapes across all four compile caches; `affinity` pins tenants (few
compiles, warm caches) but lets hot tenants pile up on their pinned
replica; `least_cost` prices both effects — backlog seconds AND the
compile a cold replica would pay — and typically wins tail latency while
merging more aggressively (watch its routing imbalance: concentration is
deliberate, not drift).

    PYTHONPATH=src python examples/fleet_routing.py
"""

from repro.config import ScheduleConfig
from repro.sim import (
    ROUTERS,
    BacklogAutoscaler,
    RooflineCostModel,
    estimate_capacity_hz,
    fleet_capacity_hz,
    fleet_sgemm_mix,
    make_trace,
    simulate_fleet,
)

EVENTS = 20_000
REPLICAS = 4
SEED = 0


def main() -> None:
    mix = fleet_sgemm_mix(12)  # Zipf arrival shares: a few hot tenants
    base = RooflineCostModel(strategy="space_time")
    offered_hz = 0.85 * REPLICAS * estimate_capacity_hz(mix, base)
    sched = ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32)

    print(f"=== {REPLICAS} replicas, bursty MMPP @ ~{offered_hz:,.0f}/s, "
          f"{EVENTS} events, compile cold-start 200us ===")
    print(f"{'router':12s} {'p95 ms':>8s} {'attain':>7s} {'goodput':>10s} "
          f"{'imbal':>6s} {'util':>6s} {'cold%':>6s} {'cold 1st->2nd half':>19s}")
    for router in ROUTERS:
        m = simulate_fleet(
            make_trace("mmpp", mix, offered_hz, EVENTS, seed=SEED),
            replicas=REPLICAS, router=router, schedule=sched,
            cost_model=base, compile_s=200e-6)
        s = m.summary()
        first, second = m.cold_fraction_halves()
        print(f"{router:12s} {s['p95_s']*1e3:8.3f} {s['slo_attainment']:7.3f} "
              f"{s['goodput_cost_per_s']:10.4g} {s['routing_imbalance']:6.3f} "
              f"{s['utilization']:6.3f} {s['cold_start_fraction']*100:6.2f} "
              f"{first:9.3f} -> {second:.3f}")

    print("\nround_robin balances counts but is blind to bursts and caches;")
    print("jsq corrects imbalance as it forms; least_cost also sees compile")
    print("costs and merge opportunities; affinity minimizes cold starts at")
    print("the price of hot-replica tails. Per-replica detail: "
          "FleetMetrics.per_replica / .routed_counts.")

    # ---- heterogeneous + elastic: mixed generations, autoscaled ----
    specs = ["v5e", "v5e_half"]  # cycled: fast, half-speed, fast, ...
    hz = 0.85 * fleet_capacity_hz(mix, [specs[i % 2] for i in range(REPLICAS)])
    print(f"\n=== mixed v5e + v5e_half fleet, autoscaled from 1 replica ===")
    print(f"{'cell':22s} {'p95 ms':>8s} {'goodput':>10s} {'replicas':>9s}")
    for name, kwargs in (
        ("hetero round_robin", dict(replicas=REPLICAS, router="round_robin")),
        ("hetero least_cost", dict(replicas=REPLICAS, router="least_cost")),
        ("elastic least_cost", dict(
            replicas=1, router="least_cost",
            autoscaler=BacklogAutoscaler(
                max_replicas=REPLICAS, up_backlog_s=0.005,
                down_backlog_s=0.001, interval_s=50.0 / hz,
                spinup_s=100e-6))),
    ):
        m = simulate_fleet(
            make_trace("mmpp", mix, hz, EVENTS, seed=SEED),
            schedule=sched, specs=specs, compile_s=200e-6, **kwargs)
        s = m.summary()
        repl = f"{m.initial_replicas}->{m.final_active}" if m.scale_events \
            else str(m.final_active)
        print(f"{name:22s} {s['p95_s']*1e3:8.3f} "
              f"{s['goodput_cost_per_s']:10.4g} {repl:>9s}")
    print("\nspeed-aware least_cost routes around the slow chips that blind")
    print("round_robin trips over; the elastic fleet grows on the backlog")
    print("signal, each new replica arriving with a stone-cold compile cache")
    print("(FleetMetrics.scale_events has the full timeline).")


if __name__ == "__main__":
    main()
