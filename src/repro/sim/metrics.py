"""Simulation metrics: latency percentiles, SLO attainment, goodput,
utilization, and tenant-interference — with deterministic JSON export.

``MetricsAccumulator`` ingests completed workloads one at a time in the
simulator's hot loop (columnar ``array`` appends, no per-item objects —
million-event runs stay cheap) and freezes into a ``SimMetrics`` whose
summary is computed vectorized at the end.

Exports are BENCH-compatible: ``SimMetrics.bench_rows()`` yields the same
``(name, us_per_call, derived)`` triples the benchmark driver's CSV block
prints, and ``to_bench_json()`` wraps them plus the full metric dict into
one JSON document (``BENCH_<name>.json``). All exports use sorted keys
and pure-deterministic arithmetic, so one seed produces one byte-exact
JSON — the determinism contract the tests pin.
"""

from __future__ import annotations

import json
import math
from array import array
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Version stamp of every exported metrics document (SimMetrics /
# FleetMetrics to_dict + to_bench_json) and of RunReport (repro.api).
# Bump when the JSON layout changes shape — the regression gate
# (benchmarks/check_regression.py) reports a version mismatch instead of
# silently comparing rows across incompatible layouts.
SCHEMA_VERSION = 1


class MetricsAccumulator:
    """Columnar per-completion record store (hot-loop ingestion side)."""

    def __init__(self) -> None:
        self._lat = array("d")
        self._slo = array("d")
        self._cost = array("d")
        self._tenant = array("l")
        self._kind_idx = array("l")
        self._kinds: Dict[str, int] = {}

    def add(self, tenant_id: int, latency_s: float, slo_s: float,
            cost: float, kind: str) -> None:
        self._lat.append(latency_s)
        self._slo.append(slo_s)
        self._cost.append(cost)
        self._tenant.append(tenant_id)
        ki = self._kinds.get(kind)
        if ki is None:
            ki = self._kinds.setdefault(kind, len(self._kinds))
        self._kind_idx.append(ki)

    def add_batch(self, done: Sequence) -> None:
        """Absorb one dispatch's completed workloads (arrival/completion
        stamps already set). Column-at-a-time ``array.extend`` over
        listcomps: one C call per column per dispatch instead of five
        Python-level appends per completion, with identical column
        contents (batch order preserved).
        """
        kinds = self._kinds
        try:
            kidx = [kinds[w.kind] for w in done]
        except KeyError:
            # rare path (new kind seen): intern first so the columns are
            # only extended once the whole index list exists
            kidx = []
            for w in done:
                ki = kinds.get(w.kind)
                if ki is None:
                    ki = kinds.setdefault(w.kind, len(kinds))
                kidx.append(ki)
        self._lat.extend([w.completion_time - w.arrival_time for w in done])
        self._slo.extend([w.slo_s for w in done])
        self._cost.extend([w.cost for w in done])
        self._tenant.extend([w.tenant_id for w in done])
        self._kind_idx.extend(kidx)

    def __len__(self) -> int:
        return len(self._lat)

    def freeze(self, sim_duration_s: float, busy_time_s: float,
               dispatches: int, rejected: int = 0,
               evicted_tenants: int = 0,
               ripe_nudges: int = 0,
               deadline_rejected: int = 0,
               oversubscribed: int = 0,
               preemptions: int = 0) -> "SimMetrics":
        return SimMetrics(
            lat=np.asarray(self._lat, np.float64),
            slo=np.asarray(self._slo, np.float64),
            cost=np.asarray(self._cost, np.float64),
            tenant=np.asarray(self._tenant, np.int64),
            kind_idx=np.asarray(self._kind_idx, np.int64),
            kinds=[k for k, _ in sorted(self._kinds.items(), key=lambda kv: kv[1])],
            sim_duration_s=float(sim_duration_s),
            busy_time_s=float(busy_time_s),
            dispatches=int(dispatches),
            rejected=int(rejected),
            evicted_tenants=int(evicted_tenants),
            ripe_nudges=int(ripe_nudges),
            deadline_rejected=int(deadline_rejected),
            oversubscribed=int(oversubscribed),
            preemptions=int(preemptions),
        )


def _pct(lat: np.ndarray) -> Dict[str, float]:
    if lat.size == 0:
        return {"p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0, "mean_s": 0.0}
    p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
    return {"p50_s": float(p50), "p95_s": float(p95), "p99_s": float(p99),
            "mean_s": float(lat.mean())}


class SimMetrics:
    """Frozen simulation outcome; every metric derives from the columns."""

    def __init__(self, lat, slo, cost, tenant, kind_idx, kinds,
                 sim_duration_s, busy_time_s, dispatches,
                 rejected=0, evicted_tenants=0, ripe_nudges=0,
                 deadline_rejected=0, oversubscribed=0, preemptions=0):
        self.lat = lat
        self.slo = slo
        self.cost = cost
        self.tenant = tenant
        self.kind_idx = kind_idx
        self.kinds = kinds
        self.sim_duration_s = sim_duration_s
        self.busy_time_s = busy_time_s
        self.dispatches = dispatches
        self.rejected = rejected
        self.evicted_tenants = evicted_tenants
        # scheduler counters, surfaced in bench rows and RunReport's
        # "scheduler" section but deliberately NOT in summary()/to_dict():
        # the metrics JSON layout (SCHEMA_VERSION 1) stays byte-identical
        self.ripe_nudges = ripe_nudges
        self.deadline_rejected = deadline_rejected
        self.oversubscribed = oversubscribed
        self.preemptions = preemptions
        self._met = lat <= slo if lat.size else np.zeros(0, bool)

    # ------------------------------------------------------------- headline
    @property
    def completed(self) -> int:
        return int(self.lat.size)

    @property
    def slo_attainment(self) -> float:
        """Fraction of completed workloads that met their SLO."""
        return float(self._met.mean()) if self.lat.size else 1.0

    @property
    def throughput_cost_per_s(self) -> float:
        """Simulated throughput in cost units (FLOPs/tokens) per second."""
        if self.sim_duration_s <= 0.0:
            return 0.0
        return float(self.cost.sum()) / self.sim_duration_s

    @property
    def goodput_cost_per_s(self) -> float:
        """Throughput counting only SLO-met work (D-STACK's usefulness
        criterion: late answers don't count)."""
        if self.sim_duration_s <= 0.0:
            return 0.0
        return float(self.cost[self._met].sum()) / self.sim_duration_s

    @property
    def utilization(self) -> float:
        """Fraction of simulated time the device was busy."""
        if self.sim_duration_s <= 0.0:
            return 0.0
        return min(1.0, self.busy_time_s / self.sim_duration_s)

    # ------------------------------------------------------------ breakdowns
    def per_tenant(self) -> Dict[int, Dict[str, float]]:
        out: Dict[int, Dict[str, float]] = {}
        for t in np.unique(self.tenant):
            mask = self.tenant == t
            d = _pct(self.lat[mask])
            d["slo_attainment"] = float(self._met[mask].mean())
            d["completed"] = float(mask.sum())
            out[int(t)] = d
        return out

    def per_kind(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for ki, kind in enumerate(self.kinds):
            mask = self.kind_idx == ki
            if not mask.any():
                continue
            d = _pct(self.lat[mask])
            d["slo_attainment"] = float(self._met[mask].mean())
            out[kind] = d
        return out

    def summary(self) -> Dict[str, float]:
        out = _pct(self.lat)
        out.update({
            "completed": float(self.completed),
            "dispatches": float(self.dispatches),
            "rejected": float(self.rejected),
            "evicted_tenants": float(self.evicted_tenants),
            "sim_duration_s": self.sim_duration_s,
            "busy_time_s": self.busy_time_s,
            "utilization": self.utilization,
            "slo_attainment": self.slo_attainment,
            "throughput_cost_per_s": self.throughput_cost_per_s,
            "goodput_cost_per_s": self.goodput_cost_per_s,
        })
        return out

    # --------------------------------------------------------------- export
    def bench_rows(self, prefix: str) -> List[Tuple[str, float, str]]:
        """``(name, us_per_call, derived)`` triples, the benchmark driver's
        CSV schema, for appending to a run's ``csv_rows``."""
        s = self.summary()
        return [
            (f"{prefix}/p50", s["p50_s"] * 1e6, "us latency"),
            (f"{prefix}/p95", s["p95_s"] * 1e6, "us latency"),
            (f"{prefix}/p99", s["p99_s"] * 1e6, "us latency"),
            (f"{prefix}/attainment", s["slo_attainment"] * 100.0, "pct SLO met"),
            (f"{prefix}/goodput", s["goodput_cost_per_s"],
             "cost_units_per_s_slo_met"),
            (f"{prefix}/utilization", s["utilization"] * 100.0, "pct busy"),
            (f"{prefix}/ripe_nudges", float(self.ripe_nudges),
             "count (ungated)"),
            (f"{prefix}/deadline_rejected", float(self.deadline_rejected),
             "count (ungated)"),
            (f"{prefix}/oversubscribed", float(self.oversubscribed),
             "count (ungated)"),
            (f"{prefix}/preemptions", float(self.preemptions),
             "count (ungated)"),
        ]

    def to_dict(self) -> Dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "summary": self.summary(),
            "per_tenant": {str(k): v for k, v in self.per_tenant().items()},
            "per_kind": self.per_kind(),
        }

    def to_json(self) -> str:
        """Canonical (sorted-keys) JSON — byte-identical across same-seed
        runs; the determinism tests compare these strings directly."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)


class FleetMetrics:
    """Fleet-level simulation outcome: the merged (fleet-wide) metrics
    plus per-replica breakdowns and the routing/cold-start signals the
    single-replica ``SimMetrics`` cannot express.

    Duck-types ``SimMetrics``'s export surface (``summary`` /
    ``bench_rows`` / ``to_dict`` / ``to_json``) so ``to_bench_json`` and
    the CI regression gate consume fleet sections unchanged.

    ``cold_times`` / ``cold_flags`` are the concatenated per-dispatch
    ``(virtual seconds, was_cold)`` series across replicas — the warm-up
    curve; ``cold_fraction_halves()`` splits it at the fleet horizon
    midpoint (cold fraction must decay as caches warm).

    ``scale_events`` is the autoscale timeline (one dict per decision
    that changed the fleet), ``replica_specs`` names each replica's
    hardware (heterogeneous fleets), and ``final_active`` is the replica
    count still receiving arrivals when the trace ended — all part of the
    deterministic JSON, so the byte-identical contract covers elasticity
    too. Every fleet-signal accessor is total: empty or single-completion
    windows (a replica spun up at the very end, a trace with no
    arrivals) yield defined 0.0 values, never NaN in BENCH JSON.
    """

    def __init__(self, merged: SimMetrics, per_replica: List[SimMetrics],
                 routed_counts: Sequence[int], router: str,
                 cold_times: np.ndarray, cold_flags: np.ndarray,
                 scale_events: Optional[Sequence] = None,
                 replica_specs: Optional[Sequence[Optional[str]]] = None,
                 final_active: Optional[int] = None,
                 partition: Optional[Dict] = None):
        self.merged = merged
        self.per_replica = per_replica
        self.routed_counts = np.asarray(routed_counts, np.int64)
        self.router = router
        self.cold_times = np.asarray(cold_times, np.float64)
        self.cold_flags = np.asarray(cold_flags, np.int64)
        # normalize to plain dicts so to_json stays canonical
        self.scale_events: List[Dict] = [
            e.to_dict() if hasattr(e, "to_dict") else dict(e)
            for e in (scale_events or [])]
        self.replica_specs: List[Optional[str]] = (
            list(replica_specs) if replica_specs is not None
            else [None] * len(per_replica))
        self.final_active = (len(per_replica) if final_active is None
                             else int(final_active))
        # fractional-share section (repro.partition): the final plan plus
        # the assign/replan event timeline. None on unpartitioned fleets,
        # and then absent from to_dict() — pre-partition metrics JSON
        # stays byte-identical.
        self.partition: Optional[Dict] = partition

    @property
    def replicas(self) -> int:
        """Replicas that were ever live (autoscaled fleets: spawned)."""
        return len(self.per_replica)

    @property
    def ripe_nudges(self) -> int:
        """Fleet-wide scheduler drift counter (sum over replicas)."""
        return self.merged.ripe_nudges

    @property
    def deadline_rejected(self) -> int:
        """Fleet-wide feasibility-admission rejects (sum over replicas)."""
        return self.merged.deadline_rejected

    @property
    def oversubscribed(self) -> int:
        """Fleet-wide past-deadline admits (sum over replicas)."""
        return self.merged.oversubscribed

    @property
    def preemptions(self) -> int:
        """Fleet-wide ahead-of-window force-dispatches (sum over replicas)."""
        return self.merged.preemptions

    @property
    def initial_replicas(self) -> int:
        """Fleet size at trace start (every scale-up spawned one more)."""
        return len(self.per_replica) - self.scale_ups

    @property
    def scale_ups(self) -> int:
        return sum(1 for e in self.scale_events if e["action"] == "up")

    @property
    def scale_downs(self) -> int:
        return sum(1 for e in self.scale_events if e["action"] == "down")

    # ------------------------------------------------------- fleet signals
    # Every accessor below must be total over degenerate windows: an empty
    # fleet section, a single completion, or a replica that never routed
    # returns a defined 0.0 — these numbers flow verbatim into gated BENCH
    # JSON, where one NaN poisons every downstream comparison.
    @property
    def utilization_spread(self) -> float:
        """max - min per-replica utilization (0 = perfectly even work)."""
        utils = [m.utilization for m in self.per_replica]
        if not utils:
            return 0.0
        spread = float(max(utils) - min(utils))
        return spread if math.isfinite(spread) else 0.0

    @property
    def routing_imbalance(self) -> float:
        """Coefficient of variation of per-replica routed arrival counts
        (0 = perfectly balanced; round-robin's floor)."""
        c = self.routed_counts.astype(np.float64)
        if c.size == 0:
            return 0.0
        mean = float(c.mean())
        if mean <= 0.0:
            return 0.0
        return float(c.std() / mean)

    @property
    def cold_start_fraction(self) -> float:
        """Fraction of all fleet dispatches that paid a compile."""
        if self.cold_flags.size == 0:
            return 0.0
        return float(self.cold_flags.mean())

    def cold_fraction_halves(self) -> Tuple[float, float]:
        """Cold-dispatch fraction in the first vs second half of the fleet
        horizon — the warm-up decay the tests pin. Windows with no
        dispatches (empty trace; a single dispatch leaves the second half
        empty) contribute a defined 0.0, not a NaN mean."""
        if self.cold_times.size == 0:
            return 0.0, 0.0
        mid = (float(self.cold_times.min()) + float(self.cold_times.max())) / 2.0
        early = self.cold_times <= mid
        first = self.cold_flags[early]
        second = self.cold_flags[~early]
        return (float(first.mean()) if first.size else 0.0,
                float(second.mean()) if second.size else 0.0)

    # ------------------------------------------------------------- exports
    def summary(self) -> Dict[str, float]:
        out = self.merged.summary()
        first, second = self.cold_fraction_halves()
        out.update({
            # merged utilization clamps Σbusy/horizon at 1.0 — meaningless
            # for N > 1; report the per-replica mean instead
            "utilization": float(
                np.mean([m.utilization for m in self.per_replica])
            ) if self.per_replica else 0.0,
            "replicas": float(self.replicas),
            "final_active": float(self.final_active),
            "scale_ups": float(self.scale_ups),
            "scale_downs": float(self.scale_downs),
            "routing_imbalance": self.routing_imbalance,
            "utilization_spread": self.utilization_spread,
            "cold_start_fraction": self.cold_start_fraction,
            "cold_fraction_first_half": first,
            "cold_fraction_second_half": second,
        })
        return out

    def bench_rows(self, prefix: str) -> List[Tuple[str, float, str]]:
        s = self.summary()
        rows = [
            (f"{prefix}/p50", s["p50_s"] * 1e6, "us latency"),
            (f"{prefix}/p95", s["p95_s"] * 1e6, "us latency"),
            (f"{prefix}/p99", s["p99_s"] * 1e6, "us latency"),
            (f"{prefix}/attainment", s["slo_attainment"] * 100.0, "pct SLO met"),
            (f"{prefix}/goodput", s["goodput_cost_per_s"],
             "cost_units_per_s_slo_met"),
            (f"{prefix}/utilization", s["utilization"] * 100.0,
             "pct busy (mean over replicas)"),
        ]
        rows.extend([
            (f"{prefix}/ripe_nudges", float(self.ripe_nudges),
             "count (ungated)"),
            (f"{prefix}/deadline_rejected", float(self.deadline_rejected),
             "count (ungated)"),
            (f"{prefix}/oversubscribed", float(self.oversubscribed),
             "count (ungated)"),
            (f"{prefix}/preemptions", float(self.preemptions),
             "count (ungated)"),
            (f"{prefix}/routing_imbalance", self.routing_imbalance,
             "cv routed counts"),
            (f"{prefix}/utilization_spread", self.utilization_spread * 100.0,
             "pct max-min"),
            (f"{prefix}/cold_fraction", self.cold_start_fraction * 100.0,
             "pct dispatches compiling"),
        ])
        if self.scale_events:
            rows.extend([
                (f"{prefix}/scale_events", float(len(self.scale_events)),
                 "autoscale decisions applied"),
                (f"{prefix}/final_active", float(self.final_active),
                 "replicas active at trace end"),
            ])
        return rows

    def to_dict(self) -> Dict:
        doc = self.merged.to_dict()
        doc["summary"] = self.summary()
        per_replica = {}
        for i, m in enumerate(self.per_replica):
            entry = m.summary()
            entry["routed"] = float(self.routed_counts[i]) \
                if i < self.routed_counts.size else 0.0
            spec = self.replica_specs[i] if i < len(self.replica_specs) else None
            if spec is not None:
                entry["spec"] = spec
            per_replica[str(i)] = entry
        doc["per_replica"] = per_replica
        doc["routed_counts"] = [int(c) for c in self.routed_counts]
        doc["router"] = self.router
        doc["scale_events"] = self.scale_events
        if self.partition is not None:
            doc["partition"] = self.partition
        return doc

    def to_json(self) -> str:
        """Canonical sorted-keys JSON — byte-identical per seed, same
        contract as ``SimMetrics.to_json``."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)


def to_bench_json(name: str, sections: Dict[str, "SimMetrics | FleetMetrics"],
                  extra: Optional[Dict] = None) -> str:
    """One BENCH_<name>.json document over named simulation sections."""
    rows = []
    for section, metrics in sorted(sections.items()):
        rows.extend(
            {"name": n, "us_per_call": v, "derived": d}
            for n, v, d in metrics.bench_rows(f"{name}/{section}")
        )
    doc = {
        "benchmark": name,
        "schema_version": SCHEMA_VERSION,
        "rows": rows,
        "sections": {k: m.to_dict() for k, m in sorted(sections.items())},
    }
    if extra:
        doc["extra"] = extra
    return json.dumps(doc, indent=2, sort_keys=True)


def interference_matrix(
    run_mix: Callable[[Sequence], SimMetrics],
    specs: Sequence,
) -> np.ndarray:
    """Tenant-interference (isolation) matrix from counterfactual co-runs.

    ``M[i][j]`` = mean latency of tenant ``i`` co-run with tenant ``j``,
    divided by tenant ``i``'s solo mean latency — 1.0 everywhere means
    perfect isolation; row spikes name the victim, column spikes the
    aggressor. ``run_mix(specs_subset)`` must run one deterministic
    simulation over the given subset (the simulator is fast enough that
    the O(T^2) counterfactuals finish in seconds).

    Specs must carry distinct tenant_ids: results are keyed per tenant,
    so two specs of one tenant (e.g. a serving mix's prefill + decode
    streams) would blend into one meaningless row — pick one spec per
    tenant before calling.
    """
    n = len(specs)
    if len({s.tenant_id for s in specs}) != n:
        raise ValueError(
            "interference_matrix needs unique tenant_ids; pick one spec "
            "per tenant (got "
            f"{sorted(s.tenant_id for s in specs)})")
    solo = np.empty(n)
    for i, s in enumerate(specs):
        pt = run_mix([s]).per_tenant()
        solo[i] = pt[s.tenant_id]["mean_s"]
    M = np.ones((n, n))
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            pt = run_mix([specs[i], specs[j]]).per_tenant()
            entry = pt.get(specs[i].tenant_id)
            if entry is None or solo[i] <= 0.0:
                # victim completed nothing in this co-run (starved) or has
                # a degenerate solo baseline — surface it, don't report it
                # as perfect isolation
                M[i, j] = float("nan")
            else:
                M[i, j] = entry["mean_s"] / solo[i]
    return M
