"""Serving engine integration: multi-tenant space-time decode must be
token-identical to single-tenant execution, slots must recycle, and the
time_only mode must produce the same tokens (slower path, same math)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def _setup(arch, R=3, mode="space_time", slots=2, cache_len=64):
    cfg = dataclasses.replace(smoke_variant(get_config(arch)), dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    tenant_params = [m.init(jax.random.fold_in(key, t)) for t in range(R)]
    eng = MultiTenantEngine(
        m, tenant_params,
        EngineConfig(num_tenants=R, slots_per_tenant=slots, cache_len=cache_len, mode=mode),
    )
    return cfg, m, tenant_params, eng


def _oracle_tokens(m, params, prompt, n, cache_len=64):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, caches = m.forward_prefill(params, toks, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(n - 1):
        logits, caches = m.forward_decode(
            params, jnp.asarray([out[-1]], jnp.int32), caches, lengths
        )
        out.append(int(jnp.argmax(logits[0])))
        lengths = lengths + 1
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-1.6b"])
def test_spacetime_matches_single_tenant(arch):
    cfg, m, tenant_params, eng = _setup(arch)
    rng = np.random.RandomState(0)
    reqs = []
    for t in range(3):
        for j in range(3):  # 3 requests per tenant, only 2 slots -> queueing
            p = list(rng.randint(1, cfg.vocab_size, size=6))
            r = InferenceRequest(tenant_id=t, prompt=p, max_new_tokens=5)
            reqs.append(r)
            eng.submit(r)
    eng.run_until_drained()
    assert len(eng.finished) == 9
    for r in eng.finished:
        want = _oracle_tokens(m, tenant_params[r.tenant_id], r.prompt, len(r.generated))
        assert r.generated == want, (arch, r.request_id)


@pytest.mark.slow
def test_time_only_mode_same_tokens():
    cfg, m, tenant_params, eng_st = _setup("stablelm-1.6b", R=2)
    _, _, _, eng_to = _setup("stablelm-1.6b", R=2, mode="time_only")
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(1, cfg.vocab_size, size=5)) for _ in range(4)]
    for i, p in enumerate(prompts):
        eng_st.submit(InferenceRequest(tenant_id=i % 2, prompt=p, max_new_tokens=4))
        eng_to.submit(InferenceRequest(tenant_id=i % 2, prompt=p, max_new_tokens=4))
    eng_st.run_until_drained()
    eng_to.run_until_drained()
    st = sorted((r.tenant_id, tuple(r.prompt), tuple(r.generated)) for r in eng_st.finished)
    to = sorted((r.tenant_id, tuple(r.prompt), tuple(r.generated)) for r in eng_to.finished)
    assert st == to


def test_slot_recycling():
    cfg, m, tenant_params, eng = _setup("stablelm-1.6b", R=1, slots=1)
    rng = np.random.RandomState(2)
    for _ in range(3):
        eng.submit(InferenceRequest(
            tenant_id=0, prompt=list(rng.randint(1, cfg.vocab_size, size=4)),
            max_new_tokens=3))
    eng.run_until_drained()
    assert len(eng.finished) == 3
    assert eng.slots.utilization() == 0.0


def test_report_metrics():
    cfg, m, tenant_params, eng = _setup("stablelm-1.6b", R=2)
    rng = np.random.RandomState(3)
    for t in range(2):
        eng.submit(InferenceRequest(
            tenant_id=t, prompt=list(rng.randint(1, cfg.vocab_size, size=4)),
            max_new_tokens=3))
    eng.run_until_drained()
    rep = eng.report()
    assert rep["finished"] == 2.0
    assert rep["decode_tokens"] >= 4.0
    assert "req_mean_latency_s" in rep
