"""Multi-tenant inference engine: space-time scheduled decode loop.

R tenants of the same architecture (different weights) are served by ONE
jitted, tenant-vmapped decode step over stacked params + stacked caches —
every projection/FFN GEMM in the model becomes an inter-model batched
super-kernel, which is the paper's mechanism applied to whole models.

All work flows through the shared ``DynamicSpaceTimeScheduler``: each
admitted prefill and each tenant's decode step is submitted as a generic
``Workload`` (bucket, cost, SLO, execute-callback) and dispatched by the
scheduler's pump. The engine therefore inherits admission control,
per-tenant SLO/latency tracking, and straggler eviction from the core
instead of duplicating its own monitor plumbing.

``mode="time_only"`` provides the contrast case: each tenant's decode
cohort gets its OWN bucket, so the scheduler dispatches them sequentially
(one program per tenant per step), modeling CUDA context time-slicing —
a tenant's recorded latency then includes waiting for every tenant ahead
of it in the dispatch order (the paper's linear-slowdown mechanism).
Used by benchmarks/fig3_latency.py and fig4_predictability.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScheduleConfig
from repro.core.scheduler import DynamicSpaceTimeScheduler
from repro.core.workload import Workload
from repro.core.tenancy import stack_params
from repro.models import Model
from repro.serving.kv_cache import SlotManager
from repro.serving.request import InferenceRequest, RequestState
from repro.serving.sampling import SamplingParams, sample


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_tenants: int
    slots_per_tenant: int = 4
    cache_len: int = 256
    mode: str = "space_time"        # "space_time" | "time_only"
    # >0: prefill prompts in fixed-size chunks (one compile per chunk
    # length instead of per prompt length). Requires a non-sliding-window
    # architecture (chunked continuation needs linear caches).
    prefill_chunk: int = 0
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    seed: int = 0
    ewma_alpha: float = 0.2
    eviction_ratio: float = 10.0    # effectively off unless benchmarking isolation
    # optional override for the shared scheduler core (batching policy,
    # admission caps, ...); None derives one from the fields above.
    schedule: Optional[ScheduleConfig] = None


class MultiTenantEngine:
    def __init__(self, model: Model, tenant_params: List[Any], config: EngineConfig):
        assert len(tenant_params) == config.num_tenants
        self.model = model
        self.cfg = config
        self.stacked_params = stack_params(tenant_params)
        self._tenant_params = tenant_params

        R, B = config.num_tenants, config.slots_per_tenant
        single = model.init_caches(B, config.cache_len)
        self.caches = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(), single
        )
        self.slots = SlotManager(R, B)

        # the unified scheduling core: prefill + decode cohorts flow
        # through it as Workloads; it owns latency/SLO tracking.
        schedule = config.schedule or ScheduleConfig(
            batching_window_s=0.0,
            max_superkernel_size=max(128, config.num_tenants),
            latency_ewma_alpha=config.ewma_alpha,
            straggler_eviction_ratio=config.eviction_ratio,
        )
        self.scheduler = DynamicSpaceTimeScheduler(schedule)

        self.queue: List[InferenceRequest] = []
        self.active: Dict[tuple, InferenceRequest] = {}  # (tenant, slot) -> req
        self.finished: List[InferenceRequest] = []
        # flight-recorder shard (repro.obs); the API layer attaches it and
        # taps scheduler.on_dispatch — the engine only records arrivals
        self.recorder = None
        self.last_token = np.zeros((R, B), np.int32)
        self.steps = 0
        self.decode_tokens = 0
        self._sample_key = jax.random.PRNGKey(config.seed)
        self._step_logits: Optional[jax.Array] = None  # (R, B, V)
        self._cohort_step = -1                         # last step decoded merged
        self._pending_caches: Dict[int, Any] = {}      # time_only per-tenant updates
        self._pending_logits: Dict[int, jax.Array] = {}

        # ---- jitted programs -------------------------------------------------
        def _decode_all(params, tokens, caches, lengths):
            return jax.vmap(model.forward_decode)(params, tokens, caches, lengths)

        self._decode_all = jax.jit(_decode_all)

        def _decode_one(params, tokens, caches, lengths):
            return model.forward_decode(params, tokens, caches, lengths)

        self._decode_one = jax.jit(_decode_one)

        def _prefill(params, tokens):
            return model.forward_prefill(params, tokens, cache_len=config.cache_len)

        self._prefill = jax.jit(_prefill)

        def _prefill_cont(params, tokens, caches, start):
            return model.forward_prefill(
                params, tokens, cache_len=config.cache_len,
                caches=caches, start=start,
            )

        self._prefill_cont = jax.jit(_prefill_cont)

    # ---------------------------------------------------------------- monitor
    @property
    def monitor(self):
        """Per-tenant latency/SLO tracking lives in the shared core."""
        return self.scheduler.monitor

    # ------------------------------------------------------------------ intake
    def submit(self, req: InferenceRequest, now: Optional[float] = None) -> None:
        req.arrival_time = now if now is not None else time.perf_counter()
        req.state = RequestState.QUEUED
        if self.recorder is not None:
            self.recorder.record_arrival(
                req.arrival_time, req.tenant_id,
                ("request", len(req.prompt)), True)
        self.queue.append(req)

    # ------------------------------------------------------------------ prefill
    def _admit(self) -> None:
        # Prefill runs at EXACT prompt length (one compile per distinct
        # length). Padding would corrupt SSM/RWKV recurrent state; callers
        # wanting fewer compiles should bucket their prompt lengths.
        # Each admitted prefill is a Workload bucketed by prompt length so
        # the scheduler accounts its latency per tenant.
        remaining = []
        submitted = False
        for req in self.queue:
            slot = self.slots.acquire(req.tenant_id, req.request_id)
            if slot is None:
                remaining.append(req)
                continue
            req.slot = slot
            req.state = RequestState.PREFILLING
            ok = self.scheduler.submit(Workload(
                tenant_id=req.tenant_id,
                bucket=("prefill", len(req.prompt)),
                cost=float(len(req.prompt)),
                slo_s=req.slo_s,
                execute=self._execute_prefill_batch,
                payload=req,
                kind="prefill",
            ))
            if not ok:
                # admission control pushed back: return the slot, retry later
                self.slots.release(req.tenant_id, slot)
                req.slot = None
                req.state = RequestState.QUEUED
                remaining.append(req)
                continue
            submitted = True
        self.queue = remaining
        if submitted:
            self.scheduler.flush()

    def _execute_prefill_batch(self, batch: List[Workload]) -> List[int]:
        """Scheduler executor: prefill each admitted request, install its
        cache into the stacked cohort, and activate its decode slot."""
        outs = []
        for wl in batch:
            req: InferenceRequest = wl.payload
            params_t = jax.tree.map(lambda x: x[req.tenant_id], self.stacked_params)
            tokens = jnp.asarray(np.asarray(req.prompt, np.int32))[None, :]
            logits, cache = self._run_prefill(params_t, tokens)
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            req.first_token_time = time.perf_counter()
            req.prefill_time = req.first_token_time
            self._scatter_slot(req.tenant_id, req.slot, cache)
            self.slots.set_length(req.tenant_id, req.slot, tokens.shape[1])
            self.last_token[req.tenant_id, req.slot] = tok
            req.state = RequestState.DECODING
            self.active[(req.tenant_id, req.slot)] = req
            outs.append(tok)
        return outs

    def _run_prefill(self, params_t, tokens):
        """Whole-prompt or chunked prefill (bounded compile count)."""
        C = self.cfg.prefill_chunk
        S = tokens.shape[1]
        if C <= 0 or S <= C:
            return self._prefill(params_t, tokens)
        logits, cache = self._prefill(params_t, tokens[:, :C])
        pos = C
        while pos < S:
            n = min(C, S - pos)  # ragged tail compiles once per tail length
            logits, cache = self._prefill_cont(
                params_t, tokens[:, pos:pos + n], cache, jnp.int32(pos))
            pos += n
        return logits, cache

    def _scatter_slot(self, tenant: int, slot: int, single_cache: Any) -> None:
        """Insert a prefilled (batch=1) cache into the stacked cohort cache."""

        def upd(big: jax.Array, small: jax.Array, slot_axis: int) -> jax.Array:
            idx = [0] * big.ndim
            idx[0] = tenant
            idx[slot_axis] = slot
            return jax.lax.dynamic_update_slice(
                big, small[None].astype(big.dtype), tuple(idx)
            )

        # unit caches: leaf (R, reps, B, ...) -> slot axis 2
        self.caches["unit"] = jax.tree.map(
            lambda big, small: upd(big, small, 2),
            self.caches["unit"],
            single_cache["unit"],
        )
        # rem caches: leaf (R, B, ...) -> slot axis 1
        self.caches["rem"] = jax.tree.map(
            lambda big, small: upd(big, small, 1),
            self.caches["rem"],
            single_cache["rem"],
        )

    # ------------------------------------------------------------------ decode
    def _lengths(self) -> np.ndarray:
        R, B = self.cfg.num_tenants, self.cfg.slots_per_tenant
        out = np.zeros((R, B), np.int32)
        for t in range(R):
            out[t] = self.slots.lengths(t)
        return out

    def _execute_decode_cohort(self, batch: List[Workload]) -> List[jax.Array]:
        """space_time executor: ONE tenant-vmapped program for the whole
        cohort — every active tenant in the batch shares the dispatch.

        The decode runs exactly once per engine step even if the scheduler
        splits the cohort's workloads across pump batches (caches must
        advance once); later sub-batches reuse the same step's logits."""
        if self._cohort_step != self.steps:
            lengths = jnp.asarray(self._lengths())
            tokens = jnp.asarray(self.last_token)
            logits, self.caches = self._decode_all(
                self.stacked_params, tokens, self.caches, lengths
            )
            self._step_logits = jax.block_until_ready(logits)
            self._cohort_step = self.steps
        return [self._step_logits[wl.payload] for wl in batch]

    def _execute_decode_tenant(self, batch: List[Workload]) -> List[jax.Array]:
        """time_only executor: a per-tenant program with a device sync per
        dispatch (the CUDA context-switch analogue). Cache/logit updates
        are staged and scattered into the stacked trees once per step."""
        outs = []
        for wl in batch:
            t = wl.payload
            params_t = jax.tree.map(lambda x: x[t], self.stacked_params)
            caches_t = jax.tree.map(lambda x: x[t], self.caches)
            tokens_t = jnp.asarray(self.last_token[t])
            lengths_t = jnp.asarray(self.slots.lengths(t), jnp.int32)
            lg, nc = self._decode_one(params_t, tokens_t, caches_t, lengths_t)
            lg = jax.block_until_ready(lg)
            self._pending_caches[t] = nc
            self._pending_logits[t] = lg
            outs.append(lg)
        return outs

    def _apply_pending_tenant_updates(self) -> None:
        """Scatter time_only per-tenant cache/logit updates in one pass."""
        if not self._pending_caches:
            return
        ts = sorted(self._pending_caches)
        idx = jnp.asarray(ts)
        small = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[self._pending_caches[t] for t in ts]
        )
        self.caches = jax.tree.map(
            lambda big, sm: big.at[idx].set(sm.astype(big.dtype)),
            self.caches, small,
        )
        lgs = jnp.stack([self._pending_logits[t] for t in ts])
        if self._step_logits is None or self._step_logits.shape[-1] != lgs.shape[-1]:
            R, B = self.cfg.num_tenants, self.cfg.slots_per_tenant
            self._step_logits = jnp.zeros((R, B, lgs.shape[-1]), lgs.dtype)
        self._step_logits = self._step_logits.at[idx].set(lgs)
        self._pending_caches.clear()
        self._pending_logits.clear()

    def step(self) -> int:
        """One engine iteration: admit + one decode step. Returns #tokens.

        The decode cohort is submitted to the shared scheduler as one
        Workload per active tenant. In space_time mode they share one
        bucket (one merged dispatch — identical completion time for every
        tenant, predictability by construction); in time_only mode each
        tenant gets its own bucket and the scheduler dispatches them
        sequentially.
        """
        self._admit()
        if not self.active:
            return 0

        slo_by_tenant: Dict[int, float] = {}
        slots_by_tenant: Dict[int, int] = {}
        for (t, _), req in self.active.items():
            slo_by_tenant[t] = min(slo_by_tenant.get(t, float("inf")), req.slo_s)
            slots_by_tenant[t] = slots_by_tenant.get(t, 0) + 1
        for t in sorted(slots_by_tenant):
            merged = self.cfg.mode == "space_time"
            ok = self.scheduler.submit(Workload(
                tenant_id=t,
                bucket=("decode", "cohort") if merged else ("decode", t),
                cost=float(slots_by_tenant[t]),
                slo_s=slo_by_tenant[t],
                execute=(self._execute_decode_cohort if merged
                         else self._execute_decode_tenant),
                payload=t,
                kind="decode",
            ))
            if not ok:
                # a dropped decode workload would silently desync caches
                raise RuntimeError(
                    "decode workload rejected by scheduler admission control; "
                    "max_pending_per_tenant must admit one decode workload "
                    "per tenant per step"
                )
        self.scheduler.flush()
        self._apply_pending_tenant_updates()
        logits = self._step_logits

        if self.cfg.sampling.greedy:
            next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        else:
            self._sample_key, sub = jax.random.split(self._sample_key)
            next_tokens = np.asarray(sample(logits, self.cfg.sampling, sub), np.int32)
        produced = 0
        now = time.perf_counter()
        for (t, s), req in list(self.active.items()):
            tok = int(next_tokens[t, s])
            req.generated.append(tok)
            produced += 1
            self.slots.set_length(t, s, self.slots.slots[(t, s)].length + 1)
            self.last_token[t, s] = tok
            if req.done:
                req.finish_time = now
                req.state = RequestState.FINISHED
                self.finished.append(req)
                self.slots.release(t, s)
                del self.active[(t, s)]
        self.steps += 1
        self.decode_tokens += produced
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            self.step()
            if not self.queue and not self.active:
                return
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------------ metrics
    def report(self) -> Dict[str, float]:
        rep = {
            "steps": float(self.steps),
            "decode_tokens": float(self.decode_tokens),
            "finished": float(len(self.finished)),
            "slot_utilization": self.slots.utilization(),
            "scheduler_dispatches": float(self.scheduler.stats.dispatches),
        }
        rep.update(self.monitor.summary())
        # decode-step semantics for the headline percentiles: prefill
        # dispatches (compile-heavy) are tracked too but reported apart
        rep.update(self.monitor.summary_for("decode"))
        rep.update({f"prefill_{k}": v
                    for k, v in self.monitor.summary_for("prefill").items()})
        lats = [r.latency_s for r in self.finished if r.latency_s is not None]
        if lats:
            rep["req_mean_latency_s"] = float(np.mean(lats))
            rep["req_p95_latency_s"] = float(np.percentile(lats, 95))
        return rep
