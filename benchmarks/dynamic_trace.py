"""Section 4 claim: "overheads gradually decrease if we cache super-kernels
as workloads stabilize over time."

DEPRECATION SHIM: this script is now a thin caller of ``repro.api`` —
the mix, the trace, and the simulated replay all come from one
``SystemSpec`` (``workload.mix="single"``), so a live wall-clock run and
``python -m repro simulate --set workload.mix=single ...`` see
bit-identical arrival sequences. The argparse surface below is kept for
existing callers; ``python -m repro calibrate`` is the spec-driven form
of ``--calibrate``.

Stochastic (Poisson) kernel arrivals from R tenants drive the dynamic
scheduler; we report per-quarter mean latency, dispatch count and cache
hit-rate. Expected: hit-rate -> ~1 and latency anneals after the first
quarter (compiles amortized), demonstrating the super-kernel cache doing
its job under non-stationary R.

A live run can additionally fit a ``CalibratedCostModel`` from its own
measured dispatches (``--calibrate PATH``) for later simulated replay.

The ``policy`` knob selects the batching-window policy of the unified
core ("fixed" or "slo_adaptive"); the trace runs under both by default so
the SLO-aware window's latency win shows up on live (wall-clock)
arrivals, not just in the Fig-4 virtual-clock replay.
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import numpy as np

from repro.api import SchedulerSpec, SystemSpec, WorkloadSpec, build_mix, build_trace

# historical pacing: ~3 arrivals per 0.2ms tick of the old sleep loop
RATE_HZ = 15_000.0
ARRIVALS_PER_EVENT = 3


def build_spec(num_events: int, tenants: int, seed: int, policy: str,
               slo_s: float) -> SystemSpec:
    """The one spec both the live replay and the simulated replay run."""
    return SystemSpec(
        workload=WorkloadSpec(
            mix="single", tenants=tenants, process="poisson",
            events=ARRIVALS_PER_EVENT * num_events, seed=seed,
            rate_hz=RATE_HZ, slo_s=slo_s),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32,
                                batching_policy=policy),
    )


def _print_quarters(lat: List[float], hit_marks: Optional[List[float]],
                    policy: str, csv_rows) -> None:
    q = max(1, len(lat) // 4)
    print(f"{'quarter':>8s} {'mean lat ms':>12s} {'hit rate':>9s}")
    for qi in range(4):
        seg = lat[qi * q:(qi + 1) * q]
        if not seg:
            continue
        hit = hit_marks[min((qi + 1) * q, len(hit_marks)) - 1] if hit_marks else float("nan")
        print(f"{qi+1:8d} {np.mean(seg)*1e3:12.3f} {hit:9.2f}")
        if csv_rows is not None:
            csv_rows.append((f"dynamic_trace/{policy}/q{qi+1}",
                             float(np.mean(seg) * 1e6),
                             f"hit_rate={hit:.2f}"))


def run(num_events: int = 200, tenants: int = 12, seed: int = 0, csv_rows=None,
        policy: str = "fixed", slo_s: float = 0.010,
        simulate_only: bool = False, calibrate_path: Optional[str] = None):
    spec = build_spec(num_events, tenants, seed, policy, slo_s)

    if simulate_only:
        print(f"\n=== Dynamic trace (SIMULATED): policy={policy} ===")
        m = spec.build().run_metrics()
        _print_quarters(list(m.lat), None, f"sim/{policy}", csv_rows)
        s = m.summary()
        print(f"final: dispatches={s['dispatches']:.0f} "
              f"problems={s['completed']:.0f} "
              f"attainment={s['slo_attainment']:.2f} "
              f"p95={s['p95_s']*1e3:.3f}ms")
        return s

    import jax
    import jax.numpy as jnp

    from repro.core import DynamicSpaceTimeScheduler, GemmProblem
    from repro.sim import CalibratedCostModel

    mix = build_mix(spec.workload)
    trace = build_trace(spec, mix)

    print(f"\n=== Dynamic trace: cache warm-up under stochastic arrivals "
          f"(policy={policy}) ===")
    g_bucket = mix[0].bucket  # all tenants share the one SGEMM geometry
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    # device-resident per-tenant weights; fresh activations per query
    ws = [jax.random.normal(jax.random.fold_in(key, t),
                            (g_bucket.K, g_bucket.N), jnp.float32)
          for t in range(tenants)]
    xs = [jax.random.normal(jax.random.fold_in(key, 1000 + i),
                            (g_bucket.M, g_bucket.K), jnp.float32)
          for i in range(8)]

    calibrated = CalibratedCostModel() if calibrate_path else None
    sched = DynamicSpaceTimeScheduler(
        spec.scheduler.to_schedule_config(),
        on_dispatch=calibrated.observe if calibrated else None,
    )
    lat: List[float] = []
    hit_marks: List[float] = []

    def collect(done):
        for p in done:
            lat.append(p.completion_time - p.arrival_time)
            hit_marks.append(sched.cache.stats.hit_rate)

    t0 = time.perf_counter()
    for ev in trace:
        # replay the trace's timeline against the wall clock
        delay = (t0 + ev.t_s) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t = ev.spec.tenant_id
        sched.submit(GemmProblem(tenant_id=t,
                                 x=xs[int(rng.integers(len(xs)))],
                                 w=ws[t], slo_s=ev.spec.slo_s))
        collect(sched.pump())
    collect(sched.flush())

    _print_quarters(lat, hit_marks, policy, csv_rows)
    rep = sched.report()
    print(f"final: dispatches={rep['dispatches']:.0f} problems={rep['problems']:.0f} "
          f"hit_rate={rep['cache_hit_rate']:.2f} spread={rep.get('spread', 0):.2%} "
          f"p95={rep.get('p95_s', 0)*1e3:.3f}ms")
    if calibrated is not None:
        calibrated.save(calibrate_path)
        print(f"calibrated cost model ({len(calibrated.table)} keys) "
              f"-> {calibrate_path}")
    return rep


def run_all_policies(num_events: int = 200, tenants: int = 12, seed: int = 0,
                     csv_rows=None, simulate_only: bool = False):
    """Same trace parameters under both batching-window policies."""
    for policy in ("fixed", "slo_adaptive"):
        run(num_events=num_events, tenants=tenants, seed=seed,
            csv_rows=csv_rows, policy=policy, simulate_only=simulate_only)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=200)
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="both",
                    choices=("fixed", "slo_adaptive", "both"))
    ap.add_argument("--simulate", action="store_true",
                    help="replay the same trace on the virtual-clock simulator")
    ap.add_argument("--calibrate", default=None, metavar="PATH",
                    help="fit+save a CalibratedCostModel from live dispatches")
    args = ap.parse_args()
    if args.calibrate and (args.simulate or args.policy == "both"):
        # calibration fits from LIVE dispatches of one scheduler; a
        # simulated run has no measurements and "both" would overwrite
        # the file with whichever policy ran last
        ap.error("--calibrate requires a live run with a single --policy "
                 "(fixed or slo_adaptive), not --simulate or --policy both")
    if args.policy == "both":
        run_all_policies(num_events=args.events, tenants=args.tenants,
                         seed=args.seed, simulate_only=args.simulate)
    else:
        run(num_events=args.events, tenants=args.tenants, seed=args.seed,
            policy=args.policy, simulate_only=args.simulate,
            calibrate_path=args.calibrate)
