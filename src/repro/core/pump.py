"""Clock-agnostic replica pump: the drain machinery both executors share.

``PumpCore`` owns one ``DynamicSpaceTimeScheduler`` on an injected
``Clock`` plus the ripeness-instant drain loop — the unit the solo
simulator, the fleet simulator, and the live fleet are all built from.
The core never imports ``repro.sim``: the simulator subclasses it with a
``VirtualClock`` and a roofline default (``repro.sim.simulator
.ReplicaPump``), while the live fleet (``repro.serving.fleet``) runs the
same class on a ``WallClock``, where ``advance_to`` is a no-op and the
calendar instants are compared against real elapsed time.

That single-core property is the point of the refactor: the ripeness
calendar, the EDF min-update calendar, the skip-pump ULP guard, the
routing signals (``queue_depth`` / ``backlog_s`` / ``estimate_item_s``)
and the recorder taps exist ONCE. A policy conclusion from the simulator
transfers to live serving because it literally is the same pump — only
the clock and the kernels differ.

Performance: ripeness is tracked two ways. Policies declaring
``stable_window`` (the fixed window) get a *calendar*: a lazy-deletion
heap of per-bucket ripeness instants maintained incrementally on submit
and dispatch, making ``next_ripe_time`` O(1) amortized instead of a scan
over every pending bucket per event. Time-dependent policies
(slo_adaptive) keep the legacy scan — their instants drift with the
clock, so cached instants would be stale the moment they were stored.
Both paths compute ripeness with the exact same float expression
(``max(now, oldest + window)``), so the dispatch timeline is
bit-identical between them.
"""

from __future__ import annotations

from collections import deque
from heapq import heappop, heappush
from typing import Callable, List, Optional, Sequence

from repro.config import ScheduleConfig
from repro.core.clock import Clock, WallClock
from repro.core.scheduler import DynamicSpaceTimeScheduler

_NEG_INF = float("-inf")


class PumpCore:
    """One replica of the real scheduler on an injected clock, plus the
    ripeness-instant drain machinery.

    Metric sinks (``accs``) are duck-typed: anything with
    ``add_batch(done)`` — the simulator wires ``MetricsAccumulator``s in.
    ``on_complete`` is the live-serving hook: called with each absorbed
    completion batch so a server can resolve per-request futures without
    the sim paths paying more than one is-None test per dispatch.
    """

    # 1 nanosecond — larger than any float rounding error at realistic
    # trace horizons, negligible against microsecond dispatches
    _RIPE_EPS = 1e-9

    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        clock: Optional[Clock] = None,
        replica_id: Optional[int] = None,
    ):
        self.replica_id = replica_id
        self.clock = clock if clock is not None else WallClock()
        self.cost_model = cost_model
        self.scheduler = DynamicSpaceTimeScheduler(
            schedule or ScheduleConfig(),
            clock=self.clock,
            cost_model=self.cost_model,
            replica_id=replica_id,
        )
        # completions are consumed by the accumulators, not the monitor;
        # per-item history lists would leak a float per event
        self.scheduler.monitor.record_history = False
        # metric sinks every completion is recorded into (solo: one; fleet:
        # the replica's own + the fleet-wide accumulator)
        self.accs: List = []
        # live-serving hook: called with each absorbed completion batch
        self.on_complete: Optional[Callable[[List], None]] = None
        # fleet-only: hardware label for per-replica summaries (hetero
        # fleets), relative chip speed (weighted-affinity routing signal),
        # and an optional ROUTING-time pricing model (per-replica
        # calibrated table) — the true cost_model still drives the clock
        self.spec_name: Optional[str] = None
        self.speed_factor: float = 1.0
        self.route_model: Optional[Callable[[Sequence], float]] = None
        # router's running backlog estimate: Σ est_s of pending items
        self.pending_est_s = 0.0
        # fleet-only (set by the fleet drivers): completion instants of
        # dispatched items, so queue_depth(now) can count work that is
        # modeled as done on this replica's (ahead) clock but still in
        # flight at the fleet's current instant. Off in solo runs — a
        # million-event trace must not accumulate a million floats.
        self.track_inflight = False
        self._inflight: deque = deque()
        # flight-recorder shard (repro.obs); None = recording off, and the
        # hot paths pay exactly one is-None test per arrival
        self.recorder = None
        # ---- ripeness calendar (stable-window policies only) ----
        # _ripe_at maps bucket -> its current ripeness instant
        # (oldest_arrival + window; -inf for cap-full buckets, matching
        # the legacy scan's "full bucket is ripe NOW" via max(now, -inf)).
        # _heap holds (instant, seq, bucket) with lazy deletion: an entry
        # is live iff it equals _ripe_at[bucket]; stale entries are
        # skipped at peek time. seq breaks instant ties without ever
        # comparing bucket keys (buckets aren't orderable).
        policy = self.scheduler.policy
        # deadline-aware (EDF) policies fix each ITEM's ripeness instant at
        # arrival — same incremental calendar, but a push can LOWER a
        # bucket's instant (a tight-SLO item ripens before older relaxed
        # peers), so EDF gets its own note functions below.
        self._edf = policy if getattr(policy, "deadline_aware", False) else None
        self._use_calendar = (
            bool(getattr(policy, "stable_window", False)) or self._edf is not None
        )
        self._window = (
            policy.window_s((), 0.0)
            if self._use_calendar and self._edf is None else 0.0
        )
        self._cap = self.scheduler.schedule.max_superkernel_size
        # preemption can force-dispatch BEFORE any calendar instant, so the
        # skip-pump-at-submit shortcut must stay off — at-risk buckets are
        # caught by pumping at every arrival.
        self._preempt_pump = self.scheduler.schedule.preemption
        self._ripe_at: dict = {}
        self._heap: list = []
        self._seq = 0

    # ------------------------------------------------------------- intake
    def submit(self, w, t_s: float) -> bool:
        """Advance to the arrival instant, admit, and pump immediately.

        The TRUE arrival time is stamped even when this replica's (busy)
        clock has run ahead — queueing delay under overload stays honest.
        (On a wall clock ``advance_to`` is a no-op: real time already is
        the arrival instant.)
        """
        self.clock.advance_to(t_s)
        admitted = self.scheduler.submit(w, now=t_s)
        rec = self.recorder
        if rec is not None:
            rec.record_arrival(t_s, w.tenant_id, w.bucket, admitted,
                               self.scheduler.admit_reason)
        if admitted:
            self.pending_est_s += w.est_s
            if self._use_calendar:
                b = w.bucket
                if self._edf is not None:
                    self._edf_note_push(
                        b, w, len(self.scheduler.queue._buckets[b]))
                else:
                    self._cal_note_push(
                        b, t_s, len(self.scheduler.queue._buckets[b]))
        # pump even when admission rejected: advancing to t_s may have
        # ripened other buckets (drain_until only covers instants < t_s)
        if self._use_calendar and not self._preempt_pump:
            # with the calendar we know the earliest ripeness instant
            # without scanning; skip the (previously unconditional) pump
            # when nothing can possibly be ripe. The guard is a few ULPs
            # wide: the legacy ripeness test computes (now - oldest) >=
            # window while the calendar stores oldest + window — not
            # bit-equivalent at the boundary — and a spuriously attempted
            # pump is a harmless no-op while a skipped-but-due pump would
            # change the timeline.
            m = self._ripe_min()
            now = self.clock.now()
            if m is None or m > now + (1e-9 + abs(now) * 1e-12):
                return admitted
        self._absorb(self.scheduler.pump())
        return admitted

    # ---------------------------------------------------------- event loop
    def _cal_note_push(self, bucket, arrival_s: float, depth: int) -> None:
        """Calendar maintenance after one item lands in ``bucket``."""
        ripe_at = self._ripe_at
        if depth >= self._cap:
            if ripe_at.get(bucket) != _NEG_INF:
                ripe_at[bucket] = _NEG_INF
                self._seq += 1
                heappush(self._heap, (_NEG_INF, self._seq, bucket))
        elif depth == 1:
            # bucket just went empty -> nonempty: its instant is fixed
            # (stable window) at oldest + window
            t = arrival_s + self._window
            ripe_at[bucket] = t
            self._seq += 1
            heappush(self._heap, (t, self._seq, bucket))
        # depths in between leave the instant untouched: the oldest
        # arrival didn't change, so neither did the ripeness instant

    def _cal_note_dispatch(self, done: List) -> None:
        """Recompute the instants of every bucket a pump touched."""
        queue = self.scheduler.queue
        buckets_map = queue._buckets
        ripe_at = self._ripe_at
        window = self._window
        cap = self._cap
        for b in {w.bucket for w in done}:
            q = buckets_map.get(b)
            if not q:
                ripe_at.pop(b, None)   # heap entries die lazily
            elif len(q) >= cap:
                if ripe_at.get(b) != _NEG_INF:
                    ripe_at[b] = _NEG_INF
                    self._seq += 1
                    heappush(self._heap, (_NEG_INF, self._seq, b))
            else:
                t = q[0].arrival_time + window
                if ripe_at.get(b) != t:
                    ripe_at[b] = t
                    self._seq += 1
                    heappush(self._heap, (t, self._seq, b))

    def _edf_note_push(self, bucket, w, depth: int) -> None:
        """EDF calendar maintenance after ``w`` lands in ``bucket``: the
        bucket's instant is the min of its items' fixed ripe_at instants,
        so any push may lower it (min-update, unlike the fixed window
        where only the first item sets it)."""
        ripe_at = self._ripe_at
        if depth >= self._cap:
            if ripe_at.get(bucket) != _NEG_INF:
                ripe_at[bucket] = _NEG_INF
                self._seq += 1
                heappush(self._heap, (_NEG_INF, self._seq, bucket))
            return
        t = self._edf.ripe_at(w)
        cur = ripe_at.get(bucket)
        if cur is None or t < cur:
            ripe_at[bucket] = t
            self._seq += 1
            heappush(self._heap, (t, self._seq, bucket))

    def _edf_note_dispatch(self, done: List) -> None:
        """Recompute EDF instants of every bucket a pump touched."""
        queue = self.scheduler.queue
        buckets_map = queue._buckets
        ripe_at = self._ripe_at
        cap = self._cap
        edf = self._edf
        for b in {w.bucket for w in done}:
            q = buckets_map.get(b)
            if not q:
                ripe_at.pop(b, None)   # heap entries die lazily
            elif len(q) >= cap:
                if ripe_at.get(b) != _NEG_INF:
                    ripe_at[b] = _NEG_INF
                    self._seq += 1
                    heappush(self._heap, (_NEG_INF, self._seq, b))
            else:
                t = min(edf.ripe_at(w) for w in q)
                if ripe_at.get(b) != t:
                    ripe_at[b] = t
                    self._seq += 1
                    heappush(self._heap, (t, self._seq, b))

    def _ripe_min(self) -> Optional[float]:
        """Earliest live calendar instant (lazy-deleting stale entries)."""
        heap = self._heap
        ripe_at = self._ripe_at
        while heap:
            t, _, b = heap[0]
            if ripe_at.get(b) == t:
                return t
            heappop(heap)
        return None

    def next_ripe_time(self) -> Optional[float]:
        """Earliest instant any bucket becomes dispatchable.

        For slack-aware policies the window shrinks as time passes, so
        ``oldest + window(now)`` is an upper bound on the true ripeness
        instant — pumping there is guaranteed to dispatch (the estimate
        errs at most by how much the window shrank in between), which
        keeps the drain loop strictly progressing.
        """
        if self._use_calendar:
            m = self._ripe_min()
            if m is None:
                return None
            now = self.clock.now()
            return m if m > now else now
        sched = self.scheduler
        now = self.clock.now()
        queue, policy = sched.queue, sched.policy
        cap = sched.schedule.max_superkernel_size
        best = None
        for bucket, count in queue.buckets():
            if count >= cap:
                return now
            oldest = queue.oldest_arrival(bucket)
            pending = queue.peek(bucket) if policy.needs_pending else ()
            t = max(now, oldest + policy.window_s(pending, now))
            if best is None or t < best:
                best = t
        return best

    def pump_at(self, t_ripe: float) -> List:
        """Advance to a ripeness instant and pump; nudge one epsilon past
        it if float rounding left the window a ULP short of elapsed."""
        self.clock.advance_to(t_ripe)
        done = self.scheduler.pump()
        if not done:
            self.scheduler.stats.ripe_nudges += 1
            self.clock.advance_to(t_ripe + self._RIPE_EPS)
            done = self.scheduler.pump()
        self._absorb(done)
        return done

    def poll(self) -> List:
        """Pump at the clock's CURRENT instant and absorb whatever ripened
        — the live serving loop's heartbeat, where wall time advances on
        its own and there is nothing to advance_to."""
        done = self.scheduler.pump()
        if done:
            self._absorb(done)
        return done

    def drain_until(self, t_limit: float) -> None:
        """Pump every bucket that ripens strictly before ``t_limit``."""
        while True:
            t_ripe = self.next_ripe_time()
            if t_ripe is None or t_ripe >= t_limit:
                return
            if not self.pump_at(t_ripe):
                return  # estimate failed to ripen anything; arrivals resume

    def drain_tail(self) -> None:
        """Drain at exact ripeness instants, then force-flush the rest."""
        sched = self.scheduler
        while len(sched.queue):
            t_ripe = self.next_ripe_time()
            if t_ripe is None or not self.pump_at(t_ripe):
                self._absorb(sched.flush())
                break

    def _absorb(self, done: List) -> None:
        if not done:
            return
        if self._use_calendar:
            if self._edf is not None:
                self._edf_note_dispatch(done)
            else:
                self._cal_note_dispatch(done)
        if self.track_inflight:
            # sequential -= preserves the exact float accumulation order
            # the routing-signal contract (backlog_s) was baselined with
            pending = self.pending_est_s
            inflight_append = self._inflight.append
            for w in done:
                pending -= w.est_s
                inflight_append(w.completion_time)
            self.pending_est_s = pending if pending > 0.0 else 0.0
        for acc in self.accs:
            acc.add_batch(done)
        if self.on_complete is not None:
            self.on_complete(done)

    # ------------------------------------------------------ routing signals
    def queue_depth(self, now: Optional[float] = None) -> int:
        """Occupancy as a router sees it: items pending in the queue plus
        items whose modeled completion lies beyond the fleet's current
        instant (this replica's clock ran ahead; the work is still in
        flight in fleet time even though this replica already priced it).
        Without ``now`` (or in-flight tracking off) it is just the queue.
        """
        depth = len(self.scheduler.queue)
        if now is None or not self.track_inflight:
            return depth
        inflight = self._inflight
        while inflight and inflight[0] <= now:
            inflight.popleft()
        return depth + len(inflight)

    def backlog_s(self, now: float) -> float:
        """Estimated seconds until this replica would run dry: residual
        busy time (its clock ahead of global ``now``) plus the estimated
        cost of everything still queued."""
        return max(0.0, self.clock.now() - now) + self.pending_est_s

    def estimate_item_s(self, w) -> float:
        """Estimated seconds this item adds to THIS replica.

        If the item's bucket already has pending items here it rides the
        forming super-kernel — marginal roofline cost only, compile shared
        with the batch. Otherwise it opens a fresh dispatch: full solo
        cost, plus the compile term when this replica's cache is cold for
        the bucket (the warm-affinity signal).

        When a ``route_model`` is attached (fleet calibration: this
        replica's measured-cost table), routing prices through IT instead
        of the true model — the convergence loop that turns wrong priors
        into measured per-replica costs."""
        model = self.route_model if self.route_model is not None \
            else self.cost_model
        if self.scheduler.queue.head(w.bucket) is not None:
            item_s = getattr(model, "item_s", None)
            if item_s is not None:
                return item_s(w)
        estimate = getattr(model, "estimate", None)
        if estimate is not None:
            return estimate((w,))
        return model((w,))

    # -------------------------------------------------------- observability
    def attach_recorder(self, shard) -> None:
        """Record this replica's events into a flight-recorder shard:
        arrivals via ``submit`` (and the chunked intake), dispatch spans
        via an ``on_dispatch`` tap composed OVER any existing tap
        (calibration keeps working underneath). Must run after the final
        cost model is in place — the tap captures its ``dispatch_cold``
        array for cold/warm labeling."""
        from repro.obs.recorder import dispatch_tap

        self.recorder = shard
        # the scheduler emits preemption decisions directly (they happen
        # inside its EDF pump, not at the pump boundary)
        self.scheduler.recorder = shard
        shard.spec_name = self.spec_name
        model = self.cost_model
        base = getattr(model, "base", model)
        shard.strategy = getattr(base, "strategy", None) or getattr(
            getattr(base, "prior", None), "strategy", None)
        self.scheduler.on_dispatch = dispatch_tap(
            shard, model=model, prev=self.scheduler.on_dispatch)

    def freeze(self, acc, sim_duration_s: float):
        """Freeze one accumulator against this replica's scheduler stats."""
        sched = self.scheduler
        return acc.freeze(
            sim_duration_s=sim_duration_s,
            busy_time_s=sched.stats.busy_time_s,
            dispatches=sched.stats.dispatches,
            rejected=sched.stats.rejected,
            evicted_tenants=len(sched.evicted),
            ripe_nudges=sched.stats.ripe_nudges,
            deadline_rejected=sched.stats.deadline_rejected,
            oversubscribed=sched.stats.oversubscribed,
            preemptions=sched.stats.preemptions,
        )


def drain_merged(pumps: Sequence[PumpCore], t_limit: float) -> None:
    """Merged global timeline across replicas: pump whichever replica
    ripens earliest, repeatedly, until no replica ripens before
    ``t_limit``.

    A replica whose ripeness estimate fails to dispatch (slack-aware
    window shrank underneath it) is stalled until the next arrival —
    the same per-replica semantics as the solo drain loop, without
    letting one stalled replica block the others.
    """
    stalled = 0  # bitmask — replica counts are small
    while True:
        best_i, best_t = -1, t_limit
        for i, p in enumerate(pumps):
            if stalled & (1 << i):
                continue
            t = p.next_ripe_time()
            if t is not None and t < best_t:
                best_i, best_t = i, t
        if best_i < 0:
            return
        if not pumps[best_i].pump_at(best_t):
            stalled |= 1 << best_i


def drain_fleet_tail(pumps: Sequence[PumpCore],
                     drain_until: Callable[[float], None]) -> None:
    """Fleet tail drain: keep merging ripeness instants until every queue
    is dry, then force-flush whatever the estimates could not ripen."""
    while any(len(p.scheduler.queue) for p in pumps):
        before = sum(len(p.scheduler.queue) for p in pumps)
        drain_until(float("inf"))
        if sum(len(p.scheduler.queue) for p in pumps) == before:
            for p in pumps:
                if len(p.scheduler.queue):
                    p._absorb(p.scheduler.flush())
            break
