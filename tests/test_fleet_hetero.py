"""Heterogeneous + elastic fleets: per-replica hardware specs, speed-aware
routing, the backlog autoscaler (cold spin-up, deterministic scale-event
timeline), per-replica calibration tables — plus regression tests for the
two PR-4 bugfixes (affinity remap stability under elastic N, FleetMetrics
divide-by-zero guards on degenerate windows).
"""

import types

import pytest

from repro.config import ScheduleConfig
from repro.launch.roofline import TPU_V5E
from repro.sim import (
    Arrival,
    BacklogAutoscaler,
    ColdStartCostModel,
    FleetCalibrator,
    FleetMetrics,
    FleetSimulator,
    MetricsAccumulator,
    ReplicaPump,
    RooflineCostModel,
    SimWorkload,
    TenantAffinityRouter,
    fleet_capacity_hz,
    fleet_sgemm_mix,
    make_autoscaler,
    make_router,
    make_trace,
    resolve_spec,
    simulate_fleet,
)

SCHED = ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32)
MIX = fleet_sgemm_mix(12)
SPECS = ["v5e", "v5e_half"]                      # cycled over the fleet
FLEET_SPECS = ["v5e", "v5e_half", "v5e", "v5e_half"]
HZ = 0.85 * fleet_capacity_hz(MIX, FLEET_SPECS)  # rho vs aggregate capacity


def _trace(events=2500, seed=0, process="mmpp"):
    return make_trace(process, MIX, HZ, events, seed=seed)


def _hetero(events=2500, seed=0, router="least_cost", **kw):
    return simulate_fleet(_trace(events, seed), 4, router=router,
                          schedule=SCHED, specs=SPECS, compile_s=2e-4, **kw)


def _scaler(**kw):
    kw.setdefault("min_replicas", 1)
    kw.setdefault("max_replicas", 4)
    kw.setdefault("up_backlog_s", 0.005)
    kw.setdefault("down_backlog_s", 0.001)
    kw.setdefault("interval_s", 50.0 / HZ)
    kw.setdefault("cooldown_ticks", 2)
    return BacklogAutoscaler(**kw)


def _pump(spec="v5e", replica_id=0, compile_s=0.0):
    base = RooflineCostModel(spec=resolve_spec(spec), strategy="space_time")
    model = base if compile_s == 0.0 else ColdStartCostModel(
        base, compile_s=compile_s)
    p = ReplicaPump(schedule=SCHED, cost_model=model, replica_id=replica_id)
    p.track_inflight = True
    return p


# ------------------------------------------------------------ hardware specs
class TestHardwareSpecs:
    def test_scaled_halves_roofs_keeps_overheads(self):
        half = TPU_V5E.scaled(0.5)
        assert half.peak_flops == pytest.approx(TPU_V5E.peak_flops / 2)
        assert half.hbm_bw == pytest.approx(TPU_V5E.hbm_bw / 2)
        # launch costs are chip-architecture constants, not roof terms
        assert half.dispatch_overhead_s == TPU_V5E.dispatch_overhead_s
        assert half.pipe_fill_s() == TPU_V5E.pipe_fill_s()

    def test_scaled_rejects_nonpositive(self):
        with pytest.raises(ValueError, match="factor"):
            TPU_V5E.scaled(0.0)

    def test_resolve_spec_names_and_passthrough(self):
        assert resolve_spec("v5e") is TPU_V5E
        assert resolve_spec(TPU_V5E) is TPU_V5E
        assert resolve_spec("v5e_half").peak_flops < TPU_V5E.peak_flops
        with pytest.raises(ValueError, match="unknown hardware spec"):
            resolve_spec("tpu_v9000")

    def test_specs_and_cost_model_mutually_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            FleetSimulator(2, specs=SPECS,
                           cost_model=RooflineCostModel())


# ----------------------------------------------------------- hetero routing
class TestHeterogeneousFleet:
    def test_replica_specs_cycle_and_export(self):
        m = _hetero(events=800)
        assert m.replica_specs == ["tpu_v5e", "v5e_half",
                                   "tpu_v5e", "v5e_half"]
        assert '"spec"' in m.to_json()

    def test_item_estimate_doubles_on_half_speed_chip(self):
        fast, slow = _pump("v5e", 0), _pump("v5e_half", 1)
        w = SimWorkload(MIX[0], MIX[0].cost)
        # pure roofline term scales exactly 2x; the full estimate includes
        # unscaled launch overheads so it sits between 1x and 2x
        assert slow.estimate_item_s(w) > 1.5 * fast.estimate_item_s(w) / 2
        assert slow.estimate_item_s(w) > fast.estimate_item_s(w)

    def test_least_cost_prefers_fast_replica_under_contention(self):
        """Equal queues, equal caches: the speed difference alone must
        steer the arrival to the fast chip."""
        r = make_router("least_cost")
        fast, slow = _pump("v5e", 0), _pump("v5e_half", 1)
        for p in (fast, slow):  # same queue depth on both
            for _ in range(4):
                p.scheduler.submit(SimWorkload(MIX[0], MIX[0].cost), now=0.0)
        assert r.route(MIX[1], [slow, fast], 0.0) == 1

    def test_least_cost_routes_more_work_to_fast_chips(self):
        m = _hetero(events=3000)
        fast = sum(c for c, s in zip(m.routed_counts, m.replica_specs)
                   if s == "tpu_v5e")
        slow = sum(c for c, s in zip(m.routed_counts, m.replica_specs)
                   if s == "v5e_half")
        assert fast > slow

    def test_speed_aware_beats_oblivious_p95_on_mixed_fleet(self):
        """The fleet_hetero --check contract at its pinned seed."""
        rr = _hetero(router="round_robin").summary()["p95_s"]
        lc = _hetero(router="least_cost").summary()["p95_s"]
        assert lc <= rr

    def test_hetero_goodput_not_below_equal_aggregate_twin(self):
        """4 mixed replicas (aggregate 3x v5e) vs 3 full-speed replicas:
        the old chips must add capacity, not subtract it. Run at the
        fleet_hetero sweep's 5000-event cell size — shorter traces
        over-weight the mixed fleet's extra (4 vs 3 caches) compile
        bill."""
        het = _hetero(events=5000).summary()["goodput_cost_per_s"]
        twin = simulate_fleet(
            _trace(5000), 3, router="least_cost", schedule=SCHED,
            cost_model=RooflineCostModel(strategy="space_time"),
            compile_s=2e-4).summary()["goodput_cost_per_s"]
        assert het >= twin * (1.0 - 1e-6)

    def test_hetero_deterministic(self):
        assert _hetero(seed=7).to_json() == _hetero(seed=7).to_json()


# --------------------------------------------------------------- scale-down
class TestScaleDown:
    def _replica(self, backlog):
        return types.SimpleNamespace(backlog_s=lambda now: backlog)

    def test_retires_cheapest_drainer(self):
        from repro.sim.autoscale import pick_scale_down

        replicas = [self._replica(0.5), self._replica(0.01),
                    self._replica(0.2)]
        assert pick_scale_down(replicas, 0.0) == 1

    def test_equal_costs_retire_newest(self):
        # the historical tie-break: idle fleets (all-zero backlogs) keep
        # retiring the NEWEST replica, preserving warmed caches and the
        # pre-cost-aware scale-event timelines
        from repro.sim.autoscale import pick_scale_down

        replicas = [self._replica(0.0)] * 4
        assert pick_scale_down(replicas, 0.0) == 3
        mixed = [self._replica(0.1), self._replica(0.0),
                 self._replica(0.1), self._replica(0.0)]
        assert pick_scale_down(mixed, 0.0) == 3

    def test_fleet_retires_loaded_replica_last(self):
        # one busy replica + idle newer ones: scale-down must not pick
        # the busy one even though cost-unaware retire-newest never would
        # either; reverse the load so the NEWEST is the busy one
        fleet = FleetSimulator(3, schedule=SCHED, cost_model=
                               RooflineCostModel(strategy="space_time"),
                               compile_s=0.0, autoscaler=_scaler(),
                               start_s=0.0)
        w = SimWorkload(MIX[0], MIX[0].cost)
        newest = fleet.active[2]
        for _ in range(50):
            newest.scheduler.submit(SimWorkload(MIX[0], MIX[0].cost),
                                    now=0.0)
            newest.pending_est_s += newest.estimate_item_s(w)
        from repro.sim.autoscale import pick_scale_down

        assert pick_scale_down(fleet.active, 0.0) != 2


# --------------------------------------------------------------- autoscaler
class TestAutoscaler:
    def test_validation(self):
        with pytest.raises(ValueError, match="min_replicas"):
            BacklogAutoscaler(min_replicas=3, max_replicas=2)
        with pytest.raises(ValueError, match="hysteresis"):
            BacklogAutoscaler(up_backlog_s=0.001, down_backlog_s=0.002)
        with pytest.raises(ValueError, match="interval_s"):
            BacklogAutoscaler(interval_s=0.0)
        with pytest.raises(ValueError, match="unknown autoscaler"):
            make_autoscaler("clairvoyant")

    def test_hysteresis_and_cooldown(self):
        scaler = _scaler(cooldown_ticks=2)
        busy = types.SimpleNamespace(backlog_s=lambda now: 1.0)
        assert scaler.decide([busy], 0.0) == 2       # up
        assert scaler.decide([busy, busy], 0.0) == 2  # cooldown tick 1
        assert scaler.decide([busy, busy], 0.0) == 2  # cooldown tick 2
        assert scaler.decide([busy, busy], 0.0) == 3  # cooldown over
        idle = types.SimpleNamespace(backlog_s=lambda now: 0.0)
        calm = types.SimpleNamespace(backlog_s=lambda now: 0.003)
        scaler2 = _scaler(cooldown_ticks=0)
        assert scaler2.decide([calm, calm], 0.0) == 2  # inside the band
        assert scaler2.decide([idle, idle], 0.0) == 1  # down
        assert scaler2.decide([idle], 0.0) == 1        # min floor

    def test_scales_up_under_load_and_all_events_complete(self):
        m = simulate_fleet(_trace(3000), 1, router="least_cost",
                           schedule=SCHED, specs=SPECS, compile_s=2e-4,
                           autoscaler=_scaler())
        assert m.scale_ups >= 1
        assert m.final_active > 1
        assert m.merged.completed == 3000
        assert sum(m.routed_counts) == 3000
        for e in m.scale_events:  # full, typed timeline
            assert set(e) == {"t_s", "action", "replica_id", "active",
                              "signal_backlog_s"}

    def test_spawned_replica_pays_full_cold_cache(self):
        fleet = FleetSimulator(1, schedule=SCHED, specs=SPECS,
                               compile_s=2e-4, autoscaler=_scaler(),
                               start_s=0.0)
        fleet.pumps[0].cost_model((SimWorkload(MIX[0], MIX[0].cost),))
        p = fleet._spawn(5.0)
        assert p.clock.now() == 5.0          # clock starts at spin-up
        assert p.replica_id == 1
        assert not p.cost_model._warm        # EMPTY compile cache
        assert p.cost_model.estimate((SimWorkload(MIX[0], MIX[0].cost),)) \
            > fleet.pumps[0].cost_model.estimate(
                (SimWorkload(MIX[0], MIX[0].cost),))

    def test_spinup_delays_first_work(self):
        fleet = FleetSimulator(1, schedule=SCHED, specs=SPECS,
                               compile_s=0.0,
                               autoscaler=_scaler(spinup_s=0.5))
        scaler = fleet.autoscaler
        # force one up decision through the fleet's own applier
        scaler.decide = lambda pumps, now: len(pumps) + 1
        fleet._apply_autoscale(2.0)
        spawned = fleet.pumps[-1]
        # the new replica's clock starts spinup_s AFTER the decision: it
        # cannot dispatch anything earlier than t=2.5
        assert spawned.clock.now() == pytest.approx(2.5)
        assert fleet.scale_events[-1].t_s == 2.0
        assert fleet.scale_events[-1].action == "up"

    def test_scale_down_retires_newest_but_drains_it(self):
        # down threshold so high the fleet sheds a replica at every tick
        scaler = _scaler(min_replicas=1, max_replicas=2,
                         up_backlog_s=10.0, down_backlog_s=9.0,
                         cooldown_ticks=0)
        m = simulate_fleet(_trace(2000), 2, router="round_robin",
                           schedule=SCHED, specs=SPECS, compile_s=0.0,
                           autoscaler=scaler)
        assert m.scale_downs >= 1
        assert m.final_active == 1
        assert m.merged.completed == 2000    # retired replica drained

    def test_autoscale_deterministic_including_scale_events(self):
        def go():
            return simulate_fleet(_trace(2500, seed=11), 1,
                                  router="least_cost", schedule=SCHED,
                                  specs=SPECS, compile_s=2e-4,
                                  autoscaler=_scaler(spinup_s=1e-4))
        a, b = go(), go()
        assert a.scale_events and a.scale_events == b.scale_events
        assert a.to_json() == b.to_json()

    def test_bench_rows_carry_scale_signals(self):
        m = simulate_fleet(_trace(3000), 1, router="least_cost",
                           schedule=SCHED, specs=SPECS, compile_s=2e-4,
                           autoscaler=_scaler())
        names = [r[0] for r in m.bench_rows("x")]
        assert "x/scale_events" in names and "x/final_active" in names
        s = m.summary()
        assert s["scale_ups"] >= 1.0 and s["final_active"] >= 1.0


# -------------------------------------------------------- fleet calibration
class TestFleetCalibration:
    def test_tables_keyed_by_replica_and_wired_to_routing(self):
        cal = FleetCalibrator()
        sim = FleetSimulator(3, router="round_robin", schedule=SCHED,
                             specs=SPECS, compile_s=0.0, calibration=cal)
        for i, p in enumerate(sim.pumps):
            assert p.route_model is cal.for_replica(i)
        sim.run(_trace(600, process="poisson"))
        assert set(cal.models) == {0, 1, 2}
        assert cal.observations > 0

    def test_calibration_converges_to_per_replica_speed(self):
        """Half-speed chips must FIT ~slower costs than full-speed chips
        for the same (bucket, pow2-R) keys — measured, not prior."""
        cal = FleetCalibrator()
        simulate_fleet(_trace(5000), 4, router="least_cost", schedule=SCHED,
                       specs=SPECS, compile_s=0.0, calibration=cal)
        fast, slow = cal.models[0].table, cal.models[1].table
        shared = set(fast) & set(slow)
        assert shared
        ratios = [slow[k] / fast[k] for k in shared]
        # roofline terms scale 2x, launch overheads don't: ratio in (1, 2]
        assert sum(r > 1.2 for r in ratios) >= len(ratios) / 2

    def test_calibrated_routing_keeps_merge_marginal_pricing(self):
        """A calibrated route_model must still price joining a forming
        super-kernel at the marginal roofline cost, not a full solo
        dispatch (CalibratedCostModel.item_s delegates to the prior)."""
        cal = FleetCalibrator()
        sim = FleetSimulator(2, router="least_cost", schedule=SCHED,
                             specs=SPECS, compile_s=2e-4, calibration=cal)
        pump = sim.pumps[0]
        w = SimWorkload(MIX[0], MIX[0].cost)
        solo = pump.estimate_item_s(w)           # empty queue: full cost
        pump.scheduler.submit(SimWorkload(MIX[0], MIX[0].cost), now=0.0)
        marginal = pump.estimate_item_s(w)       # rides the forming batch
        assert marginal < solo

    def test_calibration_fits_warm_costs_not_cold(self):
        """The fleet tap subtracts the compile term from cold dispatches:
        a replica must not price a key HIGHER right after compiling it
        than a replica that never saw it (that would invert warm-cache
        affinity)."""
        compile_s = 5e-3  # huge vs the ~us dispatch costs: unmissable
        cal = FleetCalibrator()
        sim = FleetSimulator(1, router="round_robin", schedule=SCHED,
                             specs=["v5e"], compile_s=compile_s,
                             calibration=cal)
        sim.run([Arrival(0.0, MIX[0], MIX[0].cost)])
        (key, fitted), = cal.models[0].table.items()
        warm = RooflineCostModel(strategy="space_time")(
            (SimWorkload(MIX[0], MIX[0].cost),))
        assert fitted == pytest.approx(warm)     # compile term excluded

    def test_solo_tap_files_under_sentinel_replica(self):
        cal = FleetCalibrator()
        cal.observe((SimWorkload(MIX[0], MIX[0].cost),), 1e-3,
                    replica_id=None)
        assert set(cal.models) == {-1}

    def test_json_roundtrip_preserves_tables_and_counts(self, tmp_path):
        cal = FleetCalibrator(ewma_alpha=0.5)
        batch = (SimWorkload(MIX[0], MIX[0].cost),)
        for rid, secs in ((0, 1e-3), (0, 2e-3), (1, 4e-3)):
            cal.observe(batch, secs, replica_id=rid)
        path = str(tmp_path / "fleet_costs.json")
        cal.save(path)
        loaded = FleetCalibrator.load(path)
        assert loaded.to_json() == cal.to_json()
        assert loaded.models[0].counts == cal.models[0].counts


# ------------------------------------------- bugfix: affinity pin stability
class TestAffinityStability:
    def _tenants(self, n=64):
        return [types.SimpleNamespace(tenant_id=t) for t in range(n)]

    def test_only_rebalanced_tenants_move_on_scale_up(self):
        """Adding a replica must keep every tenant either on its old
        replica (by id) or moved to the NEW one — no shuffling among
        survivors (the old t mod N pinning reshuffled ~everyone)."""
        before = [_pump(replica_id=i) for i in range(4)]
        after = before + [_pump(replica_id=4)]
        moved = 0
        for w in self._tenants():
            old = before[TenantAffinityRouter.pin(w, before)].replica_id
            new = after[TenantAffinityRouter.pin(w, after)].replica_id
            if new != old:
                assert new == 4  # may only move TO the newcomer
                moved += 1
        # expected remap fraction ~1/5; anything near full reshuffle fails
        assert 0 < moved < 64 // 2

    def test_only_orphaned_tenants_move_on_scale_down(self):
        before = [_pump(replica_id=i) for i in range(4)]
        after = before[:-1]  # retire replica 3
        for w in self._tenants():
            old = before[TenantAffinityRouter.pin(w, before)].replica_id
            new = after[TenantAffinityRouter.pin(w, after)].replica_id
            if old != 3:
                assert new == old  # survivors keep their pin (warm cache)

    def test_pins_weighted_by_chip_speed(self):
        """On a mixed fleet, full-speed replicas must win ~2x the tenants
        of half-speed ones (weighted rendezvous: affinity sees the speed
        difference, not just the replica count)."""
        pumps = [_pump(spec, i) for i, spec in
                 enumerate(["v5e", "v5e_half", "v5e", "v5e_half"])]
        pumps[0].speed_factor = pumps[2].speed_factor = 1.0
        pumps[1].speed_factor = pumps[3].speed_factor = 0.5
        fast = slow = 0
        for w in self._tenants(300):
            i = TenantAffinityRouter.pin(w, pumps)
            if i in (0, 2):
                fast += 1
            else:
                slow += 1
        # expectation: 2/3 fast vs 1/3 slow; require a clear majority
        assert fast > 1.5 * slow

    def test_pin_keys_on_replica_id_not_position(self):
        pumps = [_pump(replica_id=i) for i in range(4)]
        w = self._tenants(1)[0]
        idx = TenantAffinityRouter.pin(w, pumps)
        rotated = pumps[1:] + pumps[:1]
        assert rotated[TenantAffinityRouter.pin(w, rotated)].replica_id \
            == pumps[idx].replica_id

    def test_round_robin_survives_shrinking_fleet(self):
        r = make_router("round_robin")
        pumps = [_pump(replica_id=i) for i in range(3)]
        assert [r.route(MIX[0], pumps, 0.0) for _ in range(3)] == [0, 1, 2]
        # fleet shrinks: stored cursor must not index out of range
        assert r.route(MIX[0], pumps[:2], 0.0) in (0, 1)


# ------------------------------------------ bugfix: metric edge-case guards
class TestFleetMetricsGuards:
    def _freeze_empty(self):
        return MetricsAccumulator().freeze(
            sim_duration_s=0.0, busy_time_s=0.0, dispatches=0)

    def test_empty_trace_yields_defined_zeros(self):
        m = simulate_fleet([], 2, schedule=SCHED)
        assert m.routing_imbalance == 0.0
        assert m.utilization_spread == 0.0
        assert m.cold_start_fraction == 0.0
        assert m.cold_fraction_halves() == (0.0, 0.0)
        assert "NaN" not in m.to_json()
        assert "Infinity" not in m.to_json()

    def test_single_completion_window(self):
        """One arrival: the second half of the cold series is empty and
        every ratio has a 0 or 1-sized denominator — all must stay
        finite."""
        m = simulate_fleet([Arrival(0.0, MIX[0], MIX[0].cost)], 2,
                           schedule=SCHED, compile_s=2e-4)
        assert m.merged.completed == 1
        first, second = m.cold_fraction_halves()
        assert first == 1.0 and second == 0.0
        assert m.routing_imbalance >= 0.0
        assert "NaN" not in m.to_json()

    def test_direct_degenerate_construction(self):
        """The accessors are total even over a fully empty FleetMetrics
        (no replicas, no routed counts, no cold series)."""
        import numpy as np

        m = FleetMetrics(
            merged=self._freeze_empty(), per_replica=[], routed_counts=[],
            router="jsq", cold_times=np.zeros(0), cold_flags=np.zeros(0))
        assert m.utilization_spread == 0.0
        assert m.routing_imbalance == 0.0
        assert m.cold_fraction_halves() == (0.0, 0.0)
        assert m.scale_ups == 0 and m.scale_downs == 0
        s = m.summary()
        assert s["utilization"] == 0.0 and s["replicas"] == 0.0
        assert "NaN" not in m.to_json()

    def test_unrouted_spun_up_replica_keeps_json_finite(self):
        """A replica spun up at the very end completes nothing; its
        summary and the fleet signals must still be defined."""
        scaler = _scaler(up_backlog_s=1e-9, down_backlog_s=0.0,
                         cooldown_ticks=0, spinup_s=10.0)  # never ready
        # least_cost prices the 10s of residual spin-up as backlog, so the
        # new replica never receives an arrival — the degenerate case
        m = simulate_fleet(_trace(400, process="poisson"), 1,
                           router="least_cost", schedule=SCHED, specs=SPECS,
                           compile_s=2e-4, autoscaler=scaler)
        assert m.scale_ups >= 1
        assert min(m.routed_counts) == 0
        assert m.merged.completed == 400
        assert "NaN" not in m.to_json()
        # the idle replica's future-dated (spawn + 10s spin-up) clock must
        # NOT stretch the fleet horizon past the work actually done
        assert m.merged.sim_duration_s < 1.0
