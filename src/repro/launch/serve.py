"""Serving launcher: multi-tenant space-time engine with a stochastic
request trace (the end-to-end serving driver).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b -R 4 \
        --requests 24 --rate 20
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("-R", "--tenants", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=50.0, help="arrivals/sec (Poisson)")
    ap.add_argument("--max-new-tokens", type=int, default=10)
    ap.add_argument("--mode", default="space_time", choices=["space_time", "time_only"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(smoke_variant(get_config(args.arch)), dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = [model.init(jax.random.fold_in(key, t)) for t in range(args.tenants)]
    engine = MultiTenantEngine(
        model, params,
        EngineConfig(num_tenants=args.tenants, slots_per_tenant=2,
                     cache_len=96, mode=args.mode),
    )

    rng = np.random.RandomState(args.seed)
    pending = args.requests
    next_arrival = time.perf_counter()
    print(f"serving {args.requests} requests over {args.tenants} tenants "
          f"({args.mode}, ~{args.rate}/s Poisson)")
    while pending > 0 or engine.queue or engine.active:
        now = time.perf_counter()
        while pending > 0 and now >= next_arrival:
            engine.submit(InferenceRequest(
                tenant_id=int(rng.randint(args.tenants)),
                prompt=list(rng.randint(1, cfg.vocab_size, size=6)),
                max_new_tokens=args.max_new_tokens,
            ))
            pending -= 1
            next_arrival += rng.exponential(1.0 / args.rate)
        engine.step()

    rep = engine.report()
    print(f"\nfinished={rep['finished']:.0f} tokens={rep['decode_tokens']:.0f} "
          f"steps={rep['steps']:.0f}")
    print(f"step latency p50={rep['p50_s']*1e3:.1f}ms p95={rep['p95_s']*1e3:.1f}ms "
          f"inter-tenant spread={rep.get('spread', 0):.1%}")
    lat = [r.latency_s for r in engine.finished if r.latency_s]
    ttft = [r.ttft_s for r in engine.finished if r.ttft_s]
    print(f"request latency mean={np.mean(lat)*1e3:.0f}ms  "
          f"TTFT mean={np.mean(ttft)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
