"""The flight recorder (repro.obs): zero overhead when off, byte-exact
determinism when on, and the three read paths (Chrome trace export,
windowed telemetry, CLI).

The load-bearing contracts:

  * recorder OFF: metrics JSON is byte-identical to the committed
    pre-recorder fixtures (tests/data/pre_obs_metrics_*.json) — the
    recorder hooks and the incremental straggler-median rewrite are
    behavior-neutral;
  * recorder ON: metrics are unchanged, and same-seed runs export
    byte-identical traces — including ``workers=K`` sharded fleets,
    whose shards are shipped back from forked workers and merged.
"""

import json
import statistics

import pytest

from repro.api import ObservabilitySpec, SystemSpec
from repro.api.cli import main as cli_main
from repro.core.slo import LatencyMonitor
from repro.obs import (
    FlightRecorder,
    export_chrome_trace,
    windowed_series,
)

SOLO = {"workload.events": 3000, "workload.seed": 7,
        "cost_model.compile_us": 50.0}
FLEET = {"workload.events": 3000, "workload.seed": 11,
         "workload.mix": "fleet", "workload.tenants": 12,
         "fleet.replicas": 3, "fleet.specs": ["v5e", "v5e_half"],
         "fleet.autoscale": {"max_replicas": 5, "interval_s": 0.05},
         "router.policy": "least_cost", "cost_model.compile_us": 200.0}
SHARDED = {"workload.events": 3000, "workload.seed": 13,
           "workload.mix": "fleet", "fleet.replicas": 4,
           "fleet.workers": 2, "router.policy": "round_robin",
           "cost_model.compile_us": 100.0}


def spec_for(overrides, recorder=False, **extra) -> SystemSpec:
    ov = dict(overrides)
    if recorder:
        ov["observability.enabled"] = True
    ov.update(extra)
    return SystemSpec().replace(**ov)


def run_recorded(overrides, **extra):
    ex = spec_for(overrides, recorder=True, **extra).build()
    m = ex.run_metrics()
    return m, ex.last_recorder


# ------------------------------------------------------------- off by default
class TestRecorderOff:
    @pytest.mark.parametrize("name,overrides", [
        ("solo", SOLO), ("fleet", FLEET), ("sharded", SHARDED)])
    def test_metrics_bytes_match_pre_recorder_fixtures(self, name, overrides):
        got = spec_for(overrides).build().run_metrics().to_json() + "\n"
        with open(f"tests/data/pre_obs_metrics_{name}.json") as fh:
            assert got == fh.read()

    def test_no_recorder_attached(self):
        ex = spec_for(SOLO).build()
        ex.run_metrics()
        assert ex.last_recorder is None


# ---------------------------------------------------------------- determinism
class TestDeterminism:
    def test_solo_trace_byte_identical_across_runs(self):
        _, rec1 = run_recorded(SOLO)
        _, rec2 = run_recorded(SOLO)
        assert export_chrome_trace(rec1) == export_chrome_trace(rec2)

    def test_recorder_does_not_change_metrics(self):
        base = spec_for(FLEET).build().run_metrics().to_json()
        recorded, _ = run_recorded(FLEET)
        assert recorded.to_json() == base

    def test_sharded_matches_single_process(self):
        solo_ov = dict(SHARDED, **{"fleet.workers": 1})
        _, rec1 = run_recorded(solo_ov)
        _, reck = run_recorded(SHARDED)
        assert export_chrome_trace(rec1) == export_chrome_trace(reck)
        w = 0.001
        assert (json.dumps(windowed_series(rec1, w), sort_keys=True)
                == json.dumps(windowed_series(reck, w), sort_keys=True))


# ------------------------------------------------------------------- contents
class TestRecordingContents:
    def test_solo_counts_match_metrics(self):
        m, rec = run_recorded(SOLO)
        shard = rec.shards[0]
        assert shard.n_arrivals == SOLO["workload.events"]
        assert shard.n_requests == m.summary()["completed"]
        assert shard.n_dispatches == m.summary()["dispatches"]
        assert shard.strategy == "space_time"

    def test_cold_dispatches_recorded(self):
        _, rec = run_recorded(SOLO)
        cold = sum(rec.shards[0]._dsp_cold)
        # compile_us > 0 with a fresh compile cache: the first dispatch
        # of each distinct bucket is cold
        assert cold > 0

    def test_fleet_routes_and_prices(self):
        m, rec = run_recorded(FLEET)
        assert rec.n_routes == FLEET["workload.events"]
        assert rec.router_name == "least_cost"
        # least_cost records one price per replica active at route time
        assert rec._rt_n[0] == FLEET["fleet.replicas"]
        assert len(rec._rt_price) == sum(rec._rt_n)
        assert len(rec._rt_price_rid) == sum(rec._rt_n)

    def test_round_robin_routes_have_no_prices(self):
        solo_ov = dict(SHARDED, **{"fleet.workers": 1})
        _, rec = run_recorded(solo_ov)
        assert rec.n_routes == SHARDED["workload.events"]
        assert sum(rec._rt_n) == 0

    def test_scale_events_match_metrics(self):
        # the fixture interval (0.05 s) never fires inside the ~5 ms
        # horizon; tick every 0.5 ms so the autoscaler actually acts
        m, rec = run_recorded(
            FLEET, **{"fleet.autoscale": {"max_replicas": 5,
                                          "interval_s": 0.0005}})
        assert rec.scale_events == m.scale_events
        assert len(rec.scale_events) > 0

    def test_rejections_recorded(self):
        m, rec = run_recorded(
            SOLO, **{"scheduler.max_pending_per_tenant": 2})
        shard = rec.shards[0]
        rejected = shard.n_arrivals - sum(shard._arr_admitted)
        assert rejected == m.summary()["rejected"]
        assert rejected > 0


# ------------------------------------------------------------- chrome export
class TestChromeExport:
    def test_schema(self):
        _, rec = run_recorded(
            FLEET, **{"fleet.autoscale": {"max_replicas": 5,
                                          "interval_s": 0.0005}})
        doc = json.loads(export_chrome_trace(rec))
        assert doc["displayTimeUnit"] == "ms"
        events = doc["traceEvents"]
        assert events, "empty trace"
        phs = set()
        for ev in events:
            assert {"ph", "pid", "tid", "name"} <= set(ev)
            phs.add(ev["ph"])
            if ev["ph"] in ("X", "i"):
                assert ev["ts"] >= 0.0
            if ev["ph"] == "X":
                assert ev["dur"] >= 0.0
        assert {"M", "X", "i"} <= phs
        cats = {ev.get("cat") for ev in events}
        assert {"dispatch", "request", "router", "autoscale"} <= cats

    def test_event_counts(self):
        m, rec = run_recorded(SOLO)
        doc = json.loads(export_chrome_trace(rec))
        by_cat = {}
        for ev in doc["traceEvents"]:
            by_cat[ev.get("cat")] = by_cat.get(ev.get("cat"), 0) + 1
        assert by_cat["request"] == m.summary()["completed"]
        assert by_cat["dispatch"] == m.summary()["dispatches"]

    def test_rejected_instants(self):
        _, rec = run_recorded(
            SOLO, **{"scheduler.max_pending_per_tenant": 2})
        doc = json.loads(export_chrome_trace(rec))
        rejected = [ev for ev in doc["traceEvents"]
                    if ev.get("cat") == "admission"]
        assert rejected and all(ev["ph"] == "i" for ev in rejected)


# ------------------------------------------------------------------ telemetry
class TestTelemetry:
    def test_series_sums_match_totals(self):
        m, rec = run_recorded(FLEET)
        t = windowed_series(rec, 0.001)
        s = m.summary()
        assert sum(t["completed"]) == s["completed"]
        assert sum(t["arrivals"]) == FLEET["workload.events"]
        assert sum(t["rejected"]) == s["rejected"]
        assert t["windows"] == len(t["p95_ms"]) == len(t["backlog"])
        assert all(0.0 <= a <= 1.0 for a in t["slo_attainment"])
        assert all(b >= 0 for b in t["backlog"])
        assert len(t["per_replica"]) == len(rec.shards)
        for series in t["per_tenant"].values():
            assert len(series["completed"]) == t["windows"]

    def test_busy_seconds_conserved(self):
        _, rec = run_recorded(SOLO)
        t = windowed_series(rec, 0.0005)
        total_busy = sum(rec.shards[0]._dsp_dur)
        assert sum(t["busy_s"]) == pytest.approx(total_busy)

    def test_rides_in_run_report(self):
        report = spec_for(FLEET, recorder=True).build().run()
        t = report.metrics["telemetry"]
        assert t["schema"] == "telemetry/v1"
        assert t["windows"] > 0
        sched = report.metrics["scheduler"]
        assert "ripe_nudges" in sched
        assert "per_replica_ripe_nudges" in sched
        assert len(sched["per_replica_ripe_nudges"]) >= 1

    def test_bad_window_rejected(self):
        with pytest.raises(ValueError, match="window_s"):
            windowed_series(FlightRecorder(), 0.0)


# ----------------------------------------------------------------------- spec
class TestObservabilitySpec:
    def test_round_trip(self):
        spec = spec_for(SOLO, recorder=True,
                        **{"observability.window_s": 0.25,
                           "observability.per_request": False})
        again = SystemSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert again == spec
        assert again.observability.enabled
        assert again.observability.window_s == 0.25

    def test_off_by_default_and_absent_key_tolerated(self):
        assert not SystemSpec().observability.enabled
        doc = SystemSpec().to_dict()
        del doc["observability"]
        assert SystemSpec.from_dict(doc).observability == ObservabilitySpec()

    def test_bad_window_rejected(self):
        with pytest.raises(ValueError, match="window_s"):
            ObservabilitySpec(window_s=0.0)

    def test_trace_path_written_by_run(self, tmp_path):
        path = tmp_path / "t.json"
        spec = spec_for(SOLO, recorder=True,
                        **{"observability.trace_path": str(path)})
        spec.build().run()
        doc = json.loads(path.read_text())
        assert doc["traceEvents"]


# ------------------------------------------------- incremental median rewrite
class TestIncrementalMedian:
    def test_matches_statistics_median_and_brute_stragglers(self):
        import random

        rng = random.Random(42)
        mon = LatencyMonitor(ewma_alpha=0.3, eviction_ratio=1.5)

        class Item:
            def __init__(self, tid, arr, slo):
                self.tenant_id, self.arrival_time, self.slo_s = tid, arr, slo
                self.kind = "default"

        for step in range(400):
            if step % 3 == 0:
                mon.record(rng.randrange(12), rng.uniform(0.001, 0.05),
                           0.02)
            else:
                batch = [Item(rng.randrange(12), 0.0,
                              rng.uniform(0.005, 0.03))
                         for _ in range(rng.randrange(1, 6))]
                mon.record_batch(batch, rng.uniform(0.001, 0.05))
            ewmas = sorted(t.ewma_s for t in mon.tenants.values()
                           if t.ewma_s is not None)
            assert mon._ewma_sorted == pytest.approx(ewmas)
            assert mon.cohort_median_ewma() == pytest.approx(
                statistics.median(ewmas))
            cut = mon.eviction_ratio * statistics.median(ewmas)
            brute = [tid for tid, t in mon.tenants.items()
                     if t.ewma_s is not None and t.ewma_s > cut]
            assert sorted(mon.stragglers()) == sorted(brute)

    def test_empty_monitor(self):
        mon = LatencyMonitor()
        assert mon.cohort_median_ewma() is None
        assert mon.stragglers() == []


# ------------------------------------------------------------------------ cli
class TestCli:
    def test_trace_check_and_telemetry(self, tmp_path, capsys):
        out = tmp_path / "trace.json"
        tel = tmp_path / "telemetry.json"
        rc = cli_main([
            "trace", "--events", "1200", "--seed", "5",
            "--set", "cost_model.compile_us=50",
            "--out", str(out), "--telemetry", str(tel), "--check"])
        assert rc == 0
        assert json.loads(out.read_text())["traceEvents"]
        series = json.loads(tel.read_text())
        assert series["schema"] == "telemetry/v1"
        assert "byte-identical: True" in capsys.readouterr().out

    def test_trace_rejects_live_mode(self):
        with pytest.raises(SystemExit):
            cli_main(["trace", "--set", "mode=live"])

    def test_report_timeline(self, tmp_path, capsys):
        rep = tmp_path / "report.json"
        rc = cli_main([
            "simulate", "--events", "1200", "--seed", "5",
            "--set", "observability.enabled=true", "--out", str(rep)])
        assert rc == 0
        capsys.readouterr()
        rc = cli_main(["report", str(rep), "--timeline"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "scheduler counters:" in out
        assert "timeline:" in out

    def test_report_timeline_without_telemetry_actionable(
            self, tmp_path, capsys):
        rep = tmp_path / "plain.json"
        assert cli_main(["simulate", "--events", "1200",
                         "--out", str(rep)]) == 0
        with pytest.raises(SystemExit, match="observability.enabled"):
            cli_main(["report", str(rep), "--timeline"])
