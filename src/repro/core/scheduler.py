"""DynamicSpaceTimeScheduler — the unified space-time execution core.

Queries arrive stochastically, so super-kernels cannot be precomputed
ahead-of-time. The scheduler operates on the generic ``Workload``
protocol (see ``core.workload``) — kernel-level GEMMs and request-level
prefill/decode cohorts flow through the SAME policy core:

  1. ``submit`` stamps arrivals with the injected ``Clock`` and applies
     admission control (per-tenant pending caps);
  2. a pluggable ``BatchingPolicy`` decides when each shape bucket is
     ripe — the fixed window of the paper, or an SLO-adaptive window
     that shrinks as a tenant's slack to its deadline shrinks;
  3. ``pump`` dispatches each ripe bucket as ONE super-dispatch: items
     carrying an ``execute`` callback run it over the merged batch;
     bare GEMM problems route through the compile cache
     (``SuperKernelCache``), bounded by ``max_superkernel_size``;
  4. per-tenant latency is recorded against the same clock, stragglers
     are detected and evicted (``LatencyMonitor`` + caller hook).

The pump is synchronous and host-driven — the paper's scheduler is also a
software scheduler above the accelerator. All policy decisions read time
only through ``self.clock`` (no hidden ``time.perf_counter()``), so a
``VirtualClock`` plus a ``cost_model`` turns the pump into a fully
deterministic simulator: the property-based tests and the Fig-4
fixed-vs-adaptive comparison both rely on that.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Hashable, List, Optional, Sequence

from repro.config import ScheduleConfig
from repro.core.clock import Clock, WallClock
from repro.core.policy import BatchingPolicy, make_policy
from repro.core.queue import WorkQueue
from repro.core.slo import LatencyMonitor
from repro.core.superkernel import SuperKernelCache


@dataclasses.dataclass
class SchedulerStats:
    dispatches: int = 0
    problems_completed: int = 0
    total_cost: float = 0.0
    busy_time_s: float = 0.0
    rejected: int = 0
    # times a simulated pump found nothing ripe at a computed ripeness
    # instant and had to re-pump one epsilon later (float rounding left
    # the window a ULP short of elapsed) — drift that used to be silent
    ripe_nudges: int = 0
    # feasibility admission: rejects because the priced completion missed
    # the deadline beyond the oversubscription allowance (subset of
    # ``rejected``), and admits that landed past the deadline but inside it
    deadline_rejected: int = 0
    oversubscribed: int = 0
    # unripe buckets force-dispatched ahead of their window because
    # waiting would have missed their deadline
    preemptions: int = 0

    @property
    def total_flops(self) -> float:
        """Alias: for GEMM workloads ``cost`` is exactly FLOPs."""
        return self.total_cost

    @property
    def achieved_tflops(self) -> float:
        if self.busy_time_s == 0.0:
            return 0.0
        return self.total_cost / self.busy_time_s / 1e12


class DynamicSpaceTimeScheduler:
    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        on_evict: Optional[Callable[[int], None]] = None,
        clock: Optional[Clock] = None,
        policy: Optional[BatchingPolicy] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        on_dispatch: Optional[Callable[[List, float, Optional[int]], None]] = None,
        replica_id: Optional[int] = None,
    ):
        self.schedule = schedule or ScheduleConfig()
        self.clock = clock or WallClock()
        self.policy = policy or make_policy(self.schedule)
        # Maps a dispatched batch to modeled seconds; a VirtualClock then
        # advances by it, making completion times deterministic.
        self.cost_model = cost_model
        # Called with (batch, elapsed_s, replica_id) after every
        # super-dispatch — the calibration tap a CalibratedCostModel
        # (repro.sim.costmodel) learns per-(bucket, pow2-R) dispatch costs
        # through. ``replica_id`` identifies which fleet replica dispatched
        # (None for a solo scheduler), so fleet-wide calibration can keep
        # per-replica tables apart.
        self.on_dispatch = on_dispatch
        self.replica_id = replica_id
        self.queue = WorkQueue()
        self.cache = SuperKernelCache(self.schedule)
        self.monitor = LatencyMonitor(
            self.schedule.latency_ewma_alpha,
            self.schedule.straggler_eviction_ratio,
        )
        self.stats = SchedulerStats()
        self.on_evict = on_evict
        self.evicted: List[int] = []
        # without an admission cap the per-tenant counters are never read;
        # skipping them saves a defaultdict update per submitted workload
        self.queue._track_tenants = self.schedule.max_pending_per_tenant is not None
        # feasibility admission: earliest instant all admitted-but-
        # unfinished work can complete, advanced O(1) per admit and
        # naturally overtaken by the clock as dispatches drain it.
        self._feasibility = self.schedule.admission_policy == "feasibility"
        if self._feasibility and self.cost_model is None:
            raise ValueError(
                "admission_policy='feasibility' needs a cost_model to price "
                "candidate completions"
            )
        self._committed_s = 0.0
        self._edf_mode = bool(getattr(self.policy, "deadline_aware", False))
        # per-tenant preemption debt: seconds of ahead-of-window dispatch
        # each tenant has charged against preemption_budget_s
        self._preempt_debt: Dict[int, float] = {}
        # why the last submit admitted/rejected (recorder reason codes:
        # 0 admit, 1 oversubscribed admit, 2 cap reject, 3 infeasible
        # reject); a flight-recorder shard, when attached, reads this.
        self.admit_reason = 0
        self.recorder = None

    # ---------------------------------------------------------------- intake
    def submit(self, item, now: Optional[float] = None) -> bool:
        """Admit one workload; returns False if admission control rejects.

        ``item`` is anything satisfying the Workload protocol (a
        ``Workload``, a ``GemmProblem``, ...).
        """
        cap = self.schedule.max_pending_per_tenant
        if cap is not None and self.queue.pending_for_tenant(item.tenant_id) >= cap:
            self.stats.rejected += 1
            self.admit_reason = 2
            return False
        t = now if now is not None else self.clock.now()
        if self._feasibility:
            est = self._estimate_item_s(item)
            start = self._committed_s
            clk = self.clock.now()
            if clk > start:
                start = clk
            if t > start:
                start = t
            predicted = start + est
            deadline = t + item.slo_s
            if predicted > deadline + (self.schedule.oversubscription - 1.0) * item.slo_s:
                self.stats.rejected += 1
                self.stats.deadline_rejected += 1
                self.admit_reason = 3
                return False
            self._committed_s = predicted
            if predicted > deadline:
                self.stats.oversubscribed += 1
                self.admit_reason = 1
            else:
                self.admit_reason = 0
        else:
            self.admit_reason = 0
        item.arrival_time = t
        self.queue.push(item)
        return True

    def _estimate_item_s(self, item) -> float:
        """Price one item's marginal service time WITHOUT side effects.

        Prefers the cost model's ``item_s`` marginal (roofline/calibrated),
        then a non-mutating ``estimate``; falls back to calling the model on
        a singleton batch. Never used on models whose ``__call__`` mutates
        (ColdStartCostModel exposes both safe entry points).
        """
        cm = self.cost_model
        fn = getattr(cm, "item_s", None)
        if fn is not None:
            return fn(item)
        fn = getattr(cm, "estimate", None)
        if fn is not None:
            return fn((item,))
        return cm((item,))

    # ---------------------------------------------------------------- dispatch
    def _ripe(self, bucket: Hashable, count: int, now: float) -> bool:
        if count >= self.schedule.max_superkernel_size:
            return True
        oldest = self.queue.oldest_arrival(bucket)
        if oldest is None:
            return False
        # only slack-aware policies need the full pending list (O(n));
        # the fixed window stays O(1) per bucket per tick.
        pending = self.queue.peek(bucket) if self.policy.needs_pending else ()
        return (now - oldest) >= self.policy.window_s(pending, now)

    def pump(self, now: Optional[float] = None, force: bool = False) -> List:
        """Dispatch every ripe bucket; returns completed workloads.

        With ``allow_ragged_merge`` (beyond-paper, MAGMA-vbatched
        analogue), ripe buckets sharing a non-None ``merge_family`` are
        merged into ONE grouped super-kernel instead of one uniform
        super-kernel per exact shape.
        """
        now = now if now is not None else self.clock.now()
        if self._edf_mode and not force:
            return self._pump_edf(now)
        completed: List = []

        if self.schedule.allow_ragged_merge:
            families: Dict[Hashable, List] = {}
            for bucket, count in self.queue.buckets():
                if not force and not self._ripe(bucket, count, now):
                    continue
                fam = getattr(self.queue.head(bucket), "merge_family", None)
                # items without a family only merge within their own bucket
                key = fam if fam is not None else ("__solo__", bucket)
                families.setdefault(key, []).append(bucket)
            for fam_buckets in families.values():
                while True:  # families over the size cap drain fully too
                    batch: List = []
                    for b in fam_buckets:
                        batch.extend(
                            self.queue.pop_batch(
                                b, self.schedule.max_superkernel_size - len(batch)
                            )
                        )
                        if len(batch) >= self.schedule.max_superkernel_size:
                            break
                    if not batch:
                        break
                    ragged = len({p.x.shape[0] for p in batch if hasattr(p, "x")}) > 1
                    completed.extend(self._dispatch(batch, ragged=ragged))
                    if len(batch) < self.schedule.max_superkernel_size:
                        break
            return completed

        for bucket, count in self.queue.buckets():
            if not force and not self._ripe(bucket, count, now):
                continue
            while True:
                batch = self.queue.pop_batch(bucket, self.schedule.max_superkernel_size)
                if not batch:
                    break
                completed.extend(self._dispatch(batch))
                if len(batch) < self.schedule.max_superkernel_size:
                    break
        return completed

    def _pump_edf(self, now: float) -> List:
        """Drain ripe buckets earliest-deadline-first; with preemption on,
        force-dispatch an unripe bucket whose deadline cannot survive its
        remaining window, merged into the same deadline order.

        Preemption is bounded interference: each force-dispatch charges its
        priced service time against the tenant's ``preemption_budget_s``
        debt, so one tight-deadline tenant cannot starve ripe cohorts
        indefinitely. Every preemption is emitted through the flight
        recorder (when attached) with the number of ripe victim cohorts it
        jumped ahead of.
        """
        policy = self.policy
        cap = self.schedule.max_superkernel_size
        preempt = self.schedule.preemption
        budget = self.schedule.preemption_budget_s
        # (deadline, phase, scan_order, bucket, est_s, tenant) — phase 0 is
        # a ripe bucket, phase 1 a preempting (unripe, at-risk) one; the
        # sort keys on the deadline first, scan order breaks ties so equal
        # deadlines stay deterministic across reruns.
        ready = []
        order = 0
        for bucket, count in self.queue.buckets():
            pending = self.queue.peek(bucket)
            if not pending:
                continue
            order += 1
            dl = min(it.arrival_time + it.slo_s for it in pending)
            # same float expression the simulator's calendar stores, so a
            # pump at a calendar instant finds the bucket ripe exactly
            ripe_at = min(policy.ripe_at(it) for it in pending)
            if count >= cap or now >= ripe_at:
                ready.append((dl, 0, order, bucket, 0.0, -1))
            elif preempt and self.cost_model is not None:
                est = self._estimate_item_s(pending[0])
                tid = pending[0].tenant_id
                # at risk: waiting out the window misses the deadline, but
                # dispatching now still makes it — and the tenant has debt
                # budget left to pay for jumping the queue.
                if (
                    ripe_at + est > dl
                    and now + est <= dl
                    and self._preempt_debt.get(tid, 0.0) + est <= budget
                ):
                    ready.append((dl, 1, order, bucket, est, tid))
        if not ready:
            return []
        ready.sort()
        completed: List = []
        for dl, phase, _order, bucket, est, tid in ready:
            if phase == 1:
                victims = sum(1 for r in ready if r[1] == 0 and (r[0], r[1], r[2]) > (dl, 1, _order))
                self._preempt_debt[tid] = self._preempt_debt.get(tid, 0.0) + est
                self.stats.preemptions += 1
                if self.recorder is not None:
                    self.recorder.record_preempt(now, tid, bucket, est, victims)
            while True:
                batch = self.queue.pop_batch(bucket, cap)
                if not batch:
                    break
                completed.extend(self._dispatch(batch))
                if len(batch) < cap:
                    break
        return completed

    def flush(self) -> List:
        """Force-dispatch everything pending (end-of-step/benchmark drain)."""
        return self.pump(force=True)

    def _execute(self, batch: List, ragged: bool) -> List:
        """One super-dispatch: callback workloads run their own merged
        executor; bare GEMMs route through the compile cache."""
        execute = getattr(batch[0], "execute", None)
        if execute is not None:
            return execute(batch)
        if ragged:
            return self.cache.execute_ragged(batch)
        return self.cache.execute(batch)

    def _dispatch(self, batch: List, ragged: bool = False) -> List:
        t0 = self.clock.now()
        outs = self._execute(batch, ragged)
        if self.cost_model is not None:
            self.clock.advance(self.cost_model(batch))
        t1 = self.clock.now()

        stats = self.stats
        stats.dispatches += 1
        stats.problems_completed += len(batch)
        stats.total_cost += sum([float(getattr(p, "cost", 0.0)) for p in batch])
        stats.busy_time_s += t1 - t0

        if outs is None:
            # executor contract: None means "no per-item results" (the
            # simulator's no-op path) — skip the result zip entirely
            for p in batch:
                p.completion_time = t1
        else:
            for p, out in zip(batch, outs):
                p.result = out
                p.completion_time = t1
        # tap fires after completion stamping so observers can read
        # batch[*].completion_time (== t1) as the dispatch-end instant
        if self.on_dispatch is not None:
            self.on_dispatch(batch, t1 - t0, self.replica_id)
        self.monitor.record_batch(batch, t1)

        self._evict_stragglers()
        return batch

    # ---------------------------------------------------------------- isolation
    def _evict_stragglers(self) -> None:
        for tid in self.monitor.stragglers():
            if tid in self.evicted:
                continue
            self.evicted.append(tid)
            if self.on_evict is not None:
                self.on_evict(tid)

    # ---------------------------------------------------------------- reporting
    def report(self) -> Dict[str, float]:
        rep = {
            "dispatches": float(self.stats.dispatches),
            "problems": float(self.stats.problems_completed),
            "rejected": float(self.stats.rejected),
            "achieved_tflops": self.stats.achieved_tflops,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "evicted_tenants": float(len(self.evicted)),
            "ripe_nudges": float(self.stats.ripe_nudges),
            "deadline_rejected": float(self.stats.deadline_rejected),
            "oversubscribed": float(self.stats.oversubscribed),
            "preemptions": float(self.stats.preemptions),
        }
        rep.update(self.monitor.summary())
        return rep
