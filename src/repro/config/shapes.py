"""Assigned input shapes and their step kinds.

Decode shapes lower ``serve_step`` (one new token against a KV cache of
``seq_len``); train/prefill shapes lower ``train_step``/``prefill_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": InputShape("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def get_shape(name: str) -> InputShape:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; options: {sorted(INPUT_SHAPES)}")
