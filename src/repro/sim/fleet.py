"""Multi-replica fleet simulation: N real schedulers behind a router.

The replica-scaling half of the paper's story (Fig. 5 counts how many
replicas FIT; this answers what a fleet of them DOES under load): each
replica is a full ``ReplicaPump`` — the real ``DynamicSpaceTimeScheduler``
on its own ``VirtualClock`` with its own compile-cache cold-start state —
and a pluggable ``Router`` (``repro.sim.router``) assigns every arrival
to one of them.

The fleet event loop merges per-replica ripeness instants into ONE global
timeline: between trace arrivals it repeatedly finds the replica with the
earliest next ripeness instant and pumps exactly that replica there, so
cross-replica event ordering is exact, not quantized per replica. Routing
decisions therefore observe every replica's true state as of the
arrival's trace time.

Cold starts are what couple routing to scheduling: each replica wraps the
shared base cost model in its own ``ColdStartCostModel``, so the first
dispatch of a (bucket, pow2-R) variant on a given replica pays a compile
term — spreading a tenant across the fleet multiplies compiles, pinning
it concentrates load. That is the JSQ-vs-affinity trade the routers and
``benchmarks/fleet_sweep.py`` measure.

Determinism: routers are pure functions of replica state, replica state
is driven by seeded traces and virtual clocks — one seed, byte-identical
fleet metrics JSON, same contract as the solo simulator.
"""

from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.config import ScheduleConfig
from repro.core.clock import VirtualClock
from repro.sim.costmodel import ColdStartCostModel, RooflineCostModel
from repro.sim.metrics import FleetMetrics, MetricsAccumulator
from repro.sim.router import Router, make_router
from repro.sim.simulator import ReplicaPump, SimWorkload
from repro.sim.traces import Arrival, Trace


class FleetSimulator:
    """N replicas of the real scheduler behind a router, one timeline.

    ``cost_model`` is the SHARED stateless base (roofline or calibrated);
    when ``compile_s > 0`` each replica wraps it in its own
    ``ColdStartCostModel`` — per-replica warm caches. ``compile_s=0``
    turns cold-start modeling off (replicas still price work through the
    base model).
    """

    def __init__(
        self,
        replicas: int,
        router: Union[Router, str] = "jsq",
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        compile_s: float = 1e-3,
        start_s: float = 0.0,
    ):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.router = make_router(router) if isinstance(router, str) else router
        self.start_s = float(start_s)
        base = cost_model or RooflineCostModel()
        self.pumps: List[ReplicaPump] = []
        for i in range(replicas):
            clock = VirtualClock(start_s)
            model: Callable[[Sequence], float] = base
            if compile_s > 0.0:
                model = ColdStartCostModel(base, compile_s=compile_s,
                                           clock=clock)
            pump = ReplicaPump(schedule=schedule, cost_model=model,
                               clock=clock, replica_id=i)
            pump.track_inflight = True  # routers read occupancy in fleet time
            self.pumps.append(pump)
        self.routed_counts = [0] * replicas

    # ------------------------------------------------------------ event loop
    def _drain_until(self, t_limit: float) -> None:
        """Merged global timeline: pump whichever replica ripens earliest,
        repeatedly, until no replica ripens before ``t_limit``.

        A replica whose ripeness estimate fails to dispatch (slack-aware
        window shrank underneath it) is stalled until the next arrival —
        the same per-replica semantics as the solo drain loop, without
        letting one stalled replica block the others.
        """
        pumps = self.pumps
        stalled = 0  # bitmask — replica counts are small
        while True:
            best_i, best_t = -1, t_limit
            for i, p in enumerate(pumps):
                if stalled & (1 << i):
                    continue
                t = p.next_ripe_time()
                if t is not None and t < best_t:
                    best_i, best_t = i, t
            if best_i < 0:
                return
            if not pumps[best_i].pump_at(best_t):
                stalled |= 1 << best_i

    def run(self, trace: Union[Trace, Iterable[Arrival]]) -> FleetMetrics:
        pumps, router = self.pumps, self.router
        fleet_acc = MetricsAccumulator()
        replica_accs = [MetricsAccumulator() for _ in pumps]
        for p, acc in zip(pumps, replica_accs):
            p.accs = [fleet_acc, acc]
        t_start = self.start_s

        for t_s, spec, cost in trace:
            self._drain_until(t_s)
            idx = router.route(spec, pumps, t_s)
            w = SimWorkload(spec, cost)
            w.est_s = pumps[idx].estimate_item_s(w)
            if pumps[idx].submit(w, t_s):
                self.routed_counts[idx] += 1

        # tail: keep merging ripeness instants until every queue is dry,
        # then force-flush whatever the estimates could not ripen
        while any(len(p.scheduler.queue) for p in pumps):
            before = sum(len(p.scheduler.queue) for p in pumps)
            self._drain_until(float("inf"))
            if sum(len(p.scheduler.queue) for p in pumps) == before:
                for p in pumps:
                    if len(p.scheduler.queue):
                        p._absorb(p.scheduler.flush())
                break

        # fleet horizon: the makespan across replicas; every replica's
        # utilization is reported against it so the spread is meaningful
        horizon = max(p.clock.now() for p in pumps) - t_start
        merged = self._freeze_merged(fleet_acc, horizon)
        per_replica = [p.freeze(acc, sim_duration_s=horizon)
                       for p, acc in zip(pumps, replica_accs)]
        cold_times, cold_flags = self._cold_series()
        return FleetMetrics(
            merged=merged,
            per_replica=per_replica,
            routed_counts=list(self.routed_counts),
            router=self.router.name,
            cold_times=cold_times,
            cold_flags=cold_flags,
        )

    # ------------------------------------------------------------- internals
    def _freeze_merged(self, acc: MetricsAccumulator,
                       horizon: float):
        stats = [p.scheduler.stats for p in self.pumps]
        return acc.freeze(
            sim_duration_s=horizon,
            busy_time_s=sum(s.busy_time_s for s in stats),
            dispatches=sum(s.dispatches for s in stats),
            rejected=sum(s.rejected for s in stats),
            evicted_tenants=sum(len(p.scheduler.evicted) for p in self.pumps),
        )

    def _cold_series(self):
        """Concatenated (time, was_cold) dispatch series across replicas,
        sorted by time (stable, so equal instants keep replica order —
        deterministic)."""
        times: List[np.ndarray] = []
        flags: List[np.ndarray] = []
        for p in self.pumps:
            m = p.cost_model
            if isinstance(m, ColdStartCostModel):
                times.append(np.asarray(m.dispatch_times, np.float64))
                flags.append(np.asarray(m.dispatch_cold, np.int64))
        if not times:
            return np.zeros(0, np.float64), np.zeros(0, np.int64)
        t = np.concatenate(times)
        f = np.concatenate(flags)
        order = np.argsort(t, kind="stable")
        return t[order], f[order]


def simulate_fleet(
    trace: Union[Trace, Iterable[Arrival]],
    replicas: int,
    router: Union[Router, str] = "jsq",
    schedule: Optional[ScheduleConfig] = None,
    cost_model: Optional[Callable[[Sequence], float]] = None,
    compile_s: float = 1e-3,
) -> FleetMetrics:
    """One-shot convenience wrapper: fresh fleet, one trace, metrics."""
    return FleetSimulator(
        replicas, router=router, schedule=schedule, cost_model=cost_model,
        compile_s=compile_s,
    ).run(trace)
