"""Multi-tenant inference serving runtime.

The model-level embodiment of space-time scheduling: R tenants of one
architecture run as ONE vmapped program over stacked weights/caches
(every layer's GEMMs become inter-model batched super-kernels), with a
slot-based continuous batcher feeding the decode loop. Prefill and
decode cohorts are submitted as generic ``Workload`` items through the
shared ``DynamicSpaceTimeScheduler`` core, which owns admission control,
per-tenant SLO/latency tracking, and straggler eviction.

``repro.serving.fleet`` puts N engines behind the sim routers (the live
half of the fleet story). The engine (and therefore jax) is imported
LAZILY: building live specs, running the deterministic fake-engine fleet,
and the sim↔live parity suite all stay jax-free — only touching
``MultiTenantEngine`` / ``EngineConfig`` pays the import.
"""

from repro.serving.request import InferenceRequest, RequestState  # noqa: F401

_ENGINE_EXPORTS = ("EngineConfig", "MultiTenantEngine")


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serving import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_ENGINE_EXPORTS))
