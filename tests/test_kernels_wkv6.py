"""wkv6_scan Pallas kernel vs the sequential-scan oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.wkv6_scan import wkv6_scan


@pytest.mark.parametrize("case", [(2, 64, 16, 16), (4, 70, 16, 32), (1, 33, 8, 8)], ids=str)
@pytest.mark.parametrize("chunk", [16, 32])
def test_vs_oracle(case, chunk, rng_key):
    BH, T, N, V = case
    ks = jax.random.split(rng_key, 5)
    r = jax.random.normal(ks[0], (BH, T, N)) * 0.5
    k = jax.random.normal(ks[1], (BH, T, N)) * 0.5
    v = jax.random.normal(ks[2], (BH, T, V)) * 0.5
    w = jax.random.normal(ks[3], (BH, T, N)) * 0.3
    u = jax.random.normal(ks[4], (BH, N)) * 0.3
    got = wkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    want = ref.wkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_step_consistency(rng_key):
    """Running wkv6_step T times == the full scan."""
    BH, T, N = 2, 12, 8
    ks = jax.random.split(rng_key, 5)
    r = jax.random.normal(ks[0], (BH, T, N)) * 0.5
    k = jax.random.normal(ks[1], (BH, T, N)) * 0.5
    v = jax.random.normal(ks[2], (BH, T, N)) * 0.5
    w = jax.random.normal(ks[3], (BH, T, N)) * 0.3
    u = jax.random.normal(ks[4], (BH, N)) * 0.3
    want = ref.wkv6_scan(r, k, v, w, u)
    state = jnp.zeros((BH, N, N), jnp.float32)
    outs = []
    for t in range(T):
        state, o = ref.wkv6_step(state, r[:, t], k[:, t], v[:, t], w[:, t], u)
        outs.append(o)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_decay_monotonicity(rng_key):
    """With large decay logits (fast forgetting) early tokens must have
    vanishing influence on late outputs."""
    BH, T, N = 1, 32, 4
    ks = jax.random.split(rng_key, 5)
    r = jax.random.normal(ks[0], (BH, T, N))
    k = jax.random.normal(ks[1], (BH, T, N))
    v = jax.random.normal(ks[2], (BH, T, N))
    u = jnp.zeros((BH, N))
    w_fast = jnp.full((BH, T, N), 2.0)   # decay = exp(-exp(2)) ~ 6e-4
    base = ref.wkv6_scan(r, k, v, w_fast, u)
    v2 = v.at[:, 0].add(100.0)  # perturb the FIRST token only
    pert = ref.wkv6_scan(r, k, v2, w_fast, u)
    # by t = T-1 the perturbation must be invisible
    np.testing.assert_allclose(base[:, -1], pert[:, -1], atol=1e-3)
