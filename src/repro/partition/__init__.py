"""Fractional spatial shares as a first-class schedulable resource.

The paper's headline wins come from treating the chip's spatial fraction
as something the scheduler allocates; this package makes that fraction a
planned quantity instead of the all-or-nothing strategies the cost
models pick per batch:

    shares.py   ``PartitionPlan`` — named per-partition slices of one
                chip (``HardwareSpec.sliced``), tenants mapped to
                slices, shares summing to <= 1.0
    knee.py     throughput-vs-share curves per (bucket, R) priced from
                the roofline or a calibrated table, and the D-STACK-style
                knee share beyond which extra chip% buys ~nothing
    planner.py  the deterministic planner that co-optimizes partition
                sizes with batch windows, stopping a partition's shrink
                where its deadline stops being feasible

Execution lives in ``repro.sim.fleet`` (co-located partition pumps on
one chip, one merged timeline); the declarative surface is
``repro.api.spec.PartitionSpec``.
"""

from repro.partition.knee import (  # noqa: F401
    DEFAULT_SHARE_GRID,
    knee_share,
    share_pricer,
    throughput_curve,
)
from repro.partition.planner import PlannerConfig, plan_partitions  # noqa: F401
from repro.partition.shares import PartitionPlan, PartitionShare  # noqa: F401
