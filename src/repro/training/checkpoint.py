"""Msgpack checkpointing for param/optimizer pytrees (no external deps
beyond msgpack + numpy, both installed)."""

from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Pytree = Any


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree: Pytree, step: Optional[int] = None) -> None:
    leaves, _ = _flatten(tree)
    payload = {
        "step": step if step is not None else -1,
        "leaves": [
            {
                "dtype": str(np.asarray(leaf).dtype),
                "shape": list(np.asarray(leaf).shape),
                "data": np.ascontiguousarray(
                    np.asarray(leaf, dtype=_storage_dtype(leaf))
                ).tobytes(),
            }
            for leaf in leaves
        ],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def _storage_dtype(leaf) -> np.dtype:
    dt = np.asarray(leaf).dtype
    if dt == jnp.bfloat16:
        return np.dtype(np.float32)  # numpy has no bf16; widen for storage
    return dt


def restore_checkpoint(path: str, like: Pytree) -> Dict[str, Any]:
    """Restore into the structure (and dtypes) of ``like``."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    like_leaves, treedef = _flatten(like)
    if len(payload["leaves"]) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(payload['leaves'])} leaves, expected {len(like_leaves)}"
        )
    leaves = []
    for rec, ref in zip(payload["leaves"], like_leaves):
        arr = np.frombuffer(rec["data"], dtype=_storage_dtype(ref)).reshape(rec["shape"])
        leaves.append(jnp.asarray(arr, dtype=np.asarray(ref).dtype))
    return {"tree": jax.tree.unflatten(treedef, leaves), "step": payload["step"]}
