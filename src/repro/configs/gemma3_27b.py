"""gemma3-27b [hf:google/gemma-3-1b-pt family, 27B geometry].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144. 5:1
local(sliding-window-1024):global attention, 128k context. head_dim=128 per
model card. The sliding-window majority makes this dense arch eligible for
the long_500k decode shape.
"""

from repro.config import AttentionKind, ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="gemma3-27b",
        source="hf:google/gemma-3-1b-pt",
        family="dense",
        num_layers=62,
        d_model=5376,
        vocab_size=262144,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        attention_kind=AttentionKind.SLIDING,
        sliding_window=1024,
        global_every=6,  # 5 local : 1 global
        logit_softcap=30.0,
        tie_embeddings=True,
        scale_embed=True,
        rope_theta=1_000_000.0,
    )
)
