"""Deterministic partition planner: knee shares co-optimized with batch
windows under deadline-feasibility.

The planner carves one chip into per-bucket slices (workloads that share
a bucket can merge into super-kernels; workloads that don't would only
serialize each other inside one slice) and sizes each slice by two
forces:

* the **knee** (``repro.partition.knee``): growing a slice past its
  (bucket, R) throughput knee buys ~nothing, so the knee is where the
  planner would LIKE to stop — chip% above it is better spent on other
  tenants;
* **deadline feasibility** (PR 8's admission pricing, applied at plan
  time): a slice must finish its representative merged dispatch within
  the group's tightest SLO, or feasibility admission will reject the
  work at run time. "Shrink the partition until the deadline stops
  being feasible" is the stopping rule — the planner walks the share
  grid downward and keeps the smallest share that is both at-or-above
  the knee and still meets the deadline.

The batch window rides along: a faster slice leaves more slack to its
SLO, so the planner grants it a wider batching window (bigger merges,
better amortization), never wider than ``slack_fraction`` of the
remaining slack or the configured base window. If the per-group choices
oversubscribe the chip (shares summing past 1.0), chip% is handed back
proportionally to what each group holds ABOVE its deadline floor —
feasibility survives the squeeze whenever the floors themselves fit —
and the windows re-derive at the squeezed shares.

Everything is a pure function of (mix, hardware, config, calibration
table): byte-identical plans per seed, the property the tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.roofline import HardwareSpec
from repro.obs.recorder import bucket_label
from repro.partition.knee import (
    DEFAULT_SHARE_GRID,
    knee_share,
    share_pricer,
    throughput_curve,
)
from repro.partition.shares import SHARE_SUM_TOL, PartitionPlan, PartitionShare


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Planner knobs, mirrored by ``repro.api.spec.PartitionSpec``."""

    share_grid: Tuple[float, ...] = DEFAULT_SHARE_GRID
    knee_fraction: float = 0.9
    min_share: float = 0.0625
    base_window_s: float = 0.002     # widest batching window granted
    slack_fraction: float = 0.5      # of deadline slack a window may eat
    merge_size: int = 32             # representative merged-batch budget
    strategy: str = "space_time"
    small_kernel_efficiency: float = 0.45

    def __post_init__(self) -> None:
        grid = tuple(float(s) for s in self.share_grid)
        if not grid:
            raise ValueError("share_grid must be non-empty")
        if any(not (0.0 < s <= 1.0) for s in grid):
            raise ValueError(
                f"share_grid entries must be in (0, 1], got {grid}")
        if list(grid) != sorted(set(grid)):
            raise ValueError(
                f"share_grid must be strictly ascending, got {grid}")
        object.__setattr__(self, "share_grid", grid)
        if not (0.0 < self.knee_fraction <= 1.0):
            raise ValueError(
                f"knee_fraction must be in (0, 1], got {self.knee_fraction}")
        if not (0.0 < self.min_share <= 1.0):
            raise ValueError(
                f"min_share must be in (0, 1], got {self.min_share}")
        if self.base_window_s < 0.0:
            raise ValueError(
                f"base_window_s must be >= 0, got {self.base_window_s}")
        if not (0.0 <= self.slack_fraction <= 1.0):
            raise ValueError(
                f"slack_fraction must be in [0, 1], got {self.slack_fraction}")
        if self.merge_size < 1:
            raise ValueError(
                f"merge_size must be >= 1, got {self.merge_size}")


def group_tenants(mix: Sequence) -> List[Tuple[str, List]]:
    """``(group_name, member_specs)`` per distinct bucket, in mix order.

    Group names prefer the shape suffix of the first member's tenant
    name (``t0/resnet18_conv2_2`` -> ``resnet18_conv2_2``) and fall back
    to the interned bucket label; collisions dedupe with ``#k`` so plan
    JSON and Perfetto tracks stay unambiguous."""
    by_bucket: Dict = {}
    for spec in mix:
        by_bucket.setdefault(spec.bucket, []).append(spec)
    seen: Dict[str, int] = {}
    out: List[Tuple[str, List]] = []
    for bucket, members in by_bucket.items():
        name = members[0].name
        name = name.split("/", 1)[1] if "/" in name else bucket_label(bucket)
        n = seen.get(name, 0)
        seen[name] = n + 1
        out.append((f"{name}#{n + 1}" if n else name, members))
    return out


def representative_r(members: Sequence, total_weight: float,
                     merge_size: int) -> int:
    """The merged batch size this group would see in one dispatch round:
    its weight share of ``merge_size`` arrivals (the same split
    ``estimate_capacity_hz`` prices capacity with), floored at 1."""
    w = sum(s.weight for s in members)
    return max(1, round(merge_size * w / total_weight)) if total_weight \
        else 1


def plan_partitions(
    mix: Sequence,
    hardware: HardwareSpec,
    config: Optional[PlannerConfig] = None,
    calibrated=None,
    r_override: Optional[Dict[str, int]] = None,
) -> PartitionPlan:
    """Carve ``hardware`` into per-bucket slices for ``mix``.

    ``calibrated`` (a ``CalibratedCostModel``) prices the knee curves
    from measured tables instead of the roofline prior; ``r_override``
    maps group names to observed merged batch sizes — the re-planning
    hook the fleet uses mid-run (observed R replaces the weight-derived
    representative R, everything else re-derives deterministically).
    """
    cfg = config or PlannerConfig()
    groups = group_tenants(mix)
    if not groups:
        raise ValueError("plan_partitions needs a non-empty tenant mix")
    total_weight = sum(s.weight for s in mix)
    price = share_pricer(
        hardware, strategy=cfg.strategy,
        small_kernel_efficiency=cfg.small_kernel_efficiency,
        calibrated=calibrated)
    grid = cfg.share_grid

    chosen: List[Tuple[str, List, float, float, float, int]] = []
    for name, members in groups:
        r = (r_override or {}).get(
            name, representative_r(members, total_weight, cfg.merge_size))
        r = max(1, int(r))
        curve = throughput_curve(members[0], r, price, grid)
        knee = knee_share(curve, knee_fraction=cfg.knee_fraction,
                          min_share=cfg.min_share)
        min_slo = min(s.slo_s for s in members)
        batch = [members[0]] * r
        # "shrink the partition until the deadline stops being feasible":
        # walk the grid downward from the whole chip, keeping the
        # smallest share whose representative dispatch still fits the
        # tightest member SLO — est(share) grows as the share shrinks,
        # so feasibility is monotone and the first infeasible step ends
        # the walk. If even the whole chip misses the deadline the group
        # keeps the largest share (run-time admission will price the
        # overload honestly).
        eligible = [s for s in grid if s >= cfg.min_share] or [grid[-1]]
        floor = eligible[-1]
        for s in reversed(eligible):
            if price(batch, s) <= min_slo:
                floor = s
            else:
                break
        # the knee caps USEFUL growth: chip% past it buys < (1 -
        # knee_fraction) throughput, so the ask is the deadline floor
        # raised to the knee — never less than feasibility demands,
        # never more than the curve rewards
        share = max(floor, knee)
        chosen.append((name, members, share, floor, min_slo, r))

    total = sum(share for _, _, share, _, _, _ in chosen)
    if total > 1.0 + SHARE_SUM_TOL:
        # oversubscribed chip: give back chip% proportionally to what
        # each group holds ABOVE its deadline floor, so feasibility
        # survives the squeeze whenever the floors themselves fit; when
        # even the floors oversubscribe, scale everything proportionally
        # (the admission layer will reject what truly cannot fit)
        floors = sum(floor for _, _, _, floor, _, _ in chosen)
        if floors <= 1.0 + SHARE_SUM_TOL:
            slack = total - floors
            give_back = total - 1.0
            chosen = [
                (name, members,
                 share - give_back * ((share - floor) / slack),
                 floor, min_slo, r)
                for name, members, share, floor, min_slo, r in chosen]
        else:
            chosen = [
                (name, members, share / total, floor, min_slo, r)
                for name, members, share, floor, min_slo, r in chosen]

    out = []
    for name, members, share, _, min_slo, r in chosen:
        est = price([members[0]] * r, share)
        window = min(cfg.base_window_s,
                     max(0.0, (min_slo - est) * cfg.slack_fraction))
        out.append(PartitionShare(
            name=name, share=share,
            tenants=tuple(s.tenant_id for s in members),
            window_s=window))
    return PartitionPlan(groups=tuple(out))
