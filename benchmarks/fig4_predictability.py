"""Figure 4: inter-tenant latency predictability.

Paper: under MPS space-only sharing, co-located tenants diverge by up to
25% (worse with odd tenant counts) — unpredictability caused by the device
scheduler. Claim for space-time: a merged super-kernel gives every tenant
the SAME step latency by construction; the residual spread comes only from
the queueing layer.

Two measurements:

(a) engine modes — per-tenant mean step latency spread under the engine's
    time_only mode (each tenant's decode cohort dispatched as its own
    bucket through the shared scheduler — spread reflects dispatch order)
    vs space_time mode (one merged dispatch).

(b) batching-window policies — the SAME Poisson kernel-arrival trace
    replayed on a deterministic VirtualClock against the fixed window and
    the SLO-adaptive window. The adaptive policy shrinks a bucket's
    window as any pending item's slack to its deadline shrinks, so tail
    latency (p95) must come out at or below the fixed window's.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScheduleConfig, get_config, smoke_variant
from repro.core import DynamicSpaceTimeScheduler, GemmProblem, VirtualClock
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def policy_trace(
    policy: str,
    tenants: int = 8,
    events: int = 300,
    seed: int = 0,
    slo_s: float = 0.010,
) -> Dict[str, float]:
    """Replay one seeded arrival trace on a virtual clock under ``policy``.

    Execution is real (small GEMMs through the super-kernel cache) but
    time is modeled: the cost model advances the virtual clock by a fixed
    dispatch overhead plus compute at an assumed rate, so latencies are
    fully deterministic and the two policies see the identical trace.
    """
    clock = VirtualClock()
    sched = DynamicSpaceTimeScheduler(
        ScheduleConfig(
            batching_window_s=0.004,
            batching_policy=policy,
            slo_slack_fraction=0.25,
            max_superkernel_size=32,
        ),
        clock=clock,
        cost_model=lambda batch: 50e-6 + sum(p.cost for p in batch) / 2e12,
    )
    key = jax.random.PRNGKey(seed)
    ws = [jax.random.normal(jax.random.fold_in(key, t), (64, 64), jnp.float32)
          for t in range(tenants)]
    x = jax.random.normal(jax.random.fold_in(key, 999), (64, 64), jnp.float32)

    rng = np.random.default_rng(seed)
    tick_s = 0.0005
    for i in range(events):
        clock.advance_to(i * tick_s)
        for _ in range(rng.poisson(1.2)):
            t = int(rng.integers(tenants))
            sched.submit(GemmProblem(tenant_id=t, x=x, w=ws[t], slo_s=slo_s))
        sched.pump()
    sched.flush()

    rep = sched.report()  # monitor percentiles cover the same latency set
    return {
        "p50_ms": rep["p50_s"] * 1e3,
        "p95_ms": rep["p95_s"] * 1e3,
        "mean_ms": rep["mean_s"] * 1e3,
        "dispatches": rep["dispatches"],
        "slo_violations": rep["slo_violations"],
    }


def run(r: int = 5, steps: int = 16, csv_rows=None):
    # odd tenant count on purpose — the paper's worst case for MPS
    print(f"\n=== Fig 4: inter-tenant latency spread (R={r}, odd) ===")
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")), dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    rng = np.random.RandomState(0)
    params = [m.init(jax.random.fold_in(key, t)) for t in range(r)]

    for mode in ("time_only", "space_time"):
        eng = MultiTenantEngine(
            m, params,
            EngineConfig(num_tenants=r, slots_per_tenant=1, cache_len=64, mode=mode),
        )
        # per-tenant latency accounting happens inside the shared
        # scheduler core that both modes route their cohorts through.
        for t in range(r):
            eng.submit(InferenceRequest(
                tenant_id=t, prompt=list(rng.randint(1, cfg.vocab_size, 8)),
                max_new_tokens=steps))
        eng.run_until_drained()
        spread = eng.monitor.predictability_spread()
        rep = eng.report()
        print(f"{mode:11s}: spread={spread:7.2%}  p95/p50="
              f"{rep['p95_s']/max(rep['p50_s'],1e-12):5.2f}")
        if csv_rows is not None:
            csv_rows.append((f"fig4/{mode}/spread", spread * 100, "pct (paper MPS: 25%)"))

    print("\n--- batching-window policy on one virtual-clock trace ---")
    results = {}
    for policy in ("fixed", "slo_adaptive"):
        results[policy] = policy_trace(policy)
        rr = results[policy]
        print(f"{policy:12s}: p50={rr['p50_ms']:7.3f}ms p95={rr['p95_ms']:7.3f}ms "
              f"dispatches={rr['dispatches']:.0f} slo_viol={rr['slo_violations']:.0f}")
        if csv_rows is not None:
            csv_rows.append((f"fig4/policy_{policy}/p95", rr["p95_ms"] * 1e3,
                             "us end-to-end (virtual clock)"))
    ok = results["slo_adaptive"]["p95_ms"] <= results["fixed"]["p95_ms"]
    print(f"adaptive p95 <= fixed p95: {ok}")


if __name__ == "__main__":
    run()
