"""Training substrate: optimizer math, schedule, data determinism,
checkpoint roundtrip, loss-goes-down integration."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.training import SyntheticTokenStream, train
from repro.training import checkpoint as ckpt
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        opt = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(
                grads, opt, params, jnp.asarray(0.05), weight_decay=0.0
            )
        np.testing.assert_allclose(np.asarray(params["w"]), [0.0, 0.0], atol=1e-2)

    def test_grad_clipping(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)
        _, _, m = adamw_update(
            {"w": jnp.full((3,), 1e6)}, opt, params, jnp.asarray(0.1), grad_clip=1.0
        )
        assert float(m["grad_norm"]) > 1e5  # reported raw

    def test_weight_decay_pulls_to_zero(self):
        params = {"w": jnp.asarray([10.0])}
        opt = adamw_init(params)
        for _ in range(100):
            params, opt, _ = adamw_update(
                {"w": jnp.zeros(1)}, opt, params, jnp.asarray(0.1), weight_decay=0.5
            )
        assert abs(float(params["w"][0])) < 1.0

    def test_lr_schedule_shape(self):
        lrs = [float(lr_schedule(jnp.asarray(s), 1e-3, 10, 100)) for s in range(101)]
        assert lrs[0] == 0.0
        assert lrs[10] == pytest.approx(1e-3, rel=1e-3)
        assert lrs[100] == pytest.approx(1e-4, rel=1e-2)  # min_ratio * base
        assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # monotone decay


class TestData:
    def test_deterministic_and_shifted(self):
        ds = SyntheticTokenStream(vocab_size=100, seq_len=16, batch_size=4, seed=1)
        t1, l1 = ds.batch(7)
        t2, l2 = ds.batch(7)
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])  # labels = next token

    def test_has_learnable_structure(self):
        ds = SyntheticTokenStream(vocab_size=50, seq_len=256, batch_size=8, seed=0)
        toks, labels = ds.batch(0)
        match = np.mean(ds._succ[toks] == labels)
        assert match > 0.4  # ~succ_p of transitions follow the grammar


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.asarray([1.5, -2.5], jnp.float32)},
            "s": jnp.asarray(3, jnp.int32),
        }
        path = os.path.join(tmp_path, "ck.msgpack")
        ckpt.save_checkpoint(path, tree, step=42)
        out = ckpt.restore_checkpoint(path, tree)
        assert out["step"] == 42
        for a, b in zip(jax.tree.leaves(out["tree"]), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_structure_mismatch_raises(self, tmp_path):
        path = os.path.join(tmp_path, "ck.msgpack")
        ckpt.save_checkpoint(path, {"a": jnp.zeros(3)}, step=0)
        with pytest.raises(ValueError):
            ckpt.restore_checkpoint(path, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


@pytest.mark.slow
def test_loss_decreases_end_to_end(tmp_path):
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")), dtype="float32")
    m = build_model(cfg)
    data = SyntheticTokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=0)
    logs = []
    state = train(
        m, data, steps=25, log_every=5, base_lr=1e-3, warmup_steps=5,
        checkpoint_path=os.path.join(tmp_path, "ck.msgpack"),
        checkpoint_every=20, log_fn=logs.append,
    )
    first = float(logs[0].split("loss")[1].split()[0])
    last = float(logs[-1].split("loss")[1].split()[0])
    assert last < first - 0.5, (first, last)
    assert os.path.exists(os.path.join(tmp_path, "ck.msgpack"))
