"""Model configuration dataclasses.

A ModelConfig fully determines a decoder-only (or hybrid) transformer stack:
layer pattern, attention geometry, FFN/MoE geometry, SSM geometry, vocab and
modality frontend. Every assigned architecture in ``repro.configs`` is an
instance of this one schema, so the model builder, sharding rules, dry-run
and roofline all dispatch on config fields rather than on per-arch code.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple


class AttentionKind(str, enum.Enum):
    """Attention flavour of an attention block."""

    FULL = "full"            # global causal attention
    SLIDING = "sliding"      # sliding-window causal attention (sub-quadratic)
    NONE = "none"            # attention-free architecture (pure SSM)


class BlockKind(str, enum.Enum):
    """One entry in the per-layer block pattern."""

    ATTN_MLP = "attn_mlp"        # standard transformer block (attention + MLP/FFN)
    ATTN_MOE = "attn_moe"        # attention + mixture-of-experts FFN
    MAMBA2 = "mamba2"            # Mamba2 SSM block
    RWKV6 = "rwkv6"              # RWKV-6 "Finch" time-mix + channel-mix block
    HYBRID_SHARED_ATTN = "hybrid_shared_attn"  # Zamba2 shared attention block


class Modality(str, enum.Enum):
    TEXT = "text"
    VISION_TEXT = "vision_text"  # VLM: precomputed patch embeddings + text
    AUDIO_TOKENS = "audio_tokens"  # decoder over codec tokens (MusicGen)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts geometry."""

    num_experts: int
    experts_per_token: int          # top-k
    expert_d_ff: int                # per-expert hidden width
    num_shared_experts: int = 0     # always-on shared experts (0 for assigned archs)
    router_aux_loss_weight: float = 0.01
    router_jitter: float = 0.0
    capacity_factor: float = 1.25   # per-expert capacity = cf * tokens/experts

    def __post_init__(self) -> None:
        if self.experts_per_token > self.num_experts:
            raise ValueError(
                f"top-k {self.experts_per_token} > num_experts {self.num_experts}"
            )


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """State-space (Mamba2) / linear-recurrence (RWKV6) geometry."""

    state_dim: int = 64            # N: per-head recurrent state size
    num_ssm_heads: int = 0         # 0 -> derived as d_inner // head_dim
    head_dim: int = 64             # P: channels per SSM head
    expand: int = 2                # d_inner = expand * d_model
    conv_width: int = 4            # depthwise causal conv width (Mamba2)
    chunk_size: int = 256          # chunked-scan block length
    dt_rank: int = 0               # unused by Mamba2 (scalar dt per head)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Complete architecture description. One per assigned architecture."""

    name: str
    source: str                     # citation: arXiv id / HF model card
    family: str                     # dense | moe | hybrid | ssm | vlm | audio

    num_layers: int
    d_model: int
    vocab_size: int

    # --- attention geometry ------------------------------------------------
    num_heads: int = 0              # 0 for attention-free archs
    num_kv_heads: int = 0           # GQA KV heads
    head_dim: int = 0               # 0 -> d_model // num_heads
    attention_kind: AttentionKind = AttentionKind.FULL
    sliding_window: int = 0         # window size when attention_kind == SLIDING
    global_every: int = 0           # gemma3: 1 global layer every N (0 = never)
    qkv_bias: bool = False          # qwen2 uses bias on QKV
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0      # gemma-style final-logit soft-capping

    # --- FFN geometry -------------------------------------------------------
    d_ff: int = 0
    mlp_gated: bool = True          # SwiGLU-style gated MLP
    moe: Optional[MoEConfig] = None

    # --- SSM geometry (hybrid / ssm archs) -----------------------------------
    ssm: Optional[SSMConfig] = None

    # --- layer pattern --------------------------------------------------------
    # If None, every layer is the "default" block for the family. Otherwise a
    # tuple of BlockKind with len == num_layers.
    block_pattern: Optional[Tuple[BlockKind, ...]] = None

    # --- modality -------------------------------------------------------------
    modality: Modality = Modality.TEXT
    # VLM / audio stub frontend: number of prefix embedding positions supplied
    # as precomputed frame/patch embeddings by input_specs().
    num_prefix_embeddings: int = 0
    frontend_embed_dim: int = 0     # dim of stubbed frontend output (0 = d_model)

    # --- norm / misc -----------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False       # gemma-family sqrt(d_model) embed scaling
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ helpers
    def __post_init__(self) -> None:
        if self.attention_kind != AttentionKind.NONE:
            if self.num_heads <= 0:
                raise ValueError(f"{self.name}: num_heads required for attention arch")
            if self.num_kv_heads <= 0:
                object.__setattr__(self, "num_kv_heads", self.num_heads)
            if self.num_heads % self.num_kv_heads != 0:
                raise ValueError(
                    f"{self.name}: num_heads {self.num_heads} not divisible by "
                    f"num_kv_heads {self.num_kv_heads}"
                )
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.block_pattern is not None and len(self.block_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: block_pattern len {len(self.block_pattern)} != "
                f"num_layers {self.num_layers}"
            )

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def layer_pattern(self) -> Tuple[BlockKind, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        if self.family in ("dense", "vlm", "audio"):
            default = BlockKind.ATTN_MLP
        elif self.family == "moe":
            default = BlockKind.ATTN_MOE
        elif self.family == "ssm":
            default = BlockKind.RWKV6
        else:
            raise ValueError(f"{self.name}: family {self.family} needs block_pattern")
        return tuple(default for _ in range(self.num_layers))

    @property
    def is_subquadratic(self) -> bool:
        """True when the arch supports O(seq) long-context decode.

        SSM/RWKV archs are O(1)-state; hybrids with a bounded number of full
        attention layers decode one token in O(seq) cache reads (linear);
        sliding-window dense archs bound the cache window.
        """
        pattern = self.layer_pattern
        n_full_attn = sum(
            1
            for i, b in enumerate(pattern)
            if b in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE, BlockKind.HYBRID_SHARED_ATTN)
            and self.attention_kind_at(i) == AttentionKind.FULL
        )
        if self.attention_kind == AttentionKind.NONE:
            return True
        if self.family == "hybrid":
            return True  # Mamba2-majority; sparse attn decode is linear
        if self.attention_kind == AttentionKind.SLIDING:
            return True
        return n_full_attn == 0

    def attention_kind_at(self, layer: int) -> AttentionKind:
        """Per-layer attention kind (gemma3 interleaves local/global)."""
        if self.attention_kind != AttentionKind.SLIDING:
            return self.attention_kind
        if self.global_every and (layer + 1) % self.global_every == 0:
            return AttentionKind.FULL
        return AttentionKind.SLIDING

    # ----------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count of the substrate model (frontend stub excluded)."""
        total = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        total += self.d_model  # final norm
        shared_counted = False
        for i, kind in enumerate(self.layer_pattern):
            if kind == BlockKind.HYBRID_SHARED_ATTN:
                # Zamba2-style shared transformer block: ONE weight set reused
                # at every application point (plus a small per-site LoRA-free
                # linear adapter which we fold into the shared count).
                if shared_counted:
                    continue
                shared_counted = True
            total += self._block_params(kind)
        if self.num_prefix_embeddings:
            fed = self.frontend_embed_dim or self.d_model
            total += fed * self.d_model  # modality projector
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        total += self.d_model
        for kind in self.layer_pattern:
            total += self._block_params(kind, active_only=True)
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        p = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            p += nq * hd + 2 * nkv * hd
        return p + 2 * d  # two rmsnorm scales per block

    def _mlp_params(self) -> int:
        mult = 3 if self.mlp_gated else 2
        return mult * self.d_model * self.d_ff

    def _block_params(self, kind: BlockKind, active_only: bool = False) -> int:
        d = self.d_model
        if kind == BlockKind.ATTN_MLP:
            return self._attn_params() + self._mlp_params()
        if kind == BlockKind.ATTN_MOE:
            assert self.moe is not None
            n_exp = self.moe.experts_per_token if active_only else self.moe.num_experts
            n_exp += self.moe.num_shared_experts  # shared experts always run
            mult = 3 if self.mlp_gated else 2
            expert = mult * d * self.moe.expert_d_ff
            router = d * self.moe.num_experts
            return self._attn_params() + n_exp * expert + router
        if kind == BlockKind.MAMBA2:
            assert self.ssm is not None
            s = self.ssm
            d_inner = s.expand * d
            nheads = s.num_ssm_heads or d_inner // s.head_dim
            p = d * (2 * d_inner + 2 * nheads * s.state_dim + nheads)  # in_proj (z,x,B,C,dt)
            p += s.conv_width * (d_inner + 2 * nheads * s.state_dim)   # conv over x,B,C
            p += 2 * nheads                                            # A_log, D
            p += d_inner                                               # gated rmsnorm
            p += d_inner * d                                           # out_proj
            return p + d  # pre-norm
        if kind == BlockKind.RWKV6:
            # time-mix (r,k,v,g,w projections + output) + channel-mix
            p = 4 * d * d + d * d  # r,k,v,g + output
            p += d * 64 * 2 + 5 * d * 2  # w lora + token-shift mix params (approx, exact in model)
            p += d * self.d_ff + self.d_ff * d + d * d  # channel mix (k,v,r)
            return p + 2 * d
        if kind == BlockKind.HYBRID_SHARED_ATTN:
            # Zamba2 shared attention block: attention + dense MLP
            return self._attn_params() + self._mlp_params()
        raise ValueError(kind)

    def expert_param_count(self) -> int:
        """Routed-expert weights only (stay sharded under expert parallelism)."""
        if self.moe is None:
            return 0
        mult = 3 if self.mlp_gated else 2
        per_layer = self.moe.num_experts * mult * self.d_model * self.moe.expert_d_ff
        n_moe = sum(1 for k in self.layer_pattern if k == BlockKind.ATTN_MOE)
        return per_layer * n_moe

    def flops_per_token(self, seq_len: int = 1) -> int:
        """6*N_active*D style estimate (fwd+bwd=6x; fwd-only = 2x active params)."""
        return 2 * self.active_param_count()


def round_up(x: int, m: int) -> int:
    return m * math.ceil(x / m)
