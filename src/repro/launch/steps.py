"""Abstract step builders for the dry-run: ShapeDtypeStruct stand-ins for
every model input, the step callables, and their shardings.

No device allocation happens here — params/caches/inputs are all abstract
(jax.eval_shape), the same pattern real launchers then feed with actual
arrays.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import InputShape, ModelConfig, get_shape
from repro.distributed import sharding as shd
from repro.models import Model, build_model
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule


def abstract_params(model: Model) -> Any:
    return jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))


def abstract_caches(model: Model, batch: int, seq_len: int) -> Any:
    return jax.eval_shape(lambda: model.init_caches(batch, seq_len))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the data-plane inputs of one step."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.num_prefix_embeddings:
            fed = cfg.frontend_embed_dim or cfg.d_model
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, fed), dt
            )
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        specs["lengths"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return specs


def build_step(
    cfg: ModelConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    remat: str = "block",
    policy: str = "fsdp",
    tenants: int = 1,
    microbatch: int = 1,
) -> Tuple[Any, Tuple, Any, Any]:
    """Returns (step_fn, abstract_args, in_shardings, out_shardings).

    policy: weight-sharding policy (see distributed.sharding.param_specs).
    tenants: R > 1 builds the SPACE-TIME MULTI-TENANT serve step — R
        tenants' weights stacked on a leading axis sharded over `data`,
        the global batch split across tenants, ONE vmapped program. This
        is the paper's inter-model batching expressed at pod scale
        (decode/prefill shapes only).
    microbatch: k > 1 splits the train batch into k sequential
        gradient-accumulation slices (lax.scan), cutting activation memory
        ~k x at unchanged math (grads averaged before the optimizer step).
    """
    shape = get_shape(shape_name)
    model = build_model(cfg, remat=remat)
    B, S = shape.global_batch, shape.seq_len
    if tenants > 1:
        return _build_multitenant_serve(cfg, model, shape, mesh, policy, tenants)

    p_abs = abstract_params(model)
    p_spec = shd.param_specs(p_abs, mesh, policy)
    in_data = input_specs(cfg, shape)
    d_spec = shd.input_specs_shardings(mesh, B, shape.kind)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, p_abs)
        opt_spec = shd.opt_state_specs(p_abs, mesh, policy)
        opt_spec = type(opt_abs)(step=P(), mu=opt_spec, nu=opt_spec)

        if B % microbatch != 0:
            raise ValueError(f"global batch {B} not divisible by microbatch {microbatch}")

        def train_step(params, opt, tokens, labels, prefix_embeds=None):
            def loss_fn(p, tok, lab, pref):
                loss, m = model.forward_train(p, tok, lab, pref)
                return loss

            if microbatch == 1:
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, tokens, labels, prefix_embeds
                )
            else:
                k = microbatch
                mb = B // k
                tok_k = tokens.reshape(k, mb, S)
                lab_k = labels.reshape(k, mb, S)
                pref_k = (
                    None
                    if prefix_embeds is None
                    else prefix_embeds.reshape(k, mb, *prefix_embeds.shape[1:])
                )

                def body(acc, xs):
                    tok, lab, pref = xs
                    l, g = jax.value_and_grad(loss_fn)(params, tok, lab, pref)
                    loss_acc, grads_acc = acc
                    return (
                        loss_acc + l,
                        jax.tree.map(jnp.add, grads_acc, g),
                    ), None

                zeros = jax.tree.map(lambda x: jnp.zeros_like(x), params)
                (loss, grads), _ = jax.lax.scan(
                    body,
                    (jnp.zeros((), jnp.float32), zeros),
                    (tok_k, lab_k, pref_k) if pref_k is not None else (tok_k, lab_k, None),
                )
                loss = loss / k
                grads = jax.tree.map(lambda g: g / k, grads)

            lr = lr_schedule(opt.step, 3e-4, 100, 1000)
            params, opt, om = adamw_update(grads, opt, params, lr)
            return params, opt, loss

        args = [p_abs, opt_abs, in_data["tokens"], in_data["labels"]]
        in_specs = [p_spec, opt_spec, d_spec["tokens"], d_spec["labels"]]
        if "prefix_embeds" in in_data:
            args.append(in_data["prefix_embeds"])
            in_specs.append(d_spec["prefix_embeds"])
        out_specs = (p_spec, opt_spec, P())
        return train_step, tuple(args), tuple(in_specs), out_specs

    if shape.kind == "prefill":
        def prefill_step(params, tokens, prefix_embeds=None):
            return model.forward_prefill(
                params, tokens, cache_len=S, prefix_embeds=prefix_embeds
            )

        args = [p_abs, in_data["tokens"]]
        in_specs = [p_spec, d_spec["tokens"]]
        if "prefix_embeds" in in_data:
            args.append(in_data["prefix_embeds"])
            in_specs.append(d_spec["prefix_embeds"])
        cache_abs = abstract_caches(model, B, S)
        c_spec = shd.cache_specs(cache_abs, mesh, B)
        out_specs = (P(d_spec["token"][0] if B > 1 else None, None), c_spec)
        return prefill_step, tuple(args), tuple(in_specs), out_specs

    # decode
    cache_abs = abstract_caches(model, B, S)
    c_spec = shd.cache_specs(cache_abs, mesh, B)

    def serve_step(params, token, caches, lengths):
        return model.forward_decode(params, token, caches, lengths)

    args = (p_abs, in_data["token"], cache_abs, in_data["lengths"])
    in_specs = (p_spec, d_spec["token"], c_spec, d_spec["lengths"])
    out_specs = (P(d_spec["token"][0], None), c_spec)
    return serve_step, args, in_specs, out_specs


def _build_multitenant_serve(cfg, model, shape, mesh, policy, R):
    """Tenant-stacked serve_step: params/caches/inputs carry a leading
    tenant axis sharded over `data`; per-tenant batch = global_batch / R."""
    from jax.sharding import PartitionSpec as P

    if shape.kind != "decode":
        raise ValueError("multi-tenant step builder supports decode shapes only")
    B_total, S = shape.global_batch, shape.seq_len
    if B_total % R != 0:
        raise ValueError(f"global batch {B_total} not divisible by tenants {R}")
    B = B_total // R

    def stack_r(tree):
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((R,) + l.shape, l.dtype), tree
        )

    # tenant axis takes `data` when divisible; otherwise tenants replicate
    # and `data` stays on the per-tenant batch inside the inner specs.
    tenant_axis = "data" if R % mesh.shape["data"] == 0 else None

    def prepend(spec_tree, axis):
        def fix(s: P) -> P:
            if axis is None:
                return P(None, *s)
            inner = [
                None if (a == axis or (isinstance(a, tuple) and axis in a)) else a
                for a in s
            ]
            return P(axis, *inner)

        return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))

    p_abs = stack_r(abstract_params(model))
    p_spec = prepend(shd.param_specs(abstract_params(model), mesh, "tp"), tenant_axis)
    cache_abs = stack_r(abstract_caches(model, B, S))
    c_spec = prepend(shd.cache_specs(abstract_caches(model, B, S), mesh, B), tenant_axis)

    token = jax.ShapeDtypeStruct((R, B), jnp.int32)
    lengths = jax.ShapeDtypeStruct((R, B), jnp.int32)
    t_spec = P(tenant_axis, None if tenant_axis else "data")

    def serve_step(params, token, caches, lengths):
        return jax.vmap(model.forward_decode)(params, token, caches, lengths)

    args = (p_abs, token, cache_abs, lengths)
    in_specs = (p_spec, t_spec, c_spec, t_spec)
    out_specs = (P(tenant_axis, None if tenant_axis else "data", None), c_spec)
    return serve_step, args, in_specs, out_specs


def eligible(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """Is (arch, shape) runnable? long_500k needs sub-quadratic attention."""
    shape = get_shape(shape_name)
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "skipped: pure full-attention arch (no sub-quadratic variant)"
    return True, ""
