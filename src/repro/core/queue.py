"""Shape-bucketed workload arrival queue.

Interactive inference queries arrive stochastically; each query decomposes
into schedulable workloads — kernel launches (mostly GEMMs) at the bottom
layer, prefill/decode cohorts at the serving layer. The queue groups
pending workloads by their *bucket* (any hashable mergeability key —
``ShapeBucket`` for GEMMs, tuples for engine cohorts); items in the same
bucket are mergeable into one super-dispatch. This is the front-end of the
unified space-time scheduler.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Dict, Hashable, List, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ShapeBucket:
    """Super-kernel mergeability key for GEMM-shaped workloads."""

    op: str                       # "gemm" (others pluggable)
    M: int
    K: int
    N: int
    dtype: str

    def __post_init__(self) -> None:
        # Buckets are dict keys on every queue/scheduler hot path and each
        # simulated event hashes its bucket several times; cache the tuple
        # hash once (same value the generated __hash__ would compute, so
        # dict layouts are unchanged). Not a field: repr/eq/asdict see
        # only the shape.
        object.__setattr__(
            self, "_hash",
            hash((self.op, self.M, self.K, self.N, self.dtype)))

    def __hash__(self) -> int:
        return self._hash

    @staticmethod
    def for_gemm(x: jax.Array, w: jax.Array) -> "ShapeBucket":
        M, K = x.shape
        _, N = w.shape
        return ShapeBucket("gemm", M, K, N, str(x.dtype))


_seq = itertools.count()


@dataclasses.dataclass
class GemmProblem:
    """One pending GEMM from one tenant's model.

    Satisfies the ``Workload`` protocol (see ``core.workload``): ``bucket``
    / ``cost`` / ``merge_family`` are derived from the operand shapes, and
    its executor is the scheduler's built-in ``SuperKernelCache`` (it
    carries no ``execute`` callback).
    """

    kind = "kernel"               # monitor latency class (not a field)

    tenant_id: int
    x: jax.Array                  # (M, K) activation
    w: jax.Array                  # (K, N) this tenant's weights
    arrival_time: float = 0.0
    slo_s: float = 0.100
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    # filled by the scheduler on completion:
    result: Optional[jax.Array] = None
    completion_time: Optional[float] = None

    @property
    def bucket(self) -> ShapeBucket:
        return ShapeBucket.for_gemm(self.x, self.w)

    @property
    def merge_family(self) -> Tuple:
        """GEMMs sharing (op, K, N, dtype) may ragged-merge across M."""
        b = self.bucket
        return (b.op, b.K, b.N, b.dtype)

    @property
    def flops(self) -> int:
        M, K = self.x.shape
        N = self.w.shape[1]
        return 2 * M * K * N

    @property
    def cost(self) -> float:
        return float(self.flops)


class WorkQueue:
    """FIFO-per-bucket pending-workload store with per-tenant accounting.

    ``track_tenants=False`` skips the per-tenant counters (and makes
    ``pending_for_tenant`` constant 0): the scheduler only consults them
    when an admission cap is configured, and the simulator pushes millions
    of items through here — one defaultdict increment per push is real
    money on that path.
    """

    def __init__(self, track_tenants: bool = True) -> None:
        self._buckets: Dict[Hashable, Deque] = collections.defaultdict(
            collections.deque
        )
        self._per_tenant: Dict[int, int] = collections.defaultdict(int)
        self._track_tenants = track_tenants
        self._count = 0

    def push(self, item) -> int:
        """Append; returns the item's bucket depth after the push."""
        q = self._buckets[item.bucket]
        q.append(item)
        self._count += 1
        if self._track_tenants:
            self._per_tenant[item.tenant_id] += 1
        return len(q)

    def __len__(self) -> int:
        return self._count

    def pending_for_tenant(self, tenant_id: int) -> int:
        return self._per_tenant.get(tenant_id, 0)

    def buckets(self) -> List[Tuple[Hashable, int]]:
        return [(b, len(q)) for b, q in self._buckets.items() if q]

    def peek(self, bucket: Hashable) -> List:
        """Pending items of one bucket, FIFO order, without popping."""
        return list(self._buckets.get(bucket, ()))

    def head(self, bucket: Hashable):
        """Oldest pending item of a bucket (None if empty), O(1)."""
        q = self._buckets.get(bucket)
        return q[0] if q else None

    def oldest_arrival(self, bucket: Hashable) -> Optional[float]:
        q = self._buckets.get(bucket)
        return q[0].arrival_time if q else None

    def pop_batch(self, bucket: Hashable, max_n: int) -> List:
        """Pop up to max_n items from a bucket, FIFO order."""
        q = self._buckets[bucket]
        if len(q) <= max_n:
            out = list(q)
            q.clear()
        else:
            out = [q.popleft() for _ in range(max_n)]
        self._count -= len(out)
        if self._track_tenants:
            per_tenant = self._per_tenant
            for item in out:
                per_tenant[item.tenant_id] -= 1
        return out

    def drain(self) -> List:
        out = []
        for q in self._buckets.values():
            out.extend(q)
            q.clear()
        self._per_tenant.clear()
        self._count = 0
        return out


# Backwards-compatible alias: the queue predates the generic Workload
# refactor and most call sites still say "kernel queue".
KernelQueue = WorkQueue
