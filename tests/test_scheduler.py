"""Unit tests for the dynamic space-time scheduler components."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ScheduleConfig
from repro.core import DynamicSpaceTimeScheduler, GemmProblem, KernelQueue
from repro.core.queue import ShapeBucket
from repro.core.slo import LatencyMonitor
from repro.core.superkernel import SuperKernelCache


def mk_problem(tenant, M=32, K=16, N=8, seed=0):
    k = jax.random.PRNGKey(seed * 1000 + tenant)
    return GemmProblem(
        tenant_id=tenant,
        x=jax.random.normal(k, (M, K), jnp.float32),
        w=jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32),
    )


class TestKernelQueue:
    def test_bucketing_by_shape(self):
        q = KernelQueue()
        q.push(mk_problem(0, M=32))
        q.push(mk_problem(1, M=32))
        q.push(mk_problem(2, M=64))
        assert len(q) == 3
        buckets = dict(q.buckets())
        assert len(buckets) == 2

    def test_fifo_within_bucket(self):
        q = KernelQueue()
        ps = [mk_problem(t) for t in range(5)]
        for p in ps:
            q.push(p)
        out = q.pop_batch(ps[0].bucket, 3)
        assert [p.tenant_id for p in out] == [0, 1, 2]
        out = q.pop_batch(ps[0].bucket, 10)
        assert [p.tenant_id for p in out] == [3, 4]


class TestSuperKernelCache:
    def test_r_bucketing_pow2(self):
        cache = SuperKernelCache(ScheduleConfig(r_bucketing="pow2"))
        b = ShapeBucket("gemm", 32, 16, 8, "float32")
        _, r1 = cache.get(b, 3)
        assert r1 == 4
        _, r2 = cache.get(b, 4)
        assert r2 == 4
        assert cache.stats.hits == 1 and cache.stats.misses == 1

    def test_hit_rate_improves_as_workload_stabilizes(self):
        """Paper section 4: overheads decrease as the cache warms."""
        cache = SuperKernelCache(ScheduleConfig())
        for _ in range(10):
            cache.execute([mk_problem(t) for t in range(3)])
        assert cache.stats.hit_rate >= 0.9

    def test_padding_discarded(self):
        cache = SuperKernelCache(ScheduleConfig(r_bucketing="pow2"))
        ps = [mk_problem(t) for t in range(3)]  # padded to R=4
        outs = cache.execute(ps)
        assert len(outs) == 3
        for p, o in zip(ps, outs):
            np.testing.assert_allclose(np.asarray(o), np.asarray(p.x @ p.w), rtol=1e-4, atol=1e-4)


class TestScheduler:
    def test_correctness_vs_direct(self):
        sched = DynamicSpaceTimeScheduler(ScheduleConfig(batching_window_s=0.0))
        ps = [mk_problem(t, seed=7) for t in range(9)]
        for p in ps:
            sched.submit(p)
        done = sched.flush()
        assert len(done) == 9
        for p in done:
            np.testing.assert_allclose(
                np.asarray(p.result), np.asarray(p.x @ p.w), rtol=1e-4, atol=1e-4
            )

    def test_batching_window_holds_work(self):
        sched = DynamicSpaceTimeScheduler(ScheduleConfig(batching_window_s=1000.0))
        sched.submit(mk_problem(0))
        assert sched.pump() == []          # window not elapsed, nothing ripe
        assert len(sched.queue) == 1
        assert len(sched.flush()) == 1     # force drains

    def test_max_superkernel_size_splits(self):
        cfg = ScheduleConfig(batching_window_s=0.0, max_superkernel_size=4)
        sched = DynamicSpaceTimeScheduler(cfg)
        for t in range(10):
            sched.submit(mk_problem(t))
        done = sched.flush()
        assert len(done) == 10
        assert sched.stats.dispatches == 3  # 4 + 4 + 2

    def test_mixed_buckets_dispatch_separately(self):
        sched = DynamicSpaceTimeScheduler(ScheduleConfig(batching_window_s=0.0))
        for t in range(4):
            sched.submit(mk_problem(t, M=32))
        for t in range(4, 6):
            sched.submit(mk_problem(t, M=64))
        done = sched.flush()
        assert len(done) == 6
        assert sched.stats.dispatches == 2


class TestLatencyMonitor:
    def test_straggler_detection(self):
        mon = LatencyMonitor(ewma_alpha=1.0, eviction_ratio=1.5)
        for _ in range(3):
            for t in range(4):
                mon.record(t, 0.010, 1.0)
            mon.record(9, 0.100, 1.0)  # 10x slower tenant
        assert mon.stragglers() == [9]

    def test_predictability_spread(self):
        mon = LatencyMonitor()
        for t in range(4):
            mon.record(t, 0.010, 1.0)
        assert mon.predictability_spread() == pytest.approx(0.0)
        mon.record(5, 0.0125, 1.0)  # 25% gap — the paper's Fig 4 MPS number
        assert mon.predictability_spread() == pytest.approx(0.25)

    def test_eviction_hook_fires(self):
        evicted = []
        sched = DynamicSpaceTimeScheduler(
            ScheduleConfig(batching_window_s=0.0, straggler_eviction_ratio=1.2),
            on_evict=evicted.append,
        )
        # fake latencies by monkeypatching the monitor directly
        for _ in range(5):
            for t in range(4):
                sched.monitor.record(t, 0.010, 1.0)
            sched.monitor.record(9, 0.100, 1.0)
        sched._evict_stragglers()
        assert evicted == [9]


class TestRaggedMerge:
    """Beyond-paper: variable-M merge via the grouped (MAGMA-vbatched) kernel."""

    def test_ragged_single_dispatch_correct(self):
        import jax
        cfg = ScheduleConfig(batching_window_s=0.0, allow_ragged_merge=True)
        sched = DynamicSpaceTimeScheduler(cfg)
        key = jax.random.PRNGKey(0)
        probs = []
        for t, M in enumerate([32, 100, 7, 256, 1]):
            kx, kw = jax.random.split(jax.random.fold_in(key, t))
            probs.append(GemmProblem(
                tenant_id=t,
                x=jax.random.normal(kx, (M, 64), jnp.float32),
                w=jax.random.normal(kw, (64, 48), jnp.float32)))
        for p in probs:
            sched.submit(p)
        done = sched.flush()
        assert len(done) == 5
        assert sched.stats.dispatches == 1  # one grouped super-kernel
        for p in done:
            assert p.result.shape == (p.x.shape[0], 48)
            np.testing.assert_allclose(
                np.asarray(p.result), np.asarray(p.x @ p.w), rtol=1e-4, atol=1e-3)

    def test_uniform_still_uses_batched_path(self):
        cfg = ScheduleConfig(batching_window_s=0.0, allow_ragged_merge=True)
        sched = DynamicSpaceTimeScheduler(cfg)
        for t in range(4):
            sched.submit(mk_problem(t))
        done = sched.flush()
        assert len(done) == 4 and sched.stats.dispatches == 1

    def test_different_kn_not_merged(self):
        cfg = ScheduleConfig(batching_window_s=0.0, allow_ragged_merge=True)
        sched = DynamicSpaceTimeScheduler(cfg)
        sched.submit(mk_problem(0, M=32, K=16, N=8))
        sched.submit(mk_problem(1, M=32, K=24, N=8))  # different K
        done = sched.flush()
        assert len(done) == 2 and sched.stats.dispatches == 2
