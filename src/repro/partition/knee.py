"""Throughput-vs-share curves and the D-STACK-style knee share.

For a (bucket, R) workload — R problems of one shape merged into one
super-kernel — throughput as a function of the chip fraction it runs on
is concave with a knee: the roofline terms scale with the share, the
per-launch overheads (dispatch, pipe fill) do not, so beyond some share
the fixed costs are amortized and extra chip% buys almost nothing.
D-STACK and "Spatial Sharing of GPU for Autotuning DNN models" both
exploit exactly this curve; the knee share is where a planner should
STOP growing a partition (``repro.partition.planner``).

Curves are priced either analytically (``RooflineCostModel`` over
``HardwareSpec.sliced(share)``) or from a calibrated table
(``CalibratedCostModel.dispatch_share_s`` — measured whole-chip seconds
decomposed into fixed overhead + a share-scaled remainder, with the
count-weighted shrinkage toward the roofline prior keeping curves from
thin tables smooth). Everything here is a pure function of its inputs —
same workload, same grid, same knee — which is what makes planner output
byte-identical per seed.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

from repro.launch.roofline import HardwareSpec
from repro.sim.costmodel import RooflineCostModel

# Candidate shares, ascending: sixteenths up to a half (where knees for
# launch-dominated shapes live), then coarser steps to the whole chip.
DEFAULT_SHARE_GRID: Tuple[float, ...] = (
    0.0625, 0.125, 0.1875, 0.25, 0.3125, 0.375, 0.5,
    0.625, 0.75, 0.875, 1.0,
)

#: ``price(batch, share) -> seconds`` for one merged dispatch on a slice.
SharePricer = Callable[[Sequence, float], float]


def share_pricer(
    hardware: HardwareSpec,
    strategy: str = "space_time",
    small_kernel_efficiency: float = 0.45,
    calibrated=None,
) -> SharePricer:
    """Build the ``price(batch, share)`` function knee curves sweep.

    With ``calibrated`` (a ``CalibratedCostModel``), measured costs win:
    pricing goes through ``dispatch_share_s`` (fitted-or-prior seconds,
    overhead-decomposed and share-scaled). Otherwise each share prices
    through a ``RooflineCostModel`` over ``hardware.sliced(share)`` —
    models are cached per share, so sweeping a grid over many workloads
    builds each slice once.
    """
    if calibrated is not None:
        return lambda batch, share: calibrated.dispatch_share_s(batch, share)
    cache = {}

    def price(batch: Sequence, share: float) -> float:
        model = cache.get(share)
        if model is None:
            model = RooflineCostModel(
                spec=hardware.sliced(share), strategy=strategy,
                small_kernel_efficiency=small_kernel_efficiency)
            cache[share] = model
        return model(batch)

    return price


def throughput_curve(
    workload,
    r: int,
    price: SharePricer,
    shares: Sequence[float] = DEFAULT_SHARE_GRID,
) -> Tuple[Tuple[float, float], ...]:
    """``(share, problems/s)`` points for R merged copies of ``workload``.

    ``workload`` is anything with ``flops``/``bytes`` (a ``TenantSpec``,
    a ``SimWorkload``); R copies model the super-kernel the scheduler
    would actually dispatch for that (bucket, R) key.
    """
    if r < 1:
        raise ValueError(f"r must be >= 1, got {r}")
    if not shares:
        raise ValueError("shares grid must be non-empty")
    batch = [workload] * int(r)
    out = []
    for s in shares:
        t = price(batch, s)
        out.append((float(s), (r / t) if t > 0.0 else float("inf")))
    return tuple(out)


def knee_share(
    curve: Sequence[Tuple[float, float]],
    knee_fraction: float = 0.9,
    min_share: float = 0.0,
    tol: float = 1e-12,
) -> float:
    """The knee: the SMALLEST share on the curve whose throughput reaches
    ``knee_fraction`` of the curve's best throughput.

    On a monotone non-decreasing curve (throughput never falls as the
    share grows — the roofline guarantee) this is the unique crossing of
    the threshold, hence well-defined; ``min_share`` floors the answer
    for planners that refuse slivers. Raising ``knee_fraction`` can only
    move the knee up the curve.
    """
    if not curve:
        raise ValueError("knee_share needs a non-empty curve")
    if not (0.0 < knee_fraction <= 1.0):
        raise ValueError(
            f"knee_fraction must be in (0, 1], got {knee_fraction}")
    points = sorted(curve)
    best = max(thr for _, thr in points)
    threshold = knee_fraction * best
    for share, thr in points:
        if share + tol < min_share:
            continue
        if thr + tol >= threshold:
            return share
    # every eligible share is below threshold (min_share excluded the
    # crossing): the largest share is the closest the grid can get
    return points[-1][0]


def knee_for(
    workload,
    r: int,
    price: SharePricer,
    shares: Sequence[float] = DEFAULT_SHARE_GRID,
    knee_fraction: float = 0.9,
    min_share: float = 0.0,
) -> float:
    """Convenience: the (bucket, R) workload's knee share in one call."""
    return knee_share(throughput_curve(workload, r, price, shares),
                      knee_fraction=knee_fraction, min_share=min_share)


def pareto_shares(
    curve: Sequence[Tuple[float, float]],
    fractions: Sequence[float],
    min_share: Optional[float] = None,
) -> Tuple[float, ...]:
    """Knee shares at several quality fractions of one curve — the
    sensitivity view ``benchmarks/partition_sweep.py`` reports."""
    return tuple(
        knee_share(curve, knee_fraction=f, min_share=min_share or 0.0)
        for f in fractions)
