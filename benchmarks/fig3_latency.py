"""Figure 3: model-level decode latency vs tenant count, space-time vs
time-only multiplexing.

Paper setup: MobileNetV2 (compute-light) + ResNet-50 (heavy) on a V100.
Here: two assigned-arch smoke variants (stablelm = light dense,
granite-moe = heavier routed) decoding concurrently under the serving
engine's two modes. Claim validated: time_only per-step latency grows
~linearly in R (serialized dispatch), space_time grows sub-linearly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import numpy as np

from repro.config import get_config, smoke_variant
from repro.models import build_model
from repro.serving import EngineConfig, InferenceRequest, MultiTenantEngine


def bench_arch(arch: str, tenant_counts=(1, 2, 4, 8), steps: int = 12, csv_rows=None):
    cfg = dataclasses.replace(smoke_variant(get_config(arch)), dtype="float32")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    rng = np.random.RandomState(0)
    print(f"\n--- {arch} (reduced) decode-step latency vs tenants ---")
    print(f"{'R':>3s} {'time_only ms':>14s} {'space_time ms':>14s} {'ratio':>7s}")
    for r in tenant_counts:
        params = [m.init(jax.random.fold_in(key, t)) for t in range(r)]
        lat = {}
        for mode in ("time_only", "space_time"):
            eng = MultiTenantEngine(
                m, params,
                EngineConfig(num_tenants=r, slots_per_tenant=1, cache_len=48, mode=mode),
            )
            for t in range(r):
                eng.submit(InferenceRequest(
                    tenant_id=t, prompt=list(rng.randint(1, cfg.vocab_size, 8)),
                    max_new_tokens=steps))
            eng.step()  # admission + compile warmup outside timing
            t0 = time.perf_counter()
            n = 0
            while eng.active:
                eng.step()
                n += 1
            lat[mode] = (time.perf_counter() - t0) / max(n, 1)
        ratio = lat["time_only"] / lat["space_time"]
        print(f"{r:3d} {lat['time_only']*1e3:14.2f} {lat['space_time']*1e3:14.2f} "
              f"{ratio:6.2f}x")
        if csv_rows is not None:
            for mode, v in lat.items():
                csv_rows.append((f"fig3/{arch}/R{r}/{mode}", v * 1e6,
                                 f"step_latency_ratio={ratio:.2f}"))


def run(csv_rows=None):
    print("\n=== Fig 3: latency vs tenant count (engine modes) ===")
    for arch in ("stablelm-1.6b", "granite-moe-1b-a400m"):
        bench_arch(arch, csv_rows=csv_rows)


if __name__ == "__main__":
    run()
