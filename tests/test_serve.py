"""The HTTP front door (repro.launch.serve): endpoints, replica fan-out,
admission surfacing, and the shutdown report contract — all in-process
on an ephemeral port with the jax-free fake engine."""

import json
import threading
import urllib.error
import urllib.request
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.api.spec import ServeSpec, SystemSpec
from repro.launch.serve import ADMIT_REASONS, FleetServer


def _serve_spec(report_path=None, **system_over):
    doc = {
        "mode": "live",
        "workload": {"mix": "sgemm", "tenants": 4, "events": 100,
                     "seed": 7, "rate_hz": 2000.0, "arch": "fake",
                     "max_new_tokens": 8},
        "fleet": {"replicas": 2},
        "router": {"policy": "least_cost"},
        "scheduler": {"admission_policy": "feasibility"},
    }
    doc.update(system_over)
    return ServeSpec(system=SystemSpec.from_dict(doc), port=0,
                     report_path=report_path, request_timeout_s=10.0,
                     poll_interval_s=0.01)


@pytest.fixture()
def server():
    srv = FleetServer(_serve_spec())
    srv.start()
    t = threading.Thread(target=srv.httpd.serve_forever, daemon=True)
    t.start()
    yield srv
    srv.httpd.shutdown()
    srv.shutdown()
    t.join(timeout=5)


def _get(srv, path):
    with urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}{path}", timeout=10) as r:
        return r.status, json.loads(r.read())


def _predict(srv, tenant_id, prompt):
    req = urllib.request.Request(
        f"http://127.0.0.1:{srv.port}/v1/predict",
        data=json.dumps({"tenant_id": tenant_id, "prompt": prompt}).encode())
    with urllib.request.urlopen(req, timeout=10) as r:
        return r.status, json.loads(r.read())


class TestEndpoints:
    def test_healthz(self, server):
        status, doc = _get(server, "/healthz")
        assert status == 200
        assert doc["status"] == "ok" and doc["replicas"] == 2
        assert doc["engine"] == "fake" and doc["router"] == "least_cost"

    def test_predict_returns_tokens(self, server):
        status, doc = _predict(server, 1, [5, 6, 7])
        assert status == 200
        assert len(doc["tokens"]) == 8
        assert doc["replica"] in (0, 1)
        assert doc["latency_s"] > 0

    def test_predict_deterministic_per_tenant_prompt(self, server):
        _, a = _predict(server, 2, [1, 2])
        _, b = _predict(server, 2, [1, 2])
        assert a["tokens"] == b["tokens"]
        _, c = _predict(server, 3, [1, 2])
        assert c["tokens"] != a["tokens"]

    def test_concurrent_predicts_fan_out(self, server):
        def hit(i):
            return _predict(server, i % 4, [1, i])[1]

        with ThreadPoolExecutor(16) as ex:
            outs = list(ex.map(hit, range(48)))
        assert all(len(o["tokens"]) == 8 for o in outs)
        # backlog pressure must spread cohorts over both replicas
        assert len({o["replica"] for o in outs}) == 2

    def test_report_endpoint(self, server):
        for i in range(4):
            _predict(server, i, [i])
        status, doc = _get(server, "/v1/report")
        assert status == 200
        assert doc["executor"] == "serve" and doc["mode"] == "live"
        assert doc["metrics"]["http"]["requests"] >= 4
        assert sum(doc["metrics"]["routed_counts"]) >= 4
        assert "scheduler" in doc["metrics"]

    def test_unknown_route_404(self, server):
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(server, "/nope")
        assert e.value.code == 404

    def test_bad_request_400(self, server):
        req = urllib.request.Request(
            f"http://127.0.0.1:{server.port}/v1/predict",
            data=json.dumps({"prompt": "not-a-list"}).encode())
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(req, timeout=10)
        assert e.value.code == 400


class TestAdmission:
    def test_infeasible_rejection_surfaces_as_429(self):
        # an SLO no dispatch can meet makes feasibility admission reject
        # every request with reason code 3 (infeasible deadline)
        srv = FleetServer(_serve_spec(
            workload={"mix": "single", "tenants": 2, "events": 10,
                      "seed": 0, "rate_hz": 100.0, "arch": "fake",
                      "slo_s": 1e-12}))
        srv.start()
        threading.Thread(target=srv.httpd.serve_forever, daemon=True).start()
        try:
            with pytest.raises(urllib.error.HTTPError) as e:
                _predict(srv, 0, [1])
            assert e.value.code == 429
            doc = json.loads(e.value.read())
            assert doc["reason"] == ADMIT_REASONS[3] == "infeasible"
        finally:
            srv.httpd.shutdown()
            srv.shutdown()


class TestShutdown:
    def test_report_written_on_shutdown(self, tmp_path):
        path = str(tmp_path / "report.json")
        srv = FleetServer(_serve_spec(report_path=path))
        srv.start()
        threading.Thread(target=srv.httpd.serve_forever, daemon=True).start()
        _predict(srv, 0, [9])
        srv.httpd.shutdown()
        srv.shutdown()
        doc = json.loads(open(path).read())
        assert doc["executor"] == "serve"
        assert doc["metrics"]["http"]["requests"] == 1
        assert doc["spec"]["mode"] == "live"

    def test_shutdown_idempotent(self):
        srv = FleetServer(_serve_spec())
        srv.start()
        srv.shutdown()
        srv.shutdown()


class TestServeSpec:
    def test_round_trip(self):
        spec = _serve_spec()
        again = ServeSpec.from_json(spec.to_json())
        assert again.to_json() == spec.to_json()

    def test_rejects_sim_system(self):
        with pytest.raises(ValueError, match="live"):
            ServeSpec(system=SystemSpec(mode="sim"))

    def test_rejects_bad_port(self):
        with pytest.raises(ValueError, match="port"):
            ServeSpec(system=SystemSpec(mode="live"), port=70000)
