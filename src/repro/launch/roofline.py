"""Roofline analysis from the compiled dry-run artifact.

Three terms, all in seconds, per (arch × shape × mesh):

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is NOT in cost_analysis — we parse the optimized HLO text
and sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.

Hardware constants (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip accelerator roofline description.

    One reusable record instead of scattered module constants, so the
    roofline report, the derived-TPU benchmark models, and the
    ``repro.sim`` cost models all price work against the same hardware
    description (and alternative chips are a dataclass instance away).

    The dispatch/pipeline terms extend the classic three-roof model with
    the launch-cost constants the space-time paper's gains hinge on:
    merging R kernels into one super-kernel pays ``dispatch_overhead_s``
    once instead of R times.
    """

    name: str = "tpu_v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (one direction)
    dispatch_overhead_s: float = 2e-6    # host launch cost per kernel
    context_switch_s: float = 5e-6       # time-sliced context swap cost
    mxu_dim: int = 128                   # systolic array tile edge
    mxu_freq_hz: float = 940e6

    def t_compute(self, flops: float) -> float:
        return flops / self.peak_flops

    def t_memory(self, bytes_moved: float) -> float:
        return bytes_moved / self.hbm_bw

    def t_collective(self, bytes_moved: float) -> float:
        return bytes_moved / self.ici_bw

    def pipe_fill_s(self) -> float:
        """Systolic pipeline fill paid once per distinct kernel launch."""
        return self.mxu_dim / self.mxu_freq_hz

    def scaled(self, factor: float, name: Optional[str] = None) -> "HardwareSpec":
        """A same-architecture chip at ``factor`` x this one's throughput
        (an older or down-binned generation): the compute/memory/ICI roofs
        scale, the per-launch overheads (dispatch, context switch, pipe
        fill) do NOT — which is exactly why slower chips lose *more* to
        time-sliced multiplexing and heterogeneous fleets need
        speed-aware routing (see ``repro.sim.fleet``)."""
        if factor <= 0.0:
            raise ValueError(f"scale factor must be > 0, got {factor}")
        return dataclasses.replace(
            self,
            name=name or f"{self.name}_x{factor:g}",
            peak_flops=self.peak_flops * factor,
            hbm_bw=self.hbm_bw * factor,
            ici_bw=self.ici_bw * factor,
        )

    def sliced(self, share: float, name: Optional[str] = None) -> "HardwareSpec":
        """A fractional spatial partition of this chip: ``share`` of the
        compute/memory/ICI roofs, full-price launch overheads.

        ``scaled`` generalized from per-replica derating (a whole slower
        chip) to per-partition slices of ONE chip: a tenant granted 25%
        of the spatial units sees 25% of every roof, but still pays the
        full ``dispatch_overhead_s`` and pipe fill per kernel launch —
        the fixed terms that give throughput-vs-share curves their knee
        (``repro.partition.knee``). Shares of co-located slices must sum
        to <= 1.0; ``repro.partition.shares.PartitionPlan`` owns that
        validation."""
        if not (0.0 < share <= 1.0):
            raise ValueError(
                f"partition share must be in (0, 1], got {share} "
                f"(a share is a fraction of one chip's spatial units)")
        return self.scaled(share, name=name or f"{self.name}@{share:g}")


TPU_V5E = HardwareSpec()

# Named chips for CLI/spec surfaces (``fleet_sweep --specs ...``,
# ``repro.api`` hardware names): the current generation plus derated
# older generations of the same architecture — launch overheads
# identical, roofs scaled (see ``HardwareSpec.scaled``). Lives beside
# ``HardwareSpec`` so every layer (roofline report, sim cost models,
# declarative SystemSpec) resolves names against ONE registry.
HARDWARE_SPECS: Dict[str, HardwareSpec] = {
    "v5e": TPU_V5E,
    "v5e_half": TPU_V5E.scaled(0.5, name="v5e_half"),
    "v5e_quarter": TPU_V5E.scaled(0.25, name="v5e_quarter"),
}


def resolve_spec(spec) -> HardwareSpec:
    """Accept a ``HardwareSpec`` or a ``HARDWARE_SPECS`` name.

    Unknown names raise a ``ValueError`` that lists the registered keys —
    the same actionable message ``repro.api`` spec validation surfaces.
    """
    if isinstance(spec, HardwareSpec):
        return spec
    try:
        return HARDWARE_SPECS[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown hardware spec {spec!r} "
            f"(names: {sorted(HARDWARE_SPECS)})") from None


# Backwards-compatible module constants (pre-HardwareSpec callers).
PEAK_FLOPS = TPU_V5E.peak_flops
HBM_BW = TPU_V5E.hbm_bw
ICI_BW = TPU_V5E.ici_bw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction: `%name = <shape> opcode(...)` — shape may be a tuple.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\/ ]+?)\s+([\w\-]+)(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the whole module.

    '-start' variants are counted, '-done' skipped (same buffer). Sizes are
    the GLOBAL logical buffers in the annotated module; divide by chips for
    per-chip traffic downstream.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, opcode = m.groups()
        for coll in _COLLECTIVES:
            if opcode == coll or opcode == coll + "-start":
                out[coll] += _shape_bytes(shape_str)
                break
    return out


COLL_FACTOR = {
    # per-chip ICI traffic multiplier on the op's LOCAL result bytes
    # (partitioned-module shapes): ring all-gather moves ~result bytes per
    # chip; ring all-reduce ~2x its buffer; the rest ~1x.
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # raw cost_analysis (CPU backend: while-body
    hlo_bytes: float             # counted once — recorded for transparency)
    coll_bytes: Dict[str, int]   # per-chip local result bytes from HLO text
    model_flops: float           # 6*N_active*D (train) or 2*N_active*tokens (serve)
    analytic_flops: float = 0.0  # trip-count-exact analytic model (global)
    analytic_bytes: float = 0.0
    analytic_coll: Optional[Dict[str, float]] = None  # per-chip, trip-exact
    spec: HardwareSpec = TPU_V5E

    @property
    def coll_total(self) -> int:
        return sum(self.coll_bytes.values())

    @property
    def coll_time_bytes(self) -> float:
        return sum(COLL_FACTOR[k] * v for k, v in self.coll_bytes.items())

    @property
    def t_compute(self) -> float:
        return self.spec.t_compute(self.analytic_flops / self.chips)

    @property
    def t_memory(self) -> float:
        return self.spec.t_memory(self.analytic_bytes / self.chips)

    @property
    def t_collective(self) -> float:
        """Per-chip collective seconds.

        Uses max(analytic, HLO-text) — the text counts while bodies once
        (lower bound); the analytic model is trip-count exact but
        first-order.
        """
        text = self.spec.t_collective(self.coll_time_bytes)
        ana = self.spec.t_collective((self.analytic_coll or {}).get("total", 0.0))
        return max(text, ana)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.analytic_flops if self.analytic_flops else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_raw": self.hlo_flops,
            "hlo_bytes_raw": self.hlo_bytes,
            "analytic_flops": self.analytic_flops,
            "analytic_bytes": self.analytic_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_total": self.coll_total,
            "analytic_coll": self.analytic_coll or {},
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analytic_cost(cfg, shape, *, remat: bool = True) -> Dict[str, float]:
    """Analytic FLOPs + HBM bytes for one step of (cfg, shape).

    Needed because XLA's HloCostAnalysis on the CPU backend counts a
    while-loop (lax.scan over layer units) body ONCE instead of
    trip-count times, so ``cost_analysis()`` under-reports scanned stacks
    by ~num_layers x. We therefore derive the roofline terms from this
    analytic model (exact for GEMMs, first-order for elementwise) and
    record the raw cost_analysis numbers alongside for transparency.

    Conventions:
        train:   fwd(1x) + bwd(2x) + remat recompute(1x) = 4x fwd FLOPs
        prefill: 1x fwd
        decode:  1x fwd over 1 token/seq; HBM bytes dominated by weight +
                 cache streaming.
    """
    from repro.config import BlockKind  # local import to avoid cycle

    B, S = shape.global_batch, shape.seq_len
    tokens = B * (S if shape.kind in ("train", "prefill") else 1)
    dt_bytes = 2 if cfg.dtype == "bfloat16" else 4

    # ---- matmul params touched per token (active) -> GEMM flops
    n_active = cfg.active_param_count()
    # embedding lookup is a gather, not a matmul; subtract one vocab table
    n_matmul = n_active - cfg.vocab_size * cfg.d_model
    gemm_flops = 2.0 * tokens * n_matmul

    # ---- attention score/value flops per layer kind
    attn_flops = 0.0
    hd, Hq = cfg.head_dim, cfg.num_heads
    for i, kind in enumerate(cfg.layer_pattern):
        if kind not in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE,
                        BlockKind.HYBRID_SHARED_ATTN):
            continue
        ak = cfg.attention_kind_at(i)
        if shape.kind in ("train", "prefill"):
            kv_eff = S if ak.value == "full" else min(cfg.sliding_window or S, S)
            # causal halves the average context; sliding window doesn't
            ctx = S / 2 if ak.value == "full" else kv_eff
            attn_flops += 4.0 * B * S * ctx * Hq * hd
        else:
            kv_eff = S if ak.value == "full" else min(cfg.sliding_window or S, S)
            attn_flops += 4.0 * B * kv_eff * Hq * hd

    # ---- SSM / RWKV recurrence flops
    ssm_flops = 0.0
    if cfg.ssm is not None:
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = s.num_ssm_heads or d_inner // s.head_dim
        P, N, L = s.head_dim, s.state_dim, s.chunk_size
        for kind in cfg.layer_pattern:
            if kind == BlockKind.MAMBA2:
                if shape.kind in ("train", "prefill"):
                    # intra-chunk: scores 2*T*L*N + y 2*T*L*H*P (causal ~ /2)
                    ssm_flops += B * S * (L * N + L * H * P) \
                        + 4.0 * B * S * H * P * N  # states in/out
                else:
                    ssm_flops += 6.0 * B * H * P * N
            elif kind == BlockKind.RWKV6:
                per_tok = 6.0 * H * N * N  # state update + readout
                ssm_flops += (B * S if shape.kind in ("train", "prefill") else B) * per_tok

    fwd = gemm_flops + attn_flops + ssm_flops
    factor = (4.0 if remat else 3.0) if shape.kind == "train" else 1.0
    flops = fwd * factor

    # ---- HBM bytes
    param_bytes = cfg.param_count() * dt_bytes
    if shape.kind == "train":
        # params fwd+bwd+remat reads + grad writes + opt state rw (f32)
        pbytes = param_bytes * 4 + cfg.param_count() * 4 * 3
        act_bytes = 12.0 * tokens * cfg.d_model * dt_bytes * cfg.num_layers / 4
        logit_bytes = 4.0 * tokens * cfg.vocab_size
        hbm = pbytes + act_bytes + logit_bytes
    elif shape.kind == "prefill":
        hbm = param_bytes + 8.0 * tokens * cfg.d_model * dt_bytes * cfg.num_layers / 4 \
            + cache_bytes(cfg, shape)
    else:
        hbm = cfg.active_param_count() * dt_bytes + cache_bytes(cfg, shape)
    return {"flops": flops, "hbm_bytes": hbm, "fwd_flops": fwd}


def analytic_collectives(
    cfg, shape, *, policy: str = "fsdp", tp_acts: bool = True,
    data: int = 16, model: int = 16, pods: int = 1,
) -> Dict[str, float]:
    """Analytic per-chip collective bytes for one step.

    Needed for the same reason as ``analytic_cost``: the HLO text shows
    scan (while) bodies ONCE, so text-derived collective bytes are a lower
    bound that under-counts anything inside the layer scan by ~num_units x.
    First-order ring-collective model:

      weight all-gather (fsdp):  passes x param_bytes      (train: fwd+bwd+remat=3)
      grad sync (train):         2 x param_bytes           (ring all-reduce, bf16)
      TP activation all-reduce:  4 x toks_local x d_model x 4B x n_blocks
                                 (1 row-parallel AR fwd + ~2 bwd + 1 remat per block)
      ZeRO-1 pod sync:           2 x param_bytes across pods (multi-pod train)
    """
    dt_bytes = 2 if cfg.dtype == "bfloat16" else 4
    param_bytes = cfg.param_count() * dt_bytes
    # Routed-expert weights are ALWAYS expert-parallel (forced constraints)
    # and never gathered — only the dense remainder moves under FSDP.
    dense_bytes = (cfg.param_count() - cfg.expert_param_count()) * dt_bytes
    B, S = shape.global_batch, shape.seq_len
    toks_local = B * (S if shape.kind in ("train", "prefill") else 1) / data
    n_blocks = cfg.num_layers

    # grads are synced over the data axis PER SHARD: a chip holding 1/model
    # of the params moves 2 x its local shard bytes in the ring, not 2 x
    # the global total (replicate keeps full bytes).
    shard_div = 1 if policy == "replicate" else model

    out = {"weight_ag": 0.0, "grad_ar": 0.0, "tp_ar": 0.0, "pod_ar": 0.0}
    if shape.kind == "train":
        if policy == "fsdp":
            out["weight_ag"] = 3.0 * dense_bytes
        out["grad_ar"] = 2.0 * param_bytes / shard_div
        if pods > 1:
            out["pod_ar"] = 2.0 * param_bytes / shard_div
        if tp_acts and policy in ("fsdp", "tp"):
            out["tp_ar"] = 4.0 * toks_local * cfg.d_model * 4.0 * n_blocks
    else:
        if policy == "fsdp":
            out["weight_ag"] = 1.0 * dense_bytes / max(data, 1)  # amortized:
            # weights stay gathered across the (single) step; decode
            # re-gathers the data-sharded fraction only.
        if tp_acts and policy in ("fsdp", "tp"):
            out["tp_ar"] = 2.0 * toks_local * cfg.d_model * 4.0 * n_blocks
    out["total"] = sum(out.values())
    return out


def cache_bytes(cfg, shape) -> float:
    """Decode-state bytes read per step (KV caches + recurrent states)."""
    from repro.config import BlockKind

    B, S = shape.global_batch, shape.seq_len
    dt_bytes = 2 if cfg.dtype == "bfloat16" else 4
    total = 0.0
    for i, kind in enumerate(cfg.layer_pattern):
        if kind in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE,
                    BlockKind.HYBRID_SHARED_ATTN):
            ak = cfg.attention_kind_at(i)
            s_alloc = S if ak.value == "full" else min(cfg.sliding_window or S, S)
            total += 2.0 * B * cfg.num_kv_heads * s_alloc * cfg.head_dim * dt_bytes
        elif kind == BlockKind.MAMBA2 and cfg.ssm is not None:
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            H = s.num_ssm_heads or d_inner // s.head_dim
            total += B * H * s.head_dim * s.state_dim * 4
        elif kind == BlockKind.RWKV6 and cfg.ssm is not None:
            H = cfg.d_model // cfg.ssm.head_dim
            total += B * H * cfg.ssm.head_dim ** 2 * 4
    return total


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*D for training; 2*N_active*tokens for serving."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
