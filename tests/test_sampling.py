"""Sampling module: greedy/temperature/top-k/top-p properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.sampling import SamplingParams, apply_top_k, apply_top_p, sample

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def test_greedy_matches_argmax(rng_key):
    logits = jax.random.normal(rng_key, (4, 100))
    got = sample(logits, SamplingParams(temperature=0.0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))


def test_top_k_masks_everything_else(rng_key):
    logits = jax.random.normal(rng_key, (3, 50))
    masked = apply_top_k(logits, 5)
    n_alive = np.sum(np.asarray(masked) > -1e29, axis=-1)
    np.testing.assert_array_equal(n_alive, [5, 5, 5])
    # surviving entries are exactly the 5 largest
    for row, mrow in zip(np.asarray(logits), np.asarray(masked)):
        top5 = set(np.argsort(row)[-5:])
        assert set(np.where(mrow > -1e29)[0]) == top5


def test_top_p_keeps_nucleus(rng_key):
    logits = jnp.asarray([[10.0, 9.0, 0.0, -5.0, -5.0]])
    masked = apply_top_p(logits, 0.9)
    alive = np.where(np.asarray(masked[0]) > -1e29)[0]
    assert set(alive) == {0, 1}  # two dominant tokens carry >0.9 mass


def test_top_p_one_is_noop(rng_key):
    logits = jax.random.normal(rng_key, (2, 20))
    np.testing.assert_array_equal(np.asarray(apply_top_p(logits, 1.0)), np.asarray(logits))


@given(k=st.integers(1, 20), seed=st.integers(0, 5))
def test_sampled_token_always_in_top_k(k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (2, 20))
    tok = sample(logits, SamplingParams(temperature=1.0, top_k=k),
                 jax.random.fold_in(key, 1))
    for row, t in zip(np.asarray(logits), np.asarray(tok)):
        assert t in set(np.argsort(row)[-k:])


def test_temperature_sharpens(rng_key):
    """At tiny temperature, sampling converges to greedy."""
    logits = jax.random.normal(rng_key, (8, 30))
    tok = sample(logits, SamplingParams(temperature=1e-4),
                 jax.random.fold_in(rng_key, 2))
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(jnp.argmax(logits, -1)))


def test_nongreedy_requires_key(rng_key):
    logits = jax.random.normal(rng_key, (1, 10))
    with pytest.raises(ValueError):
        sample(logits, SamplingParams(temperature=1.0), key=None)
