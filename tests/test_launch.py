"""Launch-layer tests runnable on the single host device: input_specs
shapes, eligibility rules, microbatch math equivalence, analytic roofline
sanity. (Full-mesh lowering is exercised by repro.launch.dryrun in its own
process — it needs the 512-device XLA flag.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, get_shape, smoke_variant
from repro.launch.roofline import analytic_collectives, collective_bytes
from repro.launch.steps import eligible, input_specs
from repro.models import build_model
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule


class TestInputSpecs:
    def test_train_shape(self):
        cfg = get_config("granite-3-8b")
        s = input_specs(cfg, get_shape("train_4k"))
        assert s["tokens"].shape == (256, 4096)
        assert s["labels"].shape == (256, 4096)
        assert "prefix_embeds" not in s

    def test_vlm_prefix(self):
        cfg = get_config("paligemma-3b")
        s = input_specs(cfg, get_shape("prefill_32k"))
        assert s["prefix_embeds"].shape == (32, 256, 1152)

    def test_decode_shape(self):
        cfg = get_config("rwkv6-1.6b")
        s = input_specs(cfg, get_shape("decode_32k"))
        assert s["token"].shape == (128,)
        assert s["lengths"].shape == (128,)

    def test_eligibility(self):
        ok, _ = eligible(get_config("rwkv6-1.6b"), "long_500k")
        assert ok
        ok, why = eligible(get_config("qwen2-7b"), "long_500k")
        assert not ok and "full-attention" in why
        assert eligible(get_config("gemma3-27b"), "long_500k")[0]  # sliding window


def test_microbatch_equivalence(rng_key):
    """k-microbatched accumulated gradients == full-batch gradients."""
    cfg = dataclasses.replace(smoke_variant(get_config("stablelm-1.6b")), dtype="float32")
    m = build_model(cfg, remat="none")
    params = m.init(rng_key)
    B, S, k = 4, 16, 2
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)

    def loss_fn(p, t, l):
        return m.forward_train(p, t, l)[0]

    full_loss, full_grads = jax.value_and_grad(loss_fn)(params, toks, labels)

    mb_loss = 0.0
    mb_grads = jax.tree.map(jnp.zeros_like, params)
    for i in range(k):
        sl = slice(i * B // k, (i + 1) * B // k)
        l, g = jax.value_and_grad(loss_fn)(params, toks[sl], labels[sl])
        mb_loss += l / k
        mb_grads = jax.tree.map(lambda a, b: a + b / k, mb_grads, g)

    np.testing.assert_allclose(float(mb_loss), float(full_loss), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(mb_grads), jax.tree.leaves(full_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_analytic_collectives_policies():
    cfg = get_config("granite-3-8b")
    train = get_shape("train_4k")
    decode = get_shape("decode_32k")

    fsdp = analytic_collectives(cfg, train, policy="fsdp", tp_acts=True)
    tp = analytic_collectives(cfg, train, policy="tp", tp_acts=True)
    repl = analytic_collectives(cfg, decode, policy="replicate", tp_acts=False)

    assert fsdp["weight_ag"] > 0 and tp["weight_ag"] == 0
    assert fsdp["grad_ar"] == tp["grad_ar"] > 0  # grads sync regardless
    assert repl["total"] == 0.0  # replicated decode: no collectives
    pod2 = analytic_collectives(cfg, train, policy="fsdp", tp_acts=True, pods=2)
    assert pod2["pod_ar"] > 0 and pod2["total"] > fsdp["total"]
