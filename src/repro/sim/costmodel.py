"""Dispatch cost models: analytical roofline prior + online calibration.

A cost model is a callable ``model(batch) -> seconds`` pricing ONE
super-dispatch of merged workloads; the scheduler advances its
``VirtualClock`` by that amount, which is what turns the live pump into a
deterministic simulator (see ``core.scheduler``).

Three models, designed to compose:

``RooflineCostModel``
    Analytical prior over a ``HardwareSpec`` (the reusable record the
    hard-coded TPU constants in ``launch/roofline.py`` were refactored
    into). First-order, strategy-aware:

        t_item_i   = max(flops_i/peak, bytes_i/hbm_bw)       (per workload)
        roof       = max(Σflops/peak, Σbytes/hbm_bw)         (merged batch)

        space_time = disp + fill + roof
        exclusive  = space_time (shared-weight upper bound; same roof here)
        space_only = disp + R*fill + roof/eff
        time_only  = Σ_i (ctx + disp + fill + t_item_i/eff)

    ``eff`` (< 1) models the spatial underutilization of small unmerged
    kernels: concurrent streams cannot widen any single kernel, so neither
    the MXU nor the HBM pipeline reaches its roof. Only the merged
    super-kernel runs at the roofline. Since Σ t_item_i >= roof always
    (sum of maxes dominates max of sums), the model *guarantees* the
    paper's qualitative ordering space_time > space_only > time_only for
    every batch, while the default eff lands the gaps in the ballpark of
    the paper's measured 3.23x/7.73x wins.

``CalibratedCostModel``
    Replaces the prior, per (bucket, pow2-R) key, with an EWMA fit of
    OBSERVED dispatch seconds — attach it to a live scheduler via the
    ``on_dispatch`` tap, then ``save()``/``load()`` the fitted table as
    JSON and replay millions of simulated events against real measured
    costs. Keys use the same ``round_pow2`` bucketing as the super-kernel
    compile cache, so a measurement made on a live (bucket, R) dispatch
    resolves for exactly the simulated batches that would have hit that
    compiled variant.

``ColdStartCostModel``
    Wraps either of the above with per-instance compile-cache accounting:
    the first dispatch per (bucket, pow2-R) key pays an extra ``compile_s``
    (XLA compilation of that super-kernel variant), later dispatches reuse
    the warm variant. One instance per fleet replica models per-replica
    compile caches — the state that makes warm-cache-affinity routing
    trade against load balance (see ``repro.sim.fleet``).
"""

from __future__ import annotations

import json
import os
from array import array
from typing import Callable, Dict, Optional, Sequence

from repro.core.workload import round_pow2
from repro.launch.roofline import (  # noqa: F401  (re-exported: the
    HARDWARE_SPECS,  # registry lives beside HardwareSpec in launch/roofline;
    TPU_V5E,  # sim callers keep importing it from here)
    HardwareSpec,
    resolve_spec,
)

# canonical strategy names, worst-to-best throughput (display order too)
STRATEGIES = ("time_only", "space_only", "space_time", "exclusive")


def _flops(w) -> float:
    # explicit None check: flops == 0.0 is a valid value (pure data
    # movement) and must NOT fall back to the abstract cost field
    flops = getattr(w, "flops", None)
    if flops is None:
        flops = getattr(w, "cost", 0.0)
    return float(flops)


def _bytes(w) -> float:
    return float(getattr(w, "bytes", 0.0) or 0.0)


class RooflineCostModel:
    """Analytical strategy-aware roofline prior (see module docstring)."""

    def __init__(
        self,
        spec: HardwareSpec = TPU_V5E,
        strategy: str = "space_time",
        small_kernel_efficiency: float = 0.45,
    ):
        if strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}, got {strategy!r}")
        if not (0.0 < small_kernel_efficiency <= 1.0):
            raise ValueError("small_kernel_efficiency must be in (0, 1]")
        self.spec = spec
        self.strategy = strategy
        self.eff = small_kernel_efficiency

    def __call__(self, batch: Sequence) -> float:
        # called once per super-dispatch with up to max_superkernel_size
        # items — the loops below are the simulator's per-item pricing
        # cost, so the flops/bytes fallbacks are inlined (one pass, no
        # per-item helper calls) with the exact arithmetic order of the
        # original sum() generators
        s = self.spec
        fill = s.pipe_fill_s()
        if self.strategy == "time_only":
            tot = 0.0
            t_compute, t_memory = s.t_compute, s.t_memory
            eff = self.eff
            per_item = s.context_switch_s + s.dispatch_overhead_s + fill
            for w in batch:
                flops = getattr(w, "flops", None)
                if flops is None:
                    flops = getattr(w, "cost", 0.0)
                t_item = max(t_compute(float(flops)),
                             t_memory(float(getattr(w, "bytes", 0.0) or 0.0)))
                tot += per_item + t_item / eff
            return tot
        f_sum = 0.0
        b_sum = 0.0
        for w in batch:
            flops = getattr(w, "flops", None)
            if flops is None:
                flops = getattr(w, "cost", 0.0)
            f_sum += float(flops)
            b_sum += float(getattr(w, "bytes", 0.0) or 0.0)
        roof = max(s.t_compute(f_sum), s.t_memory(b_sum))
        if self.strategy == "space_only":
            return s.dispatch_overhead_s + len(batch) * fill + roof / self.eff
        # space_time / exclusive: one wide kernel at the roofline
        return s.dispatch_overhead_s + fill + roof

    def item_s(self, w) -> float:
        """Marginal seconds of adding ``w`` to an already-forming merged
        batch: the incremental roofline term only — dispatch, fill, and
        (for a cold key) compile are paid by the batch regardless. Upper
        bounds ``cost(batch + w) - cost(batch)`` for the merged
        strategies; routers use it to price joining a pending bucket."""
        s = self.spec
        return max(s.t_compute(_flops(w)), s.t_memory(_bytes(w)))

    def estimate_item_s(self, w, share: float = 1.0) -> float:
        """Share-aware marginal: ``item_s`` when the tenant holds only a
        ``share`` fraction of this chip. The marginal term is pure roof
        (overheads are the batch's, not the item's) and roofs scale
        linearly with the spatial share, so the fractional price is
        exactly ``item_s / share`` — this is what feasibility admission
        charges a tenant on a partition slice instead of assuming
        whole-chip service (``repro.partition``)."""
        if not (0.0 < share <= 1.0):
            raise ValueError(f"share must be in (0, 1], got {share}")
        return self.item_s(w) / share


def batch_key(batch: Sequence) -> str:
    """Calibration key of one super-dispatch: (bucket, pow2-R) as a string.

    The pow2 rounding is the shared ``round_pow2`` the compile cache uses,
    so observed timings bucket exactly like compiled super-kernel variants.
    String-typed so the fitted table round-trips through JSON losslessly.
    """
    bucket = getattr(batch[0], "bucket", None)
    return f"{bucket!r}|r{round_pow2(len(batch))}"


class CalibratedCostModel:
    """EWMA-fitted per-(bucket, pow2-R) dispatch costs over a prior.

    Usage (live calibration -> simulated replay):

        model = CalibratedCostModel()
        sched = DynamicSpaceTimeScheduler(..., on_dispatch=model.observe)
        ...run live traffic...                # fits the table
        model.save("costs.json")
        sim_model = CalibratedCostModel.load("costs.json")
        Simulator(..., cost_model=sim_model)  # prices batches from data

    Warm-up: a key's first ``1/alpha`` observations are folded in at
    ``alpha_eff = 1/count`` (a plain cumulative mean), after which the fit
    settles into steady-state EWMA at ``alpha``. Observation counts are
    part of the persisted state: a loaded model resumes steady-state EWMA
    on its warm keys instead of letting one fresh sample overwrite a
    long-fitted value.

    Calibration confidence: ``prior_strength`` (a pseudo-count ``k``,
    default 0 = off) prices each fitted key as the count-weighted
    Bayesian blend ``(n*fitted + k*prior) / (n + k)`` — a key seen once
    stays near the analytical prior, a key seen hundreds of times is
    essentially its measured value. Without it, knee curves fit from
    thin tables are jagged: one noisy observation of a sparse
    (bucket, R) key would swing the whole throughput-vs-share sweep
    (``repro.partition.knee``).
    """

    def __init__(
        self,
        prior: Optional[Callable[[Sequence], float]] = None,
        ewma_alpha: float = 0.2,
        prior_strength: float = 0.0,
    ):
        if not (0.0 < ewma_alpha <= 1.0):
            raise ValueError("ewma_alpha must be in (0, 1]")
        if prior_strength < 0.0:
            raise ValueError(
                f"prior_strength must be >= 0, got {prior_strength}")
        self.prior = prior or RooflineCostModel()
        self.alpha = ewma_alpha
        self.prior_strength = float(prior_strength)
        self.table: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    # --------------------------------------------------------------- fitting
    def observe(self, batch: Sequence, seconds: float,
                replica_id: Optional[int] = None) -> None:
        """Fold one measured dispatch into the fit (scheduler ``on_dispatch``
        signature, so it plugs in directly; ``replica_id`` is accepted for
        tap compatibility — the table is fleet-wide)."""
        if not batch or seconds < 0.0:
            return
        key = batch_key(batch)
        count = self.counts.get(key, 0) + 1
        self.counts[key] = count
        prev = self.table.get(key)
        if prev is None:
            self.table[key] = seconds
            return
        # cumulative mean while count < 1/alpha, steady-state EWMA after
        alpha_eff = max(self.alpha, 1.0 / count)
        self.table[key] = alpha_eff * seconds + (1.0 - alpha_eff) * prev

    # --------------------------------------------------------------- pricing
    def __call__(self, batch: Sequence) -> float:
        key = batch_key(batch)
        fitted = self.table.get(key)
        if fitted is None:
            return self.prior(batch)
        k = self.prior_strength
        if k <= 0.0:
            return fitted
        n = self.counts.get(key, 1)
        return (n * fitted + k * self.prior(batch)) / (n + k)

    def coverage(self, batch: Sequence) -> bool:
        """True if this batch would be priced from data, not the prior."""
        return batch_key(batch) in self.table

    def item_s(self, w) -> float:
        """Marginal seconds of joining an already-forming batch of ``w``'s
        bucket. Fitted entries are WHOLE-dispatch costs, not increments,
        so the marginal term delegates to the prior's roofline marginal —
        routers pricing through a calibrated table keep seeing the
        merge-economy discount instead of a full solo dispatch."""
        prior_item = getattr(self.prior, "item_s", None)
        if prior_item is not None:
            return prior_item(w)
        return self((w,))

    def estimate_item_s(self, w, share: float = 1.0) -> float:
        """Share-aware marginal seconds (the ``repro.partition``
        surface): the marginal term is pure roof, so it scales as
        ``1/share`` regardless of whether the solo estimate came from
        the prior or a fitted table."""
        if not (0.0 < share <= 1.0):
            raise ValueError(f"share must be in (0, 1], got {share}")
        return self.item_s(w) / share

    def dispatch_share_s(self, batch: Sequence, share: float = 1.0) -> float:
        """Whole-dispatch seconds when the batch runs on a ``share``
        fraction of the chip: the blended fitted-or-prior whole-chip
        seconds decomposed into fixed launch overhead (dispatch + pipe
        fill, paid at full price on any slice) plus a roof-bound
        remainder that scales as ``1/share`` — how knee curves price
        shares from calibrated tables without per-share measurements."""
        if not (0.0 < share <= 1.0):
            raise ValueError(f"share must be in (0, 1], got {share}")
        t_full = self(batch)
        if share >= 1.0:
            return t_full
        spec = getattr(self.prior, "spec", None)
        overhead = (spec.dispatch_overhead_s + spec.pipe_fill_s()
                    if spec is not None else 0.0)
        scalable = max(t_full - overhead, 0.0)
        return min(t_full, overhead) + scalable / share

    # ----------------------------------------------------------- persistence
    def to_json(self) -> str:
        doc = {"ewma_alpha": self.alpha,
               "entries": {k: {"seconds": self.table[k],
                               "observations": self.counts.get(k, 0)}
                           for k in sorted(self.table)}}
        if self.prior_strength > 0.0:
            # only when set: tables written with the default stay
            # byte-identical to pre-shrinkage builds
            doc["prior_strength"] = self.prior_strength
        return json.dumps(doc, indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(self.to_json())
        os.replace(tmp, path)

    @classmethod
    def from_json(cls, text: str,
                  prior: Optional[Callable[[Sequence], float]] = None,
                  prior_strength: Optional[float] = None,
                  ) -> "CalibratedCostModel":
        data = json.loads(text)
        strength = (data.get("prior_strength", 0.0)
                    if prior_strength is None else prior_strength)
        model = cls(prior=prior, ewma_alpha=data.get("ewma_alpha", 0.2),
                    prior_strength=strength)
        for key, entry in data.get("entries", {}).items():
            model.table[key] = float(entry["seconds"])
            model.counts[key] = int(entry.get("observations", 1))
        return model

    @classmethod
    def load(cls, path: str,
             prior: Optional[Callable[[Sequence], float]] = None,
             prior_strength: Optional[float] = None,
             ) -> "CalibratedCostModel":
        with open(path) as fh:
            return cls.from_json(fh.read(), prior=prior,
                                 prior_strength=prior_strength)


class FleetCalibrator:
    """Per-replica ``CalibratedCostModel`` tables behind ONE dispatch tap.

    On a heterogeneous fleet one fleet-wide table is wrong by
    construction: the same (bucket, pow2-R) dispatch takes 2x longer on a
    half-speed chip, so blending replicas' observations fits a cost no
    replica actually has. This keeps one table per ``replica_id`` —
    ``observe`` (the scheduler ``on_dispatch`` signature, replica identity
    included) routes each measurement to its replica's table, and
    ``for_replica`` hands the fleet simulator a per-replica pricing model
    routers consult, so a calibrated fleet converges toward each chip's
    MEASURED costs even when the shared prior is wrong for it.

    Tables are created lazily on first sight of a replica id, which makes
    autoscaled fleets (fresh replica ids mid-run) work unchanged; the
    JSON round-trip (``save``/``load``) persists every table keyed by
    replica id, counts included, same warm-resume contract as
    ``CalibratedCostModel``.
    """

    def __init__(
        self,
        prior: Optional[Callable[[Sequence], float]] = None,
        ewma_alpha: float = 0.2,
    ):
        self.prior = prior
        self.alpha = ewma_alpha
        self.models: Dict[int, CalibratedCostModel] = {}

    @staticmethod
    def _rid(replica_id: Optional[int]) -> int:
        # solo schedulers tap with replica_id=None; file one table for them
        return -1 if replica_id is None else int(replica_id)

    def for_replica(self, replica_id: Optional[int]) -> CalibratedCostModel:
        rid = self._rid(replica_id)
        model = self.models.get(rid)
        if model is None:
            model = CalibratedCostModel(prior=self.prior,
                                        ewma_alpha=self.alpha)
            self.models[rid] = model
        return model

    def observe(self, batch: Sequence, seconds: float,
                replica_id: Optional[int] = None) -> None:
        """Scheduler ``on_dispatch`` tap: fold one measured dispatch into
        the dispatching replica's table."""
        self.for_replica(replica_id).observe(batch, seconds)

    def coverage(self, batch: Sequence, replica_id: Optional[int]) -> bool:
        model = self.models.get(self._rid(replica_id))
        return model is not None and model.coverage(batch)

    @property
    def observations(self) -> int:
        return sum(sum(m.counts.values()) for m in self.models.values())

    # ----------------------------------------------------------- persistence
    def to_json(self) -> str:
        return json.dumps(
            {"ewma_alpha": self.alpha,
             "replicas": {str(rid): json.loads(m.to_json())
                          for rid, m in sorted(self.models.items())}},
            indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(self.to_json())
        os.replace(tmp, path)

    @classmethod
    def from_json(cls, text: str,
                  prior: Optional[Callable[[Sequence], float]] = None,
                  ) -> "FleetCalibrator":
        data = json.loads(text)
        calib = cls(prior=prior, ewma_alpha=data.get("ewma_alpha", 0.2))
        for rid, doc in data.get("replicas", {}).items():
            calib.models[int(rid)] = CalibratedCostModel.from_json(
                json.dumps(doc), prior=prior)
        return calib

    @classmethod
    def load(cls, path: str,
             prior: Optional[Callable[[Sequence], float]] = None,
             ) -> "FleetCalibrator":
        with open(path) as fh:
            return cls.from_json(fh.read(), prior=prior)


class ColdStartCostModel:
    """Compile-cache cold-start accounting over a base cost model.

    The live scheduler's ``SuperKernelCache`` jit-compiles one super-kernel
    variant per (bucket, pow2-R); the FIRST dispatch that hits a variant
    pays XLA compilation, later ones reuse it. This wrapper models that:
    the first dispatch per ``batch_key`` adds ``compile_s``; the key is
    then *warm* and subsequent dispatches pay only the base cost.

    Each fleet replica wraps the (shared, stateless) base model in its OWN
    instance — compile caches are per-process state, so a fleet of N
    replicas pays up to N compiles per variant. That is exactly what makes
    routing interesting: tenant-affinity keeps tenants on replicas that
    already compiled their shapes, pure load balancing spreads every shape
    onto every replica and pays the full N-fold compile bill.

    Every dispatch is also logged as ``(virtual time, was_cold)`` so fleet
    metrics can report the cold-start fraction and its decay over the
    trace (warm-up curve).
    """

    def __init__(
        self,
        base: Optional[Callable[[Sequence], float]] = None,
        compile_s: float = 1e-3,
        clock=None,
    ):
        if compile_s < 0.0:
            raise ValueError("compile_s must be >= 0")
        self.base = base or RooflineCostModel()
        self.compile_s = float(compile_s)
        self.clock = clock
        self._warm: set = set()
        self._warm_buckets: set = set()
        self.dispatch_times = array("d")
        self.dispatch_cold = array("b")

    def __call__(self, batch: Sequence) -> float:
        key = batch_key(batch)
        cold = key not in self._warm
        if cold:
            self._warm.add(key)
            self._warm_buckets.add(getattr(batch[0], "bucket", None))
        self.dispatch_times.append(
            self.clock.now() if self.clock is not None else 0.0)
        self.dispatch_cold.append(1 if cold else 0)
        return self.base(batch) + (self.compile_s if cold else 0.0)

    # ------------------------------------------------------- routing signals
    def bucket_warm(self, bucket) -> bool:
        """True once ANY variant of this bucket has compiled here — the
        affinity signal routers use (R varies dispatch to dispatch, the
        bucket is the stable part of the key)."""
        return bucket in self._warm_buckets

    def estimate(self, batch: Sequence) -> float:
        """Price a batch WITHOUT mutating the warm set (what a router asks
        when weighing candidate replicas)."""
        cold = getattr(batch[0], "bucket", None) not in self._warm_buckets
        return self.base(batch) + (self.compile_s if cold else 0.0)

    def item_s(self, w) -> float:
        """Marginal cost of joining an already-pending batch of ``w``'s
        bucket: no compile term — the forming batch pays any compile once
        for everyone riding it."""
        base_item = getattr(self.base, "item_s", None)
        if base_item is not None:
            return base_item(w)
        return self.base((w,))

    # ------------------------------------------------------------- reporting
    @property
    def cold_dispatches(self) -> int:
        return int(sum(self.dispatch_cold))

    @property
    def dispatches(self) -> int:
        return len(self.dispatch_cold)


def estimate_capacity_hz(
    mix: Sequence,
    model: Callable[[Sequence], float],
    merge_size: int = 32,
) -> float:
    """Sustainable arrivals/s for a tenant mix under a cost model.

    Prices one representative dispatch ROUND — ``merge_size`` arrivals
    split by weight into one merged batch PER BUCKET, matching what the
    scheduler can actually co-dispatch (specs in different buckets never
    share a super-kernel, so each bucket pays its own per-dispatch
    overheads) — and converts to a service rate. This is the anchor load
    sweeps use to express offered load as a fraction of capacity (rho)
    instead of an absolute rate that only fits one mix.
    """
    from repro.sim.simulator import SimWorkload  # local: avoid import cycle

    total_w = sum(s.weight for s in mix)
    by_bucket: Dict = {}
    items = 0
    for spec in mix:
        n = max(1, round(merge_size * spec.weight / total_w))
        by_bucket.setdefault(spec.bucket, []).extend(
            SimWorkload(spec, spec.cost) for _ in range(n))
        items += n
    round_s = sum(model(batch) for batch in by_bucket.values())
    return items / round_s
