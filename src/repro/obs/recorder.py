"""Columnar flight recorder: typed events from every decision point.

``FlightRecorder`` is the zero-overhead-when-off event store behind
``ObservabilitySpec``: when no recorder is attached the hot paths pay a
single ``is None`` test (the solo chunked loop hoists even that out);
when attached, events land in per-replica ``ReplicaShard``s as columnar
``array`` appends with interned bucket labels — the same batched-absorb
discipline as ``MetricsAccumulator.add_batch``, so recorder-on runs stay
within a small constant factor of recorder-off ones.

Event families and where they are emitted:

    arrival / admission   ``ReplicaPump.submit`` (and the solo chunked
                          intake), one row per arrival with the admitted
                          flag — rejections are the admission-control
                          story made visible
    dispatch span         the scheduler's ``on_dispatch`` tap (see
                          ``dispatch_tap``): completion instant, modeled
                          seconds, bucket, batch size R, cold/warm from
                          the replica's ``ColdStartCostModel``, strategy
    request span          per item of a dispatch (``per_request=True``):
                          arrival -> completion with tenant, SLO, bucket
    route decision        ``FleetSimulator.run``: chosen replica plus the
                          per-replica price vector that justified it
                          (``route_price_vector``)
    scale event           the autoscale timeline, verbatim

Determinism: shards are keyed by replica id and filled in each replica's
own event order, fleet-level routes in global arrival order — both pure
functions of the seeded trace. The sharded fleet (``repro.sim.shard``)
ships each shard's columns back from its worker process and replays
route rows in arrival order, so ``workers=K`` produces byte-identical
exports to ``workers=1``. Read paths: ``repro.obs.trace_export`` (Chrome
``trace_event`` JSON, Perfetto-loadable) and ``repro.obs.telemetry``
(windowed time series).
"""

from __future__ import annotations

from array import array
from typing import Dict, Hashable, List, Optional, Sequence, Tuple


def bucket_label(bucket) -> str:
    """Compact human-readable label for a shape bucket (interned once
    per distinct bucket, so this can afford to be pretty)."""
    op = getattr(bucket, "op", None)
    if op is not None and hasattr(bucket, "M"):
        return (f"{op} {bucket.M}x{bucket.K}x{bucket.N} "
                f"{getattr(bucket, 'dtype', '')}".rstrip())
    if isinstance(bucket, tuple):
        return "/".join(str(p) for p in bucket)
    return str(bucket)


class ReplicaShard:
    """One replica's event columns (the per-replica unit of determinism:
    identical between single-process and sharded fleet execution)."""

    def __init__(self, replica_id: int, per_request: bool = True):
        self.replica_id = replica_id
        self.per_request = per_request
        self.spec_name: Optional[str] = None
        self.strategy: Optional[str] = None
        self._bucket_index: Dict[Hashable, int] = {}
        self._bucket_labels: List[str] = []
        # arrivals (one row per routed arrival, admitted or not); reason
        # codes mirror scheduler.admit_reason: 0 admit, 1 oversubscribed
        # admit, 2 pending-cap reject, 3 infeasible-deadline reject
        self._arr_t = array("d")
        self._arr_tenant = array("l")
        self._arr_bucket = array("l")
        self._arr_admitted = array("b")
        self._arr_reason = array("b")
        # preemptions (one row per ahead-of-window force-dispatch)
        self._pre_t = array("d")
        self._pre_tenant = array("l")
        self._pre_bucket = array("l")
        self._pre_est = array("d")
        self._pre_victims = array("l")
        # dispatch spans (one row per super-dispatch)
        self._dsp_t0 = array("d")
        self._dsp_dur = array("d")
        self._dsp_bucket = array("l")
        self._dsp_size = array("l")
        self._dsp_cold = array("b")
        # request spans (one row per completed item; per_request only)
        self._req_t0 = array("d")
        self._req_t1 = array("d")
        self._req_tenant = array("l")
        self._req_slo = array("d")
        self._req_bucket = array("l")

    # -------------------------------------------------------------- intern
    def _intern(self, bucket) -> int:
        idx = self._bucket_index
        bi = idx.get(bucket)
        if bi is None:
            bi = len(self._bucket_labels)
            idx[bucket] = bi
            self._bucket_labels.append(bucket_label(bucket))
        return bi

    # ------------------------------------------------------------- record
    def record_arrival(self, t_s: float, tenant_id: int, bucket,
                       admitted: bool, reason: int = 0) -> None:
        self._arr_t.append(t_s)
        self._arr_tenant.append(tenant_id)
        self._arr_bucket.append(self._intern(bucket))
        self._arr_admitted.append(1 if admitted else 0)
        self._arr_reason.append(reason)

    def record_preempt(self, t_s: float, tenant_id: int, bucket,
                       est_s: float, victims: int) -> None:
        """One EDF preemption: an unripe bucket force-dispatched because
        waiting out its window would miss its deadline, jumping ahead of
        ``victims`` ripe cohorts at priced cost ``est_s``."""
        self._pre_t.append(t_s)
        self._pre_tenant.append(tenant_id)
        self._pre_bucket.append(self._intern(bucket))
        self._pre_est.append(est_s)
        self._pre_victims.append(victims)

    def record_dispatch(self, t1_s: float, dur_s: float, batch: Sequence,
                        cold: bool) -> None:
        """Absorb one super-dispatch: span row plus (optionally) one
        request-span row per item, column-at-a-time like
        ``MetricsAccumulator.add_batch``."""
        index = self._bucket_index
        try:
            bis = [index[w.bucket] for w in batch]
        except KeyError:
            bis = [self._intern(w.bucket) for w in batch]
        self._dsp_t0.append(t1_s - dur_s)
        self._dsp_dur.append(dur_s)
        self._dsp_bucket.append(bis[0])
        self._dsp_size.append(len(batch))
        self._dsp_cold.append(1 if cold else 0)
        if self.per_request:
            self._req_t0.extend([w.arrival_time for w in batch])
            self._req_t1.extend([w.completion_time for w in batch])
            self._req_tenant.extend([w.tenant_id for w in batch])
            self._req_slo.extend([w.slo_s for w in batch])
            self._req_bucket.extend(bis)

    # -------------------------------------------------------------- sizing
    @property
    def n_arrivals(self) -> int:
        return len(self._arr_t)

    @property
    def n_dispatches(self) -> int:
        return len(self._dsp_t0)

    @property
    def n_requests(self) -> int:
        return len(self._req_t0)

    @property
    def n_preemptions(self) -> int:
        return len(self._pre_t)

    # ---------------------------------------------------- worker transport
    _COLUMNS = ("_arr_t", "_arr_tenant", "_arr_bucket", "_arr_admitted",
                "_arr_reason",
                "_dsp_t0", "_dsp_dur", "_dsp_bucket", "_dsp_size",
                "_dsp_cold", "_req_t0", "_req_t1", "_req_tenant",
                "_req_slo", "_req_bucket",
                "_pre_t", "_pre_tenant", "_pre_bucket", "_pre_est",
                "_pre_victims")

    def payload(self) -> Dict:
        """Compact picklable form (arrays + label table) for shipping a
        shard back from a forked fleet worker."""
        out = {c: getattr(self, c) for c in self._COLUMNS}
        out.update(replica_id=self.replica_id, per_request=self.per_request,
                   spec_name=self.spec_name, strategy=self.strategy,
                   bucket_labels=self._bucket_labels)
        return out

    @classmethod
    def from_payload(cls, data: Dict) -> "ReplicaShard":
        """Rebuild from ``payload()``. The bucket INDEX is not restored
        (the original keys live in the worker) — a rebuilt shard is
        read-only for export/telemetry, not for further recording."""
        shard = cls(data["replica_id"], per_request=data["per_request"])
        shard.spec_name = data["spec_name"]
        shard.strategy = data["strategy"]
        shard._bucket_labels = list(data["bucket_labels"])
        for c in cls._COLUMNS:
            setattr(shard, c, data[c])
        return shard


class FlightRecorder:
    """Fleet-wide event store: per-replica shards plus the fleet-level
    route/scale timelines no single replica can see."""

    def __init__(self, per_request: bool = True):
        self.per_request = per_request
        self.shards: Dict[int, ReplicaShard] = {}
        self.router_name: Optional[str] = None
        # route decisions: one row per arrival, price vector flattened
        self._rt_t = array("d")
        self._rt_tenant = array("l")
        self._rt_chosen = array("l")
        self._rt_n = array("l")           # prices per row
        self._rt_price = array("d")       # flat, row-major
        self._rt_price_rid = array("l")   # replica id per flat price
        self.scale_events: List[Dict] = []
        self.partition_events: List[Dict] = []

    def shard(self, replica_id: int = 0) -> ReplicaShard:
        s = self.shards.get(replica_id)
        if s is None:
            s = ReplicaShard(replica_id, per_request=self.per_request)
            self.shards[replica_id] = s
        return s

    def record_route(self, t_s: float, tenant_id: int, chosen_rid: int,
                     price_rids: Sequence[int] = (),
                     prices: Sequence[float] = ()) -> None:
        self._rt_t.append(t_s)
        self._rt_tenant.append(tenant_id)
        self._rt_chosen.append(chosen_rid)
        self._rt_n.append(len(prices))
        if prices:
            self._rt_price.extend(prices)
            self._rt_price_rid.extend(price_rids)

    def record_scale_events(self, events: Sequence) -> None:
        self.scale_events = [
            e.to_dict() if hasattr(e, "to_dict") else dict(e)
            for e in events]

    def record_partition_events(self, events: Sequence) -> None:
        """Partition assign/replan timeline (repro.partition): copied to
        plain dicts like scale events, exported as instants on the
        control track."""
        self.partition_events = [
            e.to_dict() if hasattr(e, "to_dict") else dict(e)
            for e in events]

    @property
    def n_routes(self) -> int:
        return len(self._rt_t)

    def total_events(self) -> int:
        """Every recorded row, across shards and the fleet level."""
        n = self.n_routes + len(self.scale_events) \
            + len(self.partition_events)
        for s in self.shards.values():
            n += (s.n_arrivals + s.n_dispatches + s.n_requests
                  + s.n_preemptions)
        return n


def dispatch_tap(shard: ReplicaShard, model=None, prev=None):
    """Build an ``on_dispatch`` tap recording each super-dispatch into
    ``shard``, composing over any existing tap (``prev`` — calibration
    keeps working underneath the recorder).

    ``model`` is the replica's cost model: when it exposes
    ``dispatch_cold`` (``ColdStartCostModel``), the last entry at tap
    time says whether the dispatch just priced was a cold compile. The
    tap runs AFTER completion stamping (see ``scheduler._dispatch``), so
    ``batch[0].completion_time`` is the exact dispatch-end instant for
    both virtual and wall clocks.
    """
    cold_flags = getattr(model, "dispatch_cold", None)
    record = shard.record_dispatch

    def tap(batch, seconds, replica_id):
        if prev is not None:
            prev(batch, seconds, replica_id)
        cold = bool(cold_flags[-1]) if cold_flags else False
        record(batch[0].completion_time, seconds, batch, cold)

    return tap


def route_price_vector(router, spec, replicas: Sequence,
                       now: float) -> Tuple[List[int], List[float]]:
    """The per-replica price vector a router's decision was based on,
    recomputed from the same (idempotent) pump signals the router read:
    estimated-seconds for ``least_cost``, occupancy for ``jsq`` and
    ``affinity``, nothing for state-oblivious ``round_robin`` (which is
    also what keeps sharded round-robin runs byte-identical)."""
    name = getattr(router, "name", "")
    if name == "least_cost":
        return ([p.replica_id for p in replicas],
                [p.backlog_s(now) + p.estimate_item_s(spec)
                 for p in replicas])
    if name in ("jsq", "affinity"):
        return ([p.replica_id for p in replicas],
                [float(p.queue_depth(now)) for p in replicas])
    return [], []
