"""RWKV-6 (Finch) WKV recurrence as a chunked Pallas scan.

Recurrence per head (state S in R^{N x V_dim}, data-dependent decay w_t):

    S_t = diag(exp(-exp(w_t))) S_{t-1} + k_t^T (v_t)          [outer product]
    o_t = (r_t S_t') with bonus:  o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

i.e. the current token's contribution is weighted by the "bonus" u instead
of the decay. TPU adaptation: the grid's time axis executes sequentially,
so the (N, V) state lives in VMEM scratch across chunk steps; inside a
chunk we run a fori_loop over timesteps with rank-1 updates (VPU work) —
the GEMM-heavy r/k/v/g projections stay OUTSIDE this kernel where the
space-time scheduler batches them across tenants.

Grid: (BH, T/chunk). Inputs are laid out (BH, T, N) per tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, chunk: int):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0]  # (N,)

    def step(i, state):
        r = r_ref[0, i]      # (N,)
        kk = k_ref[0, i]     # (N,)
        vv = v_ref[0, i]     # (V,)
        w = w_ref[0, i]      # (N,) decay logits
        decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))
        kv = jnp.outer(kk, vv).astype(jnp.float32)          # (N, V)
        out = (r[None, :].astype(jnp.float32) @ (state + u[:, None] * kv))[0]
        o_ref[0, i] = out.astype(o_ref.dtype)
        return decay[:, None] * state + kv

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """WKV6 linear-attention scan.

    Args:
        r, k, w: (BH, T, N) receptance / key / decay-logit per head.
        v: (BH, T, V) values.
        u: (BH, N) per-head bonus.
    Returns:
        (BH, T, V) outputs.
    """
    BH, T, N = r.shape
    V = v.shape[-1]
    chunk_ = min(chunk, T)
    Tp = pl.cdiv(T, chunk_) * chunk_
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0))
        r, k, v, w = (jnp.pad(a, pad) for a in (r, k, v, w))

    grid = (BH, Tp // chunk_)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk_)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk_, N), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, chunk_, N), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, chunk_, V), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, chunk_, N), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, N), lambda bh, tb: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk_, V), lambda bh, tb: (bh, tb, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tp, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out[:, :T, :]
