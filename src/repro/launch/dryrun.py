import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on 512 placeholder host devices, print memory/cost analysis,
and write the roofline record.

Usage:
    python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh pod1
    python -m repro.launch.dryrun --all --mesh pod1 --out experiments/dryrun
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.config import INPUT_SHAPES, get_config, get_shape, list_configs
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, eligible


def run_one(arch: str, shape_name: str, mesh_name: str, out_dir=None, verbose=True,
            remat: str = "block", policy: str = "fsdp", tp_acts: str = "auto",
            tenants: int = 1, microbatch: int = 1):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = eligible(cfg, shape_name)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "remat": remat,
              "policy": policy, "tp_acts": tp_acts, "tenants": tenants,
              "microbatch": microbatch}
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        _write(record, out_dir)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({why})")
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = mesh.size
    t0 = time.time()
    try:
        step_fn, args, in_specs, out_specs = build_step(
            cfg, shape_name, mesh, remat=remat, policy=policy, tenants=tenants,
            microbatch=microbatch)
        from repro.distributed.constraints import use_mesh
        from repro.distributed.sharding import to_shardings
        in_sh = to_shardings(in_specs, mesh)
        out_sh = to_shardings(out_specs, mesh)
        # measured (EXPERIMENTS.md §Perf pair 3 iter 5): disabling TP
        # activation constraints interacts badly with per-block remat
        # (weights re-gathered every recompute, 8x collective regression),
        # so "auto" resolves to ON for every shape kind.
        tp_on = tp_acts != "off"
        with mesh, use_mesh(mesh, tp_activations=tp_on):
            jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            raw_cost = lowered.cost_analysis() or {}

        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        coll = rl.collective_bytes(hlo_text)

        flops = float(raw_cost.get("flops", 0.0))
        bytes_accessed = float(raw_cost.get("bytes accessed", 0.0))
        ana = rl.analytic_cost(cfg, shape, remat=(remat == "block"))
        ana_coll = rl.analytic_collectives(
            cfg, shape,
            # tenant-stacked serving forces tp weights internally
            policy="tp" if tenants > 1 else policy,
            tp_acts=tp_on,
            pods=2 if mesh_name == "pod2" else 1,
        )
        report = rl.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=flops, hlo_bytes=bytes_accessed, coll_bytes=coll,
            model_flops=rl.model_flops_for(cfg, shape),
            analytic_flops=ana["flops"], analytic_bytes=ana["hbm_bytes"],
            analytic_coll=ana_coll,
        )
        record.update(report.to_dict())
        record["status"] = "ok"
        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(t_compile, 2)
        record["memory_analysis"] = _mem_dict(mem, chips)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
            print(f"  memory_analysis: {record['memory_analysis']}")
            print(f"  cost_analysis: flops={flops:.3e} bytes={bytes_accessed:.3e}")
            print(f"  collectives: { {k: v for k, v in coll.items() if v} }")
            print(f"  roofline: compute={report.t_compute:.3e}s "
                  f"memory={report.t_memory:.3e}s collective={report.t_collective:.3e}s "
                  f"-> {report.bottleneck}-bound; useful-FLOPs ratio "
                  f"{report.useful_flops_ratio:.3f}")
    except Exception as e:  # noqa: BLE001 — record the failure, it's a bug to fix
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: ERROR {record['error']}")
    _write(record, out_dir)
    return record


def _mem_dict(mem, chips):
    if mem is None:
        return {}
    keys = [
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
    ]
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    total = out.get("argument_size_in_bytes", 0) + out.get("temp_size_in_bytes", 0) \
        + out.get("output_size_in_bytes", 0)
    # memory_analysis reports per-device sizes for SPMD executables
    out["approx_total_per_device_bytes"] = total
    out["approx_total_per_device_gib"] = round(total / 2**30, 3)
    return out


def _write(record, out_dir):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if record.get("policy", "fsdp") == "fsdp" else f"__{record['policy']}"
    if record.get("tenants", 1) > 1:
        suffix += f"__R{record['tenants']}"
    if record.get("microbatch", 1) > 1:
        suffix += f"__mb{record['microbatch']}"
    path = os.path.join(
        out_dir,
        f"{record['arch']}__{record['shape']}__{record['mesh']}{suffix}.json",
    )
    record = dict(record)
    record.pop("traceback", None)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--remat", default="block", choices=["block", "none"])
    ap.add_argument("--policy", default="fsdp",
                    choices=["fsdp", "tp", "replicate", "auto"])
    ap.add_argument("--tp-acts", default="auto", choices=["auto", "on", "off"],
                    help="tensor-parallel activation constraints (auto: off for train, on for serve)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="R>1: tenant-stacked multi-tenant serve step (decode shapes)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="k>1: gradient-accumulation microbatching (train shapes)")
    args = ap.parse_args()

    if args.all:
        archs = list_configs()
        shapes = list(INPUT_SHAPES)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        archs, shapes = [args.arch], [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            rec = run_one(arch, shape, args.mesh, out_dir=args.out,
                          remat=args.remat, policy=args.policy,
                          tp_acts=args.tp_acts, tenants=args.tenants,
                          microbatch=args.microbatch)
            if rec["status"] == "error":
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
