"""Policy sweep on the trace-driven simulator (repro.sim).

DEPRECATION SHIM: this script is now a thin caller of the declarative
``repro.api`` layer — every cell is a ``SystemSpec`` built once and
``replace()``d per grid point. Prefer the unified CLI for new work:

    PYTHONPATH=src python -m repro sweep --spec examples/specs/paper_mix.json \
        --axis cost_model.strategy=time_only,space_only,space_time

The argparse surface below is kept for the committed baselines and CI
gates, which it reproduces byte-identically.

Four sections, all driven by the SAME seeded arrival process through the
real scheduler on a virtual clock — deterministic per seed, millions of
events in seconds on CPU:

  1. strategies — one saturating trace priced under each multiplexing
     strategy's roofline cost model (time_only / space_only / space_time /
     exclusive). Reproduces the paper's qualitative throughput ordering
     space_time > space_only > time_only.
  2. policies — fixed vs slo_adaptive batching window at moderate load:
     SLO attainment and goodput (adaptive must not be worse).
  3. grid — batching_window x max_superkernel_size sweep: the space-time
     trade-off surface (latency vs merge opportunity).
  4. interference (--interference) — counterfactual pairwise co-run
     matrix: mean-latency slowdown of tenant i when tenant j shares the
     device.

``--check`` turns the two headline orderings into hard assertions (CI
gate); ``--json`` writes a BENCH_sim_sweep.json-style document.

    PYTHONPATH=src python benchmarks/sim_sweep.py --events 1000000
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional

from repro.api import (
    SchedulerSpec,
    SystemSpec,
    WorkloadSpec,
    build_mix,
    resolve_rate_hz,
)
from repro.sim import (
    STRATEGIES,
    PoissonTrace,
    RooflineCostModel,
    SimMetrics,
    Simulator,
    TenantSpec,
    interference_matrix,
    to_bench_json,
)


def run(events: int = 200_000, tenants: int = 8, seed: int = 0,
        process: str = "poisson", mix_name: str = "sgemm", rho: float = 0.7,
        check: bool = False, json_path: Optional[str] = None,
        with_interference: bool = False, csv_rows=None) -> Dict[str, SimMetrics]:
    t_wall = time.perf_counter()
    # the base spec every cell derives from; rho=1.0 makes resolve_rate_hz
    # report the mix's raw space_time capacity (the sweep's load anchor)
    base = SystemSpec(
        workload=WorkloadSpec(mix=mix_name, tenants=tenants, process=process,
                              events=events, seed=seed, rho=1.0),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32),
    )
    mix = build_mix(base.workload)
    sections: Dict[str, SimMetrics] = {}
    failures: List[str] = []

    # ---------------------------------------------------------- 1. strategies
    capacity_hz = resolve_rate_hz(base, mix)
    sat_hz = 2.0 * capacity_hz  # saturate even the fastest strategy
    print(f"\n=== sim_sweep: {events} events/section, mix={mix_name}, "
          f"process={process}, seed={seed} ===")
    print(f"estimated space_time capacity ~{capacity_hz:,.0f} arrivals/s; "
          f"strategy section driven at 2x (saturating)")
    print(f"\n--- strategies (same trace, per-strategy roofline cost) ---")
    print(f"{'strategy':11s} {'tput cost/s':>12s} {'p95 ms':>9s} "
          f"{'attain':>7s} {'util':>6s} {'dispatches':>10s}")
    tput: Dict[str, float] = {}
    for strat in STRATEGIES:
        m = base.replace(**{"workload.rate_hz": sat_hz,
                            "cost_model.strategy": strat}).build().run_metrics()
        s = m.summary()
        tput[strat] = s["throughput_cost_per_s"]
        sections[f"strategy_{strat}"] = m
        print(f"{strat:11s} {s['throughput_cost_per_s']:12.4g} "
              f"{s['p95_s']*1e3:9.3f} {s['slo_attainment']:7.3f} "
              f"{s['utilization']:6.3f} {s['dispatches']:10.0f}")
    print(f"space_time/space_only: {tput['space_time']/tput['space_only']:.2f}x   "
          f"space_time/time_only: {tput['space_time']/tput['time_only']:.2f}x   "
          f"(paper: 3.23x / 7.73x)")
    if not tput["space_time"] > tput["space_only"] > tput["time_only"]:
        failures.append(
            f"throughput ordering violated: st={tput['space_time']:.4g} "
            f"so={tput['space_only']:.4g} to={tput['time_only']:.4g}")

    # ------------------------------------------------------------ 2. policies
    pol_hz = rho * capacity_hz
    pol_events = max(events // 2, 1000)
    # a window wide enough to threaten the tightest SLO tier, so the
    # adaptive policy has a violation budget to win back
    pol_window = max(0.5 * min(s.slo_s for s in mix), 0.002)
    print(f"\n--- batching policies @ rho={rho:.2f} "
          f"(window {pol_window*1e3:.1f}ms, {pol_events} events) ---")
    attain: Dict[str, float] = {}
    for policy in ("fixed", "slo_adaptive"):
        m = base.replace(**{
            "workload.events": pol_events,
            "workload.seed": seed + 1,
            "workload.rate_hz": pol_hz,
            "scheduler.batching_window_s": pol_window,
            "scheduler.batching_policy": policy,
            "scheduler.max_superkernel_size": 64,
        }).build().run_metrics()
        s = m.summary()
        attain[policy] = s["slo_attainment"]
        sections[f"policy_{policy}"] = m
        print(f"{policy:12s}: attainment={s['slo_attainment']:.4f} "
              f"p95={s['p95_s']*1e3:8.3f}ms "
              f"goodput={s['goodput_cost_per_s']:.4g} "
              f"dispatches={s['dispatches']:.0f}")
    print(f"adaptive >= fixed attainment: "
          f"{attain['slo_adaptive'] >= attain['fixed']}")
    if attain["slo_adaptive"] < attain["fixed"]:
        failures.append(
            f"SLO attainment ordering violated: adaptive={attain['slo_adaptive']:.4f} "
            f"< fixed={attain['fixed']:.4f}")

    # ---------------------------------------------------------------- 3. grid
    grid_events = max(events // 20, 1000)
    print(f"\n--- window x size grid @ rho={rho:.2f} "
          f"({grid_events} events/cell) ---")
    print(f"{'window ms':>9s} {'size':>5s} {'p95 ms':>9s} {'attain':>7s} "
          f"{'goodput':>10s} {'dispatches':>10s}")
    for window_s in (0.0005, 0.001, 0.002, 0.004):
        for size in (8, 32, 128):
            m = base.replace(**{
                "workload.events": grid_events,
                "workload.seed": seed + 2,
                "workload.rate_hz": pol_hz,
                "scheduler.batching_window_s": window_s,
                "scheduler.max_superkernel_size": size,
            }).build().run_metrics()
            s = m.summary()
            sections[f"grid_w{window_s*1e3:g}ms_s{size}"] = m
            print(f"{window_s*1e3:9.1f} {size:5d} {s['p95_s']*1e3:9.3f} "
                  f"{s['slo_attainment']:7.3f} {s['goodput_cost_per_s']:10.4g} "
                  f"{s['dispatches']:10.0f}")

    # -------------------------------------------------------- 4. interference
    if with_interference:
        # one spec per tenant (serving mixes carry prefill+decode streams
        # per tenant; the matrix is keyed per tenant) — heaviest stream wins.
        # Subsets of a mix are below the declarative spec's granularity, so
        # this section drives the sim primitives directly.
        by_tenant: Dict[int, TenantSpec] = {}
        for s in mix:
            if s.tenant_id < min(4, tenants):
                cur = by_tenant.get(s.tenant_id)
                if cur is None or s.weight > cur.weight:
                    by_tenant[s.tenant_id] = s
        sub = [by_tenant[t] for t in sorted(by_tenant)]
        pair_events = max(events // 50, 500)
        sched_cfg = base.scheduler.to_schedule_config()
        st_model = RooflineCostModel(strategy="space_time")

        def run_subset(specs):
            trace = PoissonTrace(specs, rate_hz=pol_hz * len(specs) / len(mix),
                                 events=pair_events, seed=seed + 3)
            return Simulator(schedule=sched_cfg, cost_model=st_model).run(trace)

        M = interference_matrix(run_subset, sub)
        width = max(len(s.name) for s in sub)
        print(f"\n--- tenant interference (mean-latency slowdown, "
              f"{pair_events} events/pair) ---")
        print(" " * (width + 1) + " ".join(f"+{s.name:<{width}s}" for s in sub))
        for i, s in enumerate(sub):
            print(f"{s.name:<{width}s}  " +
                  " ".join(f"{M[i, j]:<{width}.2f} " for j in range(len(sub))))

    # ---------------------------------------------------------------- outputs
    if csv_rows is not None:
        for name, m in sections.items():
            csv_rows.extend(m.bench_rows(f"sim_sweep/{name}"))
    if json_path:
        with open(json_path, "w") as fh:
            fh.write(to_bench_json(
                "sim_sweep", sections,
                extra={"events": events, "seed": seed, "process": process,
                       "mix": mix_name, "rho": rho,
                       "capacity_hz": capacity_hz}))
        print(f"\nwrote {json_path}")

    print(f"\ntotal wall time: {time.perf_counter() - t_wall:.1f}s")
    if failures:
        for f in failures:
            print(f"CHECK FAILED: {f}", file=sys.stderr)
        if check:
            sys.exit(1)
    elif check:
        print("checks passed: space_time > space_only > time_only throughput; "
              "adaptive >= fixed SLO attainment")
    return sections


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--events", type=int, default=200_000,
                    help="arrivals for the strategy section (others scale down)")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--process", default="poisson",
                    choices=("poisson", "mmpp", "diurnal", "flash"))
    ap.add_argument("--mix", default="sgemm", choices=("sgemm", "serving"))
    ap.add_argument("--rho", type=float, default=0.7,
                    help="offered load as a fraction of space_time capacity")
    ap.add_argument("--json", default=None, help="write BENCH-style JSON here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless headline orderings hold")
    ap.add_argument("--interference", action="store_true",
                    help="include the pairwise tenant-interference matrix")
    args = ap.parse_args()
    print("note: sim_sweep.py is a shim over the unified CLI; prefer "
          "`python -m repro sweep` (see README)", file=sys.stderr)
    run(events=args.events, tenants=args.tenants, seed=args.seed,
        process=args.process, mix_name=args.mix, rho=args.rho,
        check=args.check, json_path=args.json,
        with_interference=args.interference)


if __name__ == "__main__":
    main()
