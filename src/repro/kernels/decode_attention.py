"""One-token GQA decode attention against a KV cache.

serve_step's hot kernel: each sequence has ONE new query token attending to
a ``cache_len`` KV history. This is memory-bound (roofline: ~2*S*Hkv*D bytes
streamed per token), so the kernel's job is to stream K/V through VMEM in
large blocks while the q_per_kv query heads of each KV head ride along as
the GEMM M dimension (MXU rows).

Grid: (B * Hkv, S/bkv); q rows = q_per_kv heads; online softmax scratch as
in flash_attention. Variable ``lengths`` masks the tail of each sequence's
cache (continuous batching: every row may have a different live length).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BKV = 512
NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, bkv: int,
):
    jk = pl.program_id(1)
    b_hkv = pl.program_id(0)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (q_per_kv, D)
    k = k_ref[0]  # (bkv, D)
    v = v_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (q_per_kv, bkv)

    kv_pos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    live = len_ref[b_hkv]  # this sequence's cache length
    mask = kv_pos < live
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(jk == pl.num_programs(1) - 1)
    def _store():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bkv", "interpret"))
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    bkv: int = DEFAULT_BKV,
    interpret: bool = False,
) -> jax.Array:
    """Single-token decode attention.

    Args:
        q: (B, Hq, D) new-token queries.
        k_cache: (B, Hkv, S, D) key cache (S = allocated cache length).
        v_cache: (B, Hkv, S, D) value cache.
        lengths: (B,) int32 live length per sequence (<= S).
    Returns:
        (B, Hq, D)
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    q_per_kv = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    bkv_ = min(bkv, S)
    Sp = pl.cdiv(S, bkv_) * bkv_
    if Sp != S:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))

    qf = q.reshape(B * Hkv, q_per_kv, D)
    kf = k_cache.reshape(B * Hkv, Sp, D)
    vf = v_cache.reshape(B * Hkv, Sp, D)
    # per-(b,hkv) live length, scalar-prefetched for masking
    lens = jnp.repeat(lengths.astype(jnp.int32), Hkv)

    grid = (B * Hkv, Sp // bkv_)
    kernel = functools.partial(_decode_kernel, scale=scale, bkv=bkv_)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, q_per_kv, D), lambda bh, jk, lens: (bh, 0, 0)),
                pl.BlockSpec((1, bkv_, D), lambda bh, jk, lens: (bh, jk, 0)),
                pl.BlockSpec((1, bkv_, D), lambda bh, jk, lens: (bh, jk, 0)),
            ],
            out_specs=pl.BlockSpec((1, q_per_kv, D), lambda bh, jk, lens: (bh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((q_per_kv, 1), jnp.float32),
                pltpu.VMEM((q_per_kv, 1), jnp.float32),
                pltpu.VMEM((q_per_kv, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, q_per_kv, D), q.dtype),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(B, Hq, D)
