"""repro.sim — trace-driven workload simulation with calibrated cost models.

The evaluation layer the paper's policy claims are checked on: seeded
arrival-process generators (``traces``) drive the REAL scheduling core on
a virtual clock (``simulator``), with dispatches priced by an analytical
roofline prior or an online-calibrated measured-cost table (``costmodel``)
and outcomes reduced to SLO/latency/goodput/isolation metrics with
deterministic JSON export (``metrics``). ``fleet`` + ``router`` scale the
same machinery to N replicas behind a routing policy, with per-replica
compile-cache cold-start accounting; replicas can be heterogeneous (one
``HardwareSpec`` each), elastic (``autoscale`` spins them up cold and
down deterministically), and individually calibrated
(``FleetCalibrator`` tables keyed by replica id). Policy sweeps over
millions of events run in seconds on CPU — and in CI.
"""

from repro.sim.autoscale import (  # noqa: F401
    Autoscaler,
    BacklogAutoscaler,
    ScaleEvent,
    make_autoscaler,
)
from repro.sim.costmodel import (  # noqa: F401
    HARDWARE_SPECS,
    STRATEGIES,
    CalibratedCostModel,
    ColdStartCostModel,
    FleetCalibrator,
    RooflineCostModel,
    batch_key,
    estimate_capacity_hz,
    resolve_spec,
)
from repro.sim.fleet import (  # noqa: F401
    FleetSimulator,
    fleet_capacity_hz,
    simulate_fleet,
)
from repro.sim.metrics import (  # noqa: F401
    SCHEMA_VERSION,
    FleetMetrics,
    MetricsAccumulator,
    SimMetrics,
    interference_matrix,
    to_bench_json,
)
from repro.sim.router import (  # noqa: F401
    ROUTERS,
    JoinShortestQueueRouter,
    LeastEstimatedCostRouter,
    RoundRobinRouter,
    Router,
    TenantAffinityRouter,
    make_router,
)
from repro.sim.simulator import (  # noqa: F401
    ReplicaPump,
    SimWorkload,
    Simulator,
    simulate,
)
from repro.sim.traces import (  # noqa: F401
    Arrival,
    CsvReplayTrace,
    DiurnalTrace,
    FlashCrowdTrace,
    MarkovModulatedTrace,
    MergedTrace,
    PoissonTrace,
    TenantSpec,
    Trace,
    fleet_sgemm_mix,
    make_trace,
    paper_sgemm_mix,
    prefill_decode_mix,
)
