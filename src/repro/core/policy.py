"""Pluggable batching-window policies for the unified scheduler.

The batching window is the space-time trade-off knob: wait longer and
more work merges into one super-kernel (throughput), wait shorter and
each item sees less queueing delay (latency). The paper uses a fixed
window; D-STACK-style SLO-aware scheduling shrinks the window as a
tenant's slack to its deadline shrinks, so a bucket holding a nearly-late
item dispatches immediately while relaxed buckets keep accumulating.

A policy answers one question: given the pending items of one bucket and
the current (injected) time, how long may the oldest item keep waiting?
The scheduler combines that with its size cap (a full bucket is always
ripe).
"""

from __future__ import annotations

from typing import Sequence

from repro.config import ScheduleConfig


class BatchingPolicy:
    """Decides when a bucket of pending workloads is ripe to dispatch."""

    name: str = "base"
    # True if window_s inspects every pending item (the scheduler then
    # materializes the bucket's pending list; False keeps ripeness O(1)).
    needs_pending: bool = False
    # True if window_s is a constant — independent of both the pending
    # set and the clock. Lets the simulator cache one window value and
    # maintain per-bucket ripeness instants incrementally (a bucket's
    # instant is fixed at submit time) instead of rescanning every
    # bucket per event. Time- or slack-dependent policies must leave
    # this False: their instants drift as the clock advances.
    stable_window: bool = False
    # True if the policy fixes each item's ripeness instant at arrival
    # (``ripe_at``) and wants ripe buckets drained earliest-deadline-
    # first. The scheduler switches to its EDF pump and the simulator
    # keeps a calendar of per-bucket min-ripe_at instants (same
    # incremental machinery stable_window buys the fixed policy, keyed
    # on item deadlines instead of one constant window).
    deadline_aware: bool = False

    def window_s(self, pending: Sequence, now: float) -> float:
        """Max time the oldest pending item may keep waiting (seconds).

        The scheduler's ``_ripe`` combines this with its size cap (a full
        bucket is always ripe) and the bucket's oldest arrival.
        """
        raise NotImplementedError


class FixedWindowPolicy(BatchingPolicy):
    """The paper's policy: one constant accumulation window."""

    name = "fixed"
    stable_window = True

    def __init__(self, window_s: float):
        self._window_s = window_s

    def window_s(self, pending: Sequence, now: float) -> float:
        return self._window_s


class SLOAdaptiveWindowPolicy(BatchingPolicy):
    """Window shrinks as any pending item's slack to its SLO shrinks.

    Each item's slack is ``(arrival + slo) - now``. The bucket's window is
    the most urgent item's ``clamp(slack * slack_fraction, min_window,
    base_window)`` — an item at (or past) its deadline forces immediate
    dispatch, an item with lots of slack waits the full base window and
    merges with more peers.
    """

    name = "slo_adaptive"
    needs_pending = True

    def __init__(
        self,
        base_window_s: float,
        min_window_s: float = 0.0,
        slack_fraction: float = 0.25,
    ):
        self.base_window_s = base_window_s
        self.min_window_s = min_window_s
        self.slack_fraction = slack_fraction

    def window_s(self, pending: Sequence, now: float) -> float:
        w = self.base_window_s
        for item in pending:
            slack = (item.arrival_time + item.slo_s) - now
            w = min(w, max(self.min_window_s, slack * self.slack_fraction))
        return w


class DeadlineEDFPolicy(BatchingPolicy):
    """Earliest-deadline-first: ripeness is fixed per item at arrival.

    An item arriving at ``a`` with SLO ``s`` ripens at ``a + min(base_window,
    s * (1 - lead_fraction))`` — tight deadlines ripen early (reserving
    ``lead_fraction`` of the SLO for dispatch + service), relaxed ones wait
    the full base window and merge with more peers. Because the instant
    depends only on the item (never on the clock), the simulator keeps the
    same incremental per-bucket calendar the fixed policy gets; the
    scheduler additionally drains ripe buckets in earliest-deadline order
    rather than dict order, so a late bucket never queues behind a relaxed
    one.
    """

    name = "edf"
    needs_pending = True
    deadline_aware = True

    def __init__(self, base_window_s: float, lead_fraction: float = 0.5):
        self.base_window_s = base_window_s
        self.lead_fraction = lead_fraction

    def ripe_at(self, item) -> float:
        """The instant ``item`` ripens — fixed once, at arrival."""
        return item.arrival_time + min(
            self.base_window_s, item.slo_s * (1.0 - self.lead_fraction)
        )

    def deadline(self, item) -> float:
        return item.arrival_time + item.slo_s

    def window_s(self, pending: Sequence, now: float) -> float:
        # A bucket is ripe once its earliest-ripening item ripens; expressed
        # as a window on the oldest arrival so _ripe's contract holds. The
        # oldest item always ripens no later than any newer one waiting at
        # most base_window, so the window is never negative.
        if not pending:
            return self.base_window_s
        return min(self.ripe_at(it) for it in pending) - pending[0].arrival_time


def make_policy(schedule: ScheduleConfig) -> BatchingPolicy:
    """Instantiate the policy named by ``schedule.batching_policy``."""
    if schedule.batching_policy == "fixed":
        return FixedWindowPolicy(schedule.batching_window_s)
    if schedule.batching_policy == "slo_adaptive":
        return SLOAdaptiveWindowPolicy(
            schedule.batching_window_s,
            schedule.min_batching_window_s,
            schedule.slo_slack_fraction,
        )
    if schedule.batching_policy == "edf":
        return DeadlineEDFPolicy(
            schedule.batching_window_s,
            schedule.deadline_lead_fraction,
        )
    raise ValueError(f"unknown batching policy: {schedule.batching_policy!r}")
