"""qwen2-7b [arXiv:2407.10671].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, QKV bias.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="qwen2-7b",
        source="arXiv:2407.10671",
        family="dense",
        num_layers=28,
        d_model=3584,
        vocab_size=152064,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
)
