"""Multi-tenant inference engine: space-time scheduled decode loop.

R tenants of the same architecture (different weights) are served by ONE
jitted, tenant-vmapped decode step over stacked params + stacked caches —
every projection/FFN GEMM in the model becomes an inter-model batched
super-kernel, which is the paper's mechanism applied to whole models.

``mode="time_only"`` provides the contrast case: the same work dispatched
per-tenant sequentially (one program per tenant per step), modeling CUDA
context time-slicing. Used by benchmarks/fig3_latency.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slo import LatencyMonitor
from repro.core.tenancy import stack_params
from repro.models import Model
from repro.serving.kv_cache import SlotManager
from repro.serving.request import InferenceRequest, RequestState
from repro.serving.sampling import SamplingParams, sample


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_tenants: int
    slots_per_tenant: int = 4
    cache_len: int = 256
    mode: str = "space_time"        # "space_time" | "time_only"
    # >0: prefill prompts in fixed-size chunks (one compile per chunk
    # length instead of per prompt length). Requires a non-sliding-window
    # architecture (chunked continuation needs linear caches).
    prefill_chunk: int = 0
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    seed: int = 0
    ewma_alpha: float = 0.2
    eviction_ratio: float = 10.0    # effectively off unless benchmarking isolation


class MultiTenantEngine:
    def __init__(self, model: Model, tenant_params: List[Any], config: EngineConfig):
        assert len(tenant_params) == config.num_tenants
        self.model = model
        self.cfg = config
        self.stacked_params = stack_params(tenant_params)
        self._tenant_params = tenant_params

        R, B = config.num_tenants, config.slots_per_tenant
        single = model.init_caches(B, config.cache_len)
        self.caches = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(), single
        )
        self.slots = SlotManager(R, B)
        self.monitor = LatencyMonitor(config.ewma_alpha, config.eviction_ratio)

        self.queue: List[InferenceRequest] = []
        self.active: Dict[tuple, InferenceRequest] = {}  # (tenant, slot) -> req
        self.finished: List[InferenceRequest] = []
        self.last_token = np.zeros((R, B), np.int32)
        self.steps = 0
        self.decode_tokens = 0
        self._sample_key = jax.random.PRNGKey(config.seed)

        # ---- jitted programs -------------------------------------------------
        def _decode_all(params, tokens, caches, lengths):
            return jax.vmap(model.forward_decode)(params, tokens, caches, lengths)

        self._decode_all = jax.jit(_decode_all)

        def _decode_one(params, tokens, caches, lengths):
            return model.forward_decode(params, tokens, caches, lengths)

        self._decode_one = jax.jit(_decode_one)

        def _prefill(params, tokens):
            return model.forward_prefill(params, tokens, cache_len=config.cache_len)

        self._prefill = jax.jit(_prefill)

        def _prefill_cont(params, tokens, caches, start):
            return model.forward_prefill(
                params, tokens, cache_len=config.cache_len,
                caches=caches, start=start,
            )

        self._prefill_cont = jax.jit(_prefill_cont)

    # ------------------------------------------------------------------ intake
    def submit(self, req: InferenceRequest, now: Optional[float] = None) -> None:
        req.arrival_time = now if now is not None else time.perf_counter()
        req.state = RequestState.QUEUED
        self.queue.append(req)

    # ------------------------------------------------------------------ prefill
    def _admit(self) -> None:
        # Prefill runs at EXACT prompt length (one compile per distinct
        # length). Padding would corrupt SSM/RWKV recurrent state; callers
        # wanting fewer compiles should bucket their prompt lengths.
        remaining = []
        for req in self.queue:
            slot = self.slots.acquire(req.tenant_id, req.request_id)
            if slot is None:
                remaining.append(req)
                continue
            req.slot = slot
            req.state = RequestState.PREFILLING
            params_t = jax.tree.map(lambda x: x[req.tenant_id], self.stacked_params)
            tokens = jnp.asarray(np.asarray(req.prompt, np.int32))[None, :]
            logits, cache = self._run_prefill(params_t, tokens)
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            req.first_token_time = time.perf_counter()
            req.prefill_time = req.first_token_time
            self._scatter_slot(req.tenant_id, slot, cache)
            self.slots.set_length(req.tenant_id, slot, tokens.shape[1])
            self.last_token[req.tenant_id, slot] = tok
            req.state = RequestState.DECODING
            self.active[(req.tenant_id, slot)] = req
        self.queue = remaining

    def _run_prefill(self, params_t, tokens):
        """Whole-prompt or chunked prefill (bounded compile count)."""
        C = self.cfg.prefill_chunk
        S = tokens.shape[1]
        if C <= 0 or S <= C:
            return self._prefill(params_t, tokens)
        logits, cache = self._prefill(params_t, tokens[:, :C])
        pos = C
        while pos < S:
            n = min(C, S - pos)  # ragged tail compiles once per tail length
            logits, cache = self._prefill_cont(
                params_t, tokens[:, pos:pos + n], cache, jnp.int32(pos))
            pos += n
        return logits, cache

    def _scatter_slot(self, tenant: int, slot: int, single_cache: Any) -> None:
        """Insert a prefilled (batch=1) cache into the stacked cohort cache."""

        def upd(big: jax.Array, small: jax.Array, slot_axis: int) -> jax.Array:
            idx = [0] * big.ndim
            idx[0] = tenant
            idx[slot_axis] = slot
            return jax.lax.dynamic_update_slice(
                big, small[None].astype(big.dtype), tuple(idx)
            )

        # unit caches: leaf (R, reps, B, ...) -> slot axis 2
        self.caches["unit"] = jax.tree.map(
            lambda big, small: upd(big, small, 2),
            self.caches["unit"],
            single_cache["unit"],
        )
        # rem caches: leaf (R, B, ...) -> slot axis 1
        self.caches["rem"] = jax.tree.map(
            lambda big, small: upd(big, small, 1),
            self.caches["rem"],
            single_cache["rem"],
        )

    # ------------------------------------------------------------------ decode
    def _lengths(self) -> np.ndarray:
        R, B = self.cfg.num_tenants, self.cfg.slots_per_tenant
        out = np.zeros((R, B), np.int32)
        for t in range(R):
            out[t] = self.slots.lengths(t)
        return out

    def step(self) -> int:
        """One engine iteration: admit + one decode step. Returns #tokens."""
        self._admit()
        if not self.active:
            return 0
        lengths = jnp.asarray(self._lengths())
        tokens = jnp.asarray(self.last_token)
        t0 = time.perf_counter()

        per_tenant_time: Dict[int, float] = {}
        if self.cfg.mode == "space_time":
            logits, self.caches = self._decode_all(
                self.stacked_params, tokens, self.caches, lengths
            )
            logits = jax.block_until_ready(logits)
        else:  # time_only: sequential per-tenant dispatch
            outs = []
            new_caches = []
            for t in range(self.cfg.num_tenants):
                tt0 = time.perf_counter()
                params_t = jax.tree.map(lambda x: x[t], self.stacked_params)
                caches_t = jax.tree.map(lambda x: x[t], self.caches)
                lg, nc = self._decode_one(params_t, tokens[t], caches_t, lengths[t])
                outs.append(jax.block_until_ready(lg))
                new_caches.append(nc)
                # a tenant's request latency includes waiting for every
                # tenant AHEAD of it in the time-slice order (the paper's
                # linear-slowdown mechanism)
                per_tenant_time[t] = time.perf_counter() - t0
            logits = jnp.stack(outs)
            self.caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        step_time = time.perf_counter() - t0

        if self.cfg.sampling.greedy:
            next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        else:
            self._sample_key, sub = jax.random.split(self._sample_key)
            next_tokens = np.asarray(sample(logits, self.cfg.sampling, sub), np.int32)
        produced = 0
        now = time.perf_counter()
        for (t, s), req in list(self.active.items()):
            tok = int(next_tokens[t, s])
            req.generated.append(tok)
            produced += 1
            self.slots.set_length(t, s, self.slots.slots[(t, s)].length + 1)
            self.last_token[t, s] = tok
            self.monitor.record(t, per_tenant_time.get(t, step_time), req.slo_s)
            if req.done:
                req.finish_time = now
                req.state = RequestState.FINISHED
                self.finished.append(req)
                self.slots.release(t, s)
                del self.active[(t, s)]
        self.steps += 1
        self.decode_tokens += produced
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            self.step()
            if not self.queue and not self.active:
                return
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------------ metrics
    def report(self) -> Dict[str, float]:
        rep = {
            "steps": float(self.steps),
            "decode_tokens": float(self.decode_tokens),
            "finished": float(len(self.finished)),
            "slot_utilization": self.slots.utilization(),
        }
        rep.update(self.monitor.summary())
        lats = [r.latency_s for r in self.finished if r.latency_s is not None]
        if lats:
            rep["req_mean_latency_s"] = float(np.mean(lats))
            rep["req_p95_latency_s"] = float(np.percentile(lats, 95))
        return rep
