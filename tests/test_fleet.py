"""The multi-replica fleet layer (repro.sim.fleet / router): routing
policies, per-replica compile-cache cold-start accounting, the merged
global timeline, and the determinism / scaling contracts CI asserts on.

The hypothesis goodput-monotone-in-replicas property lives at the bottom
behind the usual importorskip guard; a plain parametrized version of the
same property runs everywhere.
"""

import pytest

from repro.config import ScheduleConfig
from repro.sim import (
    ColdStartCostModel,
    FleetSimulator,
    ReplicaPump,
    RooflineCostModel,
    SimWorkload,
    estimate_capacity_hz,
    fleet_sgemm_mix,
    make_router,
    make_trace,
    simulate_fleet,
)

SCHED = ScheduleConfig(batching_window_s=0.0005, max_superkernel_size=32)
MIX = fleet_sgemm_mix(12)
BASE = RooflineCostModel(strategy="space_time")
CAP_HZ = estimate_capacity_hz(MIX, BASE)
OFFERED_HZ = 0.85 * 4 * CAP_HZ  # full-fleet rho for a 4-replica grid


def _fleet(replicas=4, router="jsq", events=2500, seed=0, compile_s=2e-4,
           process="mmpp"):
    return simulate_fleet(
        make_trace(process, MIX, OFFERED_HZ, events, seed=seed),
        replicas=replicas, router=router, schedule=SCHED, cost_model=BASE,
        compile_s=compile_s)


def _pumps(n, compile_s=0.0):
    out = []
    for i in range(n):
        model = BASE if compile_s == 0.0 else ColdStartCostModel(
            BASE, compile_s=compile_s)
        p = ReplicaPump(schedule=SCHED, cost_model=model, replica_id=i)
        p.track_inflight = True
        out.append(p)
    return out


def _fill(pump, spec, n):
    """Queue n items WITHOUT pumping (direct scheduler submit)."""
    for _ in range(n):
        pump.scheduler.submit(SimWorkload(spec, spec.cost), now=0.0)


# ------------------------------------------------------------------- routers
class TestRouters:
    def test_round_robin_cycles(self):
        r = make_router("round_robin")
        pumps = _pumps(3)
        assert [r.route(MIX[0], pumps, 0.0) for _ in range(7)] \
            == [0, 1, 2, 0, 1, 2, 0]

    def test_jsq_picks_shortest(self):
        r = make_router("jsq")
        pumps = _pumps(3)
        _fill(pumps[0], MIX[0], 5)
        _fill(pumps[1], MIX[0], 2)
        _fill(pumps[2], MIX[0], 9)
        assert r.route(MIX[0], pumps, 0.0) == 1

    def test_jsq_rotates_ties(self):
        """An all-even fleet must degenerate to round-robin, not herd
        every arrival onto replica 0."""
        r = make_router("jsq")
        pumps = _pumps(3)
        assert [r.route(MIX[0], pumps, 0.0) for _ in range(6)] \
            == [0, 1, 2, 0, 1, 2]

    def test_jsq_counts_inflight_work(self):
        """A replica whose clock ran ahead has an empty queue but undone
        work in fleet time; JSQ must not treat it as idle."""
        pumps = _pumps(2)
        # replica 0: dispatched work completing at t=5.0 on its own clock
        w = SimWorkload(MIX[0], MIX[0].cost)
        pumps[0].scheduler.submit(w, now=0.0)
        pumps[0].clock.advance_to(5.0)
        pumps[0]._absorb(pumps[0].scheduler.flush())
        assert pumps[0].queue_depth(now=0.0) == 1   # still in flight
        r = make_router("jsq")
        assert r.route(MIX[0], pumps, 0.0) == 1
        # reads are monotone in `now`: by 6.0 the work has landed (this
        # pops the in-flight record, so it comes after the routing check)
        assert pumps[0].queue_depth(now=6.0) == 0

    def test_affinity_pins_by_tenant(self):
        """Each tenant sticks to ONE replica (rendezvous hash of tenant and
        replica id — not position), and distinct tenants spread out."""
        r = make_router("affinity")
        pumps = _pumps(4)
        pins = {s.tenant_id: r.route(s, pumps, 0.0) for s in MIX}
        for s in MIX:  # idle fleet: the pin never wavers
            assert r.route(s, pumps, 0.0) == pins[s.tenant_id]
        assert len(set(pins.values())) > 1  # 12 tenants never herd onto one

    def test_affinity_spills_under_gross_imbalance(self):
        r = make_router("affinity", spill_factor=2.0, spill_grace=2)
        pumps = _pumps(2)
        _fill(pumps[0], MIX[0], 50)  # tenant 0 pins here, badly backed up
        assert r.route(MIX[0], pumps, 0.0) == 1

    def test_least_cost_prefers_warm_replica(self):
        """Equal queues, one replica already compiled the bucket: the
        cold-start term must steer the arrival to the warm cache."""
        r = make_router("least_cost")
        pumps = _pumps(2, compile_s=1e-3)
        pumps[1].cost_model((SimWorkload(MIX[0], MIX[0].cost),))  # warm it
        assert r.route(MIX[0], pumps, 0.0) == 1

    def test_least_cost_prefers_forming_batch(self):
        """An item whose bucket is already pending rides that super-kernel
        for its marginal roofline cost — cheaper than opening a fresh
        (cold) dispatch elsewhere."""
        pumps = _pumps(2, compile_s=1e-3)
        _fill(pumps[0], MIX[0], 3)
        w = SimWorkload(MIX[0], MIX[0].cost)
        assert pumps[0].estimate_item_s(w) < pumps[1].estimate_item_s(w)

    def test_unknown_router_rejected(self):
        with pytest.raises(ValueError, match="unknown router"):
            make_router("warp_speed")


# ---------------------------------------------------------------- cold start
class TestColdStartCostModel:
    def test_first_dispatch_pays_compile(self):
        m = ColdStartCostModel(BASE, compile_s=1e-3)
        batch = (SimWorkload(MIX[0], MIX[0].cost),)
        cold = m(batch)
        warm = m(batch)
        assert cold == pytest.approx(warm + 1e-3)
        assert m.cold_dispatches == 1 and m.dispatches == 2

    def test_per_variant_compile(self):
        """Different pow2-R variants of one bucket compile separately —
        same scheme as the live SuperKernelCache."""
        m = ColdStartCostModel(BASE, compile_s=1e-3)
        one = tuple(SimWorkload(MIX[0], MIX[0].cost) for _ in range(1))
        eight = tuple(SimWorkload(MIX[0], MIX[0].cost) for _ in range(8))
        m(one)
        assert m(eight) == pytest.approx(BASE(eight) + 1e-3)  # r8 still cold
        assert m.bucket_warm(MIX[0].bucket)

    def test_estimate_does_not_mutate(self):
        m = ColdStartCostModel(BASE, compile_s=1e-3)
        batch = (SimWorkload(MIX[0], MIX[0].cost),)
        est = m.estimate(batch)
        assert est == pytest.approx(BASE(batch) + 1e-3)
        assert m(batch) == pytest.approx(est)  # still cold: estimate was pure
        assert m.dispatches == 1

    def test_instances_are_independent_caches(self):
        a = ColdStartCostModel(BASE, compile_s=1e-3)
        b = ColdStartCostModel(BASE, compile_s=1e-3)
        batch = (SimWorkload(MIX[0], MIX[0].cost),)
        a(batch)
        assert b(batch) == pytest.approx(BASE(batch) + 1e-3)  # b still cold


# -------------------------------------------------------------------- fleet
class TestFleetSimulator:
    def test_all_events_complete_once(self):
        m = _fleet(events=2000)
        assert m.merged.completed == 2000
        assert sum(r.completed for r in m.per_replica) == 2000
        assert sum(m.routed_counts) == 2000

    def test_rejects_zero_replicas(self):
        with pytest.raises(ValueError, match="replicas"):
            FleetSimulator(0)

    @pytest.mark.parametrize("router", ["round_robin", "jsq", "least_cost",
                                        "affinity"])
    def test_same_seed_bit_identical_metrics_json(self, router):
        a = _fleet(router=router, seed=3).to_json()
        b = _fleet(router=router, seed=3).to_json()
        assert a == b  # byte-identical: the determinism contract

    def test_different_seed_differs(self):
        assert _fleet(seed=1).to_json() != _fleet(seed=2).to_json()

    def test_single_replica_matches_solo_semantics(self):
        """A 1-replica fleet with cold starts off is the solo simulator
        wearing a router — completions and latencies must agree."""
        from repro.sim import simulate

        trace = lambda: make_trace("mmpp", MIX, OFFERED_HZ, 1500, seed=0)  # noqa: E731
        fleet = _fleet(replicas=1, events=1500, compile_s=0.0)
        solo = simulate(trace(), SCHED, BASE)
        assert fleet.merged.to_json() == solo.to_json()

    def test_routing_imbalance_round_robin_floor(self):
        m = _fleet(router="round_robin", events=2000)
        assert m.routing_imbalance == pytest.approx(0.0)
        assert m.utilization_spread >= 0.0

    def test_cold_fraction_decreases_over_trace(self):
        """Caches warm up: the cold-dispatch fraction in the first half of
        the horizon must exceed the second half's."""
        for seed in (0, 1, 2):
            m = _fleet(seed=seed)
            first, second = m.cold_fraction_halves()
            assert first > second
            assert m.cold_start_fraction > 0.0

    def test_goodput_monotone_in_replicas_plain(self):
        for seed in (0, 5):
            goods = [_fleet(replicas=n, seed=seed)
                     .summary()["goodput_cost_per_s"] for n in (1, 2, 4)]
            for lo, hi in zip(goods, goods[1:]):
                assert hi >= lo * (1.0 - 1e-6)

    def test_load_aware_routers_beat_round_robin_p95(self):
        """The fleet_sweep --check contract at its pinned seed."""
        rr = _fleet(router="round_robin").summary()["p95_s"]
        for router in ("jsq", "least_cost"):
            assert _fleet(router=router).summary()["p95_s"] <= rr

    def test_replica_id_reaches_dispatch_tap(self):
        """core.scheduler forwards its replica identity to on_dispatch."""
        seen = set()
        sim = FleetSimulator(3, router="round_robin", schedule=SCHED,
                             cost_model=BASE, compile_s=0.0)
        for pump in sim.pumps:
            pump.scheduler.on_dispatch = \
                lambda batch, dt, rid: seen.add(rid)
        sim.run(make_trace("poisson", MIX, OFFERED_HZ, 300, seed=0))
        assert seen == {0, 1, 2}

    def test_summary_carries_fleet_signals(self):
        s = _fleet(events=1500).summary()
        for key in ("replicas", "routing_imbalance", "utilization_spread",
                    "cold_start_fraction", "cold_fraction_first_half",
                    "cold_fraction_second_half"):
            assert key in s
        assert s["replicas"] == 4.0
        # fleet utilization is the per-replica mean, never the clamped sum
        assert 0.0 <= s["utilization"] <= 1.0

    def test_bench_rows_include_fleet_rows(self):
        rows = _fleet(events=1500).bench_rows("fleet/test")
        names = [r[0] for r in rows]
        assert "fleet/test/p95" in names
        assert "fleet/test/routing_imbalance" in names
        assert "fleet/test/cold_fraction" in names


# --------------------------------------------------- hypothesis (optional)
def test_goodput_monotone_in_replicas_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings.register_profile("fleet", max_examples=8, deadline=None)
    settings.load_profile("fleet")

    @given(seed=st.integers(0, 11),
           router=st.sampled_from(["round_robin", "jsq"]))
    def prop(seed, router):
        goods = [_fleet(replicas=n, router=router, seed=seed, events=1200)
                 .summary()["goodput_cost_per_s"] for n in (1, 2, 4)]
        for lo, hi in zip(goods, goods[1:]):
            assert hi >= lo * (1.0 - 1e-6)
    prop()
