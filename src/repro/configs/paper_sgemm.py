"""The paper's own micro-benchmark problem shapes (Table 1 / Fig 7).

Not a ModelConfig — these are the three SGEMM problem geometries the paper
batches into super-kernels, used by benchmarks/table1_sgemm.py and the
scheduler tests.
"""

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class GemmShape:
    name: str
    M: int
    N: int
    K: int

    @property
    def flops(self) -> int:
        return 2 * self.M * self.N * self.K


# Table 1 geometries (verbatim from the paper).
PAPER_GEMM_SHAPES: Dict[str, GemmShape] = {
    # "Matrix-vector: RNN" M=512, N=1, K=512
    "rnn_matvec": GemmShape("rnn_matvec", M=512, N=1, K=512),
    # "ResNet-18 conv2_2" im2col SGEMM: M=256, N=128, K=1152
    # (128x128 input image, 3x3 kernel, 128 in/out channels)
    "resnet18_conv2_2": GemmShape("resnet18_conv2_2", M=256, N=128, K=1152),
    # "Square matrix-matrix" M=N=K=256
    "square_256": GemmShape("square_256", M=256, N=256, K=256),
}

# R sweep used for the Table 1 geomean rows: 2 <= R <= 120.
PAPER_R_SWEEP = (2, 4, 8, 10, 16, 20, 32, 48, 64, 96, 120)
