"""Multi-tenant model store: stacked weight pytrees + eviction.

The space-time scheduler's model-level form: R tenants of the same
architecture (different weights — "These models have different weights and
inputs, as is likely in a multi-tenancy setting") are stored STACKED along
a leading tenant axis, so one vmap'd program serves all tenants — every
matmul becomes a batched super-kernel, and on a pod the tenant axis shards
over the `data` mesh axis.

Contrast with per-process replication (paper Fig 5): stacked storage holds
exactly R copies of the weights and zero framework duplication, which is
what let the paper's explicit-streams variant scale to 60+ ResNet-50s
while MPS hit the 16 GB wall at 18.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

Params = Any


def stack_params(params_list: List[Params]) -> Params:
    """Stack R tenants' pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_params(stacked: Params, r: int) -> List[Params]:
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(r)]


def tenant_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


@dataclasses.dataclass
class TenantSlot:
    tenant_id: int
    active: bool = True
    evictions: int = 0


class TenantManager:
    """Registry of co-located tenants and their stacked weights."""

    def __init__(self) -> None:
        self._slots: Dict[int, TenantSlot] = {}
        self._params: Dict[int, Params] = {}
        self._stacked: Optional[Params] = None
        self._stack_order: List[int] = []
        self._dirty = True

    # ------------------------------------------------------------- membership
    def register(self, tenant_id: int, params: Params) -> None:
        if tenant_id in self._slots:
            raise ValueError(f"tenant {tenant_id} already registered")
        self._slots[tenant_id] = TenantSlot(tenant_id)
        self._params[tenant_id] = params
        self._dirty = True

    def evict(self, tenant_id: int) -> None:
        """Straggler eviction: drop the tenant from the merged cohort.

        The tenant is marked inactive (its weights stay resident so it can
        be re-admitted to a fresh slot, as the paper's evict-and-restart
        policy does) and the stacked cohort is rebuilt without it.
        """
        slot = self._slots[tenant_id]
        slot.active = False
        slot.evictions += 1
        self._dirty = True

    def readmit(self, tenant_id: int) -> None:
        self._slots[tenant_id].active = True
        self._dirty = True

    @property
    def active_ids(self) -> List[int]:
        return sorted(tid for tid, s in self._slots.items() if s.active)

    # ------------------------------------------------------------- stacking
    def stacked(self) -> Params:
        """Stacked weights of the ACTIVE cohort, rebuilt lazily on change."""
        if self._dirty:
            ids = self.active_ids
            if not ids:
                raise ValueError("no active tenants")
            self._stacked = stack_params([self._params[i] for i in ids])
            self._stack_order = ids
            self._dirty = False
        return self._stacked

    @property
    def stack_order(self) -> List[int]:
        self.stacked()
        return list(self._stack_order)

    def memory_bytes(self) -> int:
        """Total resident weight bytes (stacked cohort)."""
        return sum(tenant_bytes(self._params[i]) for i in self._slots)
